//! Baselines vs BigFCM: the comparative claims of the paper's evaluation,
//! verified as *shape* assertions at test scale.

use std::sync::Arc;

use bigfcm::baselines::{run_baseline, BaselineAlgo};
use bigfcm::config::Config;
use bigfcm::coordinator::BigFcm;
use bigfcm::data::synth::{blobs, susy_like};
use bigfcm::fcm::seeding::random_records;
use bigfcm::fcm::{assign_hard, NativeBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{Engine, EngineOptions};
use bigfcm::metrics::{confusion_accuracy, silhouette_width_sampled, speedup};
use bigfcm::prng::Pcg;

fn cfg_with(c: usize, eps: f64, max_iter: usize) -> Config {
    let mut cfg = Config::default();
    cfg.fcm.clusters = c;
    cfg.fcm.epsilon = eps;
    cfg.fcm.max_iterations = max_iter;
    cfg.cluster.block_records = 1024;
    cfg
}

fn engine(cfg: &Config) -> Engine {
    Engine::new(EngineOptions::default(), cfg.overhead.clone())
}

/// Table 3/4 shape: BigFCM's modelled time beats both baselines by a wide
/// margin at tight epsilon (job-per-iteration vs single job).
#[test]
fn bigfcm_beats_baselines_at_tight_epsilon() {
    let data = susy_like(8_000, 3);
    let store = Arc::new(BlockStore::in_memory("susy", &data.features, 1024, 4).unwrap());
    let cfg = cfg_with(2, 5e-9, 100);

    let mut e = engine(&cfg);
    let big = BigFcm::new(cfg.clone()).clusters(2).run_with_engine(&store, &mut e).unwrap();
    let mut e = engine(&cfg);
    let km = run_baseline(BaselineAlgo::KMeans, &cfg, &store, Arc::new(NativeBackend), &mut e)
        .unwrap();
    let mut e = engine(&cfg);
    let fkm = run_baseline(
        BaselineAlgo::FuzzyKMeans,
        &cfg,
        &store,
        Arc::new(NativeBackend),
        &mut e,
    )
    .unwrap();

    let sp_km = speedup(km.modelled_s(), big.modelled_s());
    let sp_fkm = speedup(fkm.modelled_s(), big.modelled_s());
    assert!(sp_km > 3.0, "KM speedup only {sp_km:.1}x");
    assert!(sp_fkm > 3.0, "FKM speedup only {sp_fkm:.1}x");
    // The gap is driven by job count: baselines launch one job per iteration.
    assert!(km.jobs > 1);
    assert!(fkm.jobs > 1);
}

/// Figure 2 shape: BigFCM modelled time is ~flat in epsilon while the FKM
/// baseline grows.
#[test]
fn bigfcm_flat_in_epsilon_baseline_grows() {
    let data = susy_like(6_000, 5);
    let store = Arc::new(BlockStore::in_memory("susy", &data.features, 1024, 4).unwrap());
    let mut big_times = Vec::new();
    let mut fkm_jobs = Vec::new();
    for eps in [5e-2, 5e-5, 5e-9] {
        let cfg = cfg_with(2, eps, 80);
        let mut e = engine(&cfg);
        let big = BigFcm::new(cfg.clone()).clusters(2).epsilon(eps).run_with_engine(&store, &mut e).unwrap();
        big_times.push(big.modelled_s());
        let mut e = engine(&cfg);
        let fkm = run_baseline(
            BaselineAlgo::FuzzyKMeans,
            &cfg,
            &store,
            Arc::new(NativeBackend),
            &mut e,
        )
        .unwrap();
        fkm_jobs.push(fkm.jobs);
    }
    // BigFCM: job count fixed at 1 → modelled time within 2x across epsilons.
    let (min_t, max_t) = (
        big_times.iter().cloned().fold(f64::INFINITY, f64::min),
        big_times.iter().cloned().fold(0.0, f64::max),
    );
    assert!(max_t / min_t < 2.0, "BigFCM not flat in epsilon: {big_times:?}");
    // FKM: strictly more jobs as epsilon tightens.
    assert!(
        fkm_jobs[2] > fkm_jobs[0],
        "FKM jobs did not grow with tighter epsilon: {fkm_jobs:?}"
    );
}

/// Table 7 shape: BigFCM clustering quality is not worse than the FKM
/// baseline on a separable workload.
#[test]
fn quality_parity_with_baseline() {
    let data = blobs(4_000, 6, 4, 0.35, 7);
    let labels = data.labels.as_ref().unwrap();
    let store = Arc::new(BlockStore::in_memory("blobs", &data.features, 512, 4).unwrap());
    let cfg = cfg_with(4, 1e-8, 200);

    let mut e = engine(&cfg);
    let big = BigFcm::new(cfg.clone()).clusters(4).run_with_engine(&store, &mut e).unwrap();
    let mut e = engine(&cfg);
    let fkm = run_baseline(
        BaselineAlgo::FuzzyKMeans,
        &cfg,
        &store,
        Arc::new(NativeBackend),
        &mut e,
    )
    .unwrap();

    let acc_big = confusion_accuracy(&assign_hard(&data.features, &big.centers), labels, 4);
    let acc_fkm = confusion_accuracy(&assign_hard(&data.features, &fkm.centers), labels, 4);
    assert!(
        acc_big + 0.03 >= acc_fkm,
        "BigFCM accuracy {acc_big:.3} markedly below baseline {acc_fkm:.3}"
    );
    assert!(acc_big > 0.9, "absolute quality too low: {acc_big:.3}");
}

/// Table 8 shape: BigFCM silhouette is positive and stable across sample
/// sizes on a clusterable workload.
#[test]
fn silhouette_positive_and_stable() {
    let data = blobs(6_000, 8, 2, 0.6, 11);
    let store = Arc::new(BlockStore::in_memory("blobs", &data.features, 1024, 4).unwrap());
    let cfg = cfg_with(2, 1e-8, 200);
    let mut e = engine(&cfg);
    let big = BigFcm::new(cfg).clusters(2).run_with_engine(&store, &mut e).unwrap();
    let assign = assign_hard(&data.features, &big.centers);
    let mut values = Vec::new();
    for (i, k) in [1000usize, 2000, 3000, 4000].into_iter().enumerate() {
        let mut rng = Pcg::new(100 + i as u64);
        values.push(silhouette_width_sampled(&data.features, &assign, k, &mut rng));
    }
    for v in &values {
        assert!(*v > 0.2, "silhouette not positive: {values:?}");
    }
    let spread = values.iter().cloned().fold(0.0, f64::max)
        - values.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread < 0.1, "silhouette unstable across samples: {values:?}");
}

/// Table 5 shape: the fast combiner update is O(n·c) — doubling C must
/// roughly double (not quadruple) the cost of one pass.
#[test]
fn cost_near_linear_in_clusters() {
    use bigfcm::fcm::native::fcm_partials_native;
    let data = susy_like(30_000, 13);
    let w = vec![1.0f32; data.features.rows()];
    let mut rng = Pcg::new(99);
    let time_pass = |c: usize, rng: &mut Pcg| {
        let v = random_records(&data.features, c, rng);
        // Warm-up + 3 timed passes, take the min (noise robustness).
        fcm_partials_native(&data.features, &v, &w, 2.0);
        (0..3)
            .map(|_| {
                let t0 = std::time::Instant::now();
                fcm_partials_native(&data.features, &v, &w, 2.0);
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };
    let t6 = time_pass(6, &mut rng);
    let t12 = time_pass(12, &mut rng);
    let t24 = time_pass(24, &mut rng);
    // Linear would be 2.0x per doubling; quadratic 4.0x. Require < 3.2x.
    assert!(t12 / t6 < 3.2, "6->12 scaling {:.2}x", t12 / t6);
    assert!(t24 / t12 < 3.2, "12->24 scaling {:.2}x", t24 / t12);
}

/// Baselines converge to sane centers — they are real algorithms, not straw
/// men: on separable data KM and FKM recover the blob structure from at
/// least one of a few random seeds (random seeding can hit the classic
/// two-seeds-in-one-blob local minimum, exactly as real Mahout does).
#[test]
fn baselines_are_not_strawmen() {
    let data = blobs(3_000, 4, 3, 0.25, 17);
    let labels = data.labels.as_ref().unwrap();
    let store = Arc::new(BlockStore::in_memory("blobs", &data.features, 512, 4).unwrap());
    for algo in [BaselineAlgo::KMeans, BaselineAlgo::FuzzyKMeans] {
        let mut best = 0.0f64;
        for seed in 0..4u64 {
            let mut cfg = cfg_with(3, 1e-9, 300);
            cfg.seed = 1000 + seed;
            let mut e = engine(&cfg);
            let run = run_baseline(algo, &cfg, &store, Arc::new(NativeBackend), &mut e).unwrap();
            assert!(run.converged, "{algo:?} did not converge (seed {seed})");
            let acc = confusion_accuracy(&assign_hard(&data.features, &run.centers), labels, 3);
            best = best.max(acc);
            if best > 0.95 {
                break;
            }
        }
        assert!(best > 0.95, "{algo:?} best accuracy {best:.3}");
    }
}
