//! End-to-end pipeline integration: BigFCM over the MapReduce substrate,
//! cross-checked against single-machine clustering and the baselines.

use std::sync::Arc;

use bigfcm::config::Config;
use bigfcm::coordinator::BigFcm;
use bigfcm::data::matrix::dist2;
use bigfcm::data::synth::blobs;
use bigfcm::data::{builtin, Matrix};
use bigfcm::fcm::loops::{run_fcm, FcmParams};
use bigfcm::fcm::{assign_hard, NativeBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{Engine, EngineOptions};
use bigfcm::metrics::confusion_accuracy;

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.block_records = 512;
    cfg.fcm.epsilon = 1e-9;
    cfg
}

/// The headline soundness property: the distributed pipeline must land on
/// the same cluster structure as a single-machine FCM over all records.
#[test]
fn pipeline_matches_single_machine_fcm() {
    let data = blobs(4096, 4, 3, 0.25, 101);
    let cfg = small_cfg();
    let run = BigFcm::new(cfg.clone())
        .clusters(3)
        .run_in_memory(&data.features)
        .unwrap();

    // The pipeline's centers must be (near) a fixed point of global FCM.
    let w = vec![1.0f32; data.features.rows()];
    let global = run_fcm(
        &NativeBackend,
        &data.features,
        &w,
        run.centers.clone(),
        &FcmParams { epsilon: 1e-9, ..Default::default() },
    )
    .unwrap();

    for i in 0..3 {
        let best = (0..3)
            .map(|j| dist2(run.centers.row(i), global.centers.row(j)))
            .fold(f64::INFINITY, f64::min);
        assert!(best < 0.02, "pipeline center {i} not a global fixed point ({best})");
    }
    // And the structure matches the generating blobs.
    let labels = data.labels.as_ref().unwrap();
    let acc = confusion_accuracy(&assign_hard(&data.features, &run.centers), labels, 3);
    assert!(acc > 0.95, "accuracy {acc}");
}

#[test]
fn pipeline_is_deterministic_given_seed() {
    let data = blobs(2048, 3, 3, 0.3, 55);
    // Pin the flag: the driver's FCM-vs-WFCMPB race is timing-dependent by
    // design (the paper's Algorithm 3 line 6), so determinism is only
    // guaranteed under a forced policy.
    let mut cfg = small_cfg();
    cfg.fcm.flag_policy = bigfcm::config::FlagPolicy::ForceFcm;
    let a = BigFcm::new(cfg.clone()).clusters(3).seed(7).run_in_memory(&data.features).unwrap();
    let b = BigFcm::new(cfg).clusters(3).seed(7).run_in_memory(&data.features).unwrap();
    assert_eq!(a.centers.as_slice(), b.centers.as_slice());
    assert_eq!(a.driver.flag_fcm, b.driver.flag_fcm);
}

#[test]
fn pipeline_single_job_regardless_of_epsilon() {
    // The paper's core scaling property: one MR job total, for any epsilon.
    let data = blobs(2048, 3, 2, 0.3, 77);
    for eps in [5e-2, 5e-7, 5e-11] {
        let mut engine = Engine::new(EngineOptions::default(), small_cfg().overhead.clone());
        let store = Arc::new(BlockStore::in_memory("t", &data.features, 512, 4).unwrap());
        let _run = BigFcm::new(small_cfg())
            .clusters(2)
            .epsilon(eps)
            .run_with_engine(&store, &mut engine)
            .unwrap();
        assert_eq!(engine.clock().jobs(), 1, "eps={eps}: more than one MR job");
    }
}

#[test]
fn pipeline_handles_tiny_datasets() {
    let data = builtin::iris();
    let mut cfg = small_cfg();
    cfg.cluster.block_records = 64; // force multiple blocks even on iris
    cfg.fcm.fuzzifier = 1.2;
    cfg.fcm.epsilon = 5e-2;
    let run = BigFcm::new(cfg).clusters(3).run_in_memory(&data.features).unwrap();
    assert_eq!(run.centers.rows(), 3);
    let labels = data.labels.as_ref().unwrap();
    let acc = confusion_accuracy(&assign_hard(&data.features, &run.centers), labels, 3);
    // Iris fuzzy clustering lands 80-96% depending on seeding; the paper
    // reports 92%.
    assert!(acc > 0.75, "iris accuracy {acc}");
}

#[test]
fn pipeline_survives_injected_task_faults() {
    let data = blobs(4096, 3, 3, 0.25, 31);
    let mut cfg = small_cfg();
    cfg.fcm.flag_policy = bigfcm::config::FlagPolicy::ForceFcm;
    let store = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
    let mut engine = Engine::new(
        EngineOptions { workers: 4, fault_rate: 0.3, fault_seed: 5, ..Default::default() },
        cfg.overhead.clone(),
    );
    let run = BigFcm::new(cfg.clone())
        .clusters(3)
        .run_with_engine(&store, &mut engine)
        .unwrap();
    assert!(run.job.attempts > run.job.map_tasks, "faults were not injected");
    // Results are identical to a fault-free run (idempotent combiners).
    let clean = BigFcm::new(cfg)
        .clusters(3)
        .run_store(&store)
        .unwrap();
    for (a, b) in run.centers.as_slice().iter().zip(clean.centers.as_slice()) {
        assert!((a - b).abs() < 1e-5, "fault injection changed the result");
    }
}

#[test]
fn disk_and_memory_stores_agree() {
    let data = blobs(2000, 4, 2, 0.3, 13);
    let dir = std::env::temp_dir().join(format!("bigfcm_it_{}", std::process::id()));
    let disk = Arc::new(BlockStore::on_disk("t", &data.features, 256, 4, dir.clone()).unwrap());
    let mem = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
    // Pin the flag (the FCM-vs-WFCMPB race is timing-dependent by design).
    let mut cfg = small_cfg();
    cfg.fcm.flag_policy = bigfcm::config::FlagPolicy::ForceFcm;
    let a = BigFcm::new(cfg.clone()).clusters(2).run_store(&disk).unwrap();
    let b = BigFcm::new(cfg).clusters(2).run_store(&mem).unwrap();
    assert_eq!(a.centers.as_slice(), b.centers.as_slice());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn weights_reflect_partition_mass() {
    // All record mass must be conserved into the final center weights
    // (within fuzzy-membership shrinkage: Σ weights <= N, > 0).
    let data = blobs(3000, 3, 3, 0.25, 17);
    let run = BigFcm::new(small_cfg()).clusters(3).run_in_memory(&data.features).unwrap();
    let total: f64 = run.weights.iter().sum();
    assert!(total > 0.0);
    assert!(total.is_finite(), "weights contain NaN/inf: {:?}", run.weights);
}

#[test]
fn multi_reducer_tree_agrees_with_flat() {
    let data = blobs(4096, 3, 3, 0.25, 23);
    let store = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
    let mut cfg_flat = small_cfg();
    cfg_flat.cluster.reducers = 1;
    let mut cfg_tree = small_cfg();
    cfg_tree.cluster.reducers = 4;
    let a = BigFcm::new(cfg_flat).clusters(3).run_store(&store).unwrap();
    let b = BigFcm::new(cfg_tree).clusters(3).run_store(&store).unwrap();
    for i in 0..3 {
        let best = (0..3)
            .map(|j| dist2(a.centers.row(i), b.centers.row(j)))
            .fold(f64::INFINITY, f64::min);
        assert!(best < 0.05, "tree reducer diverged at center {i}: {best}");
    }
}

#[test]
fn backend_trait_object_works_via_arc() {
    // The builder accepts any KernelBackend behind an Arc.
    let data = blobs(1024, 3, 2, 0.3, 29);
    let run = BigFcm::new(small_cfg())
        .backend(Arc::new(NativeBackend))
        .clusters(2)
        .run_in_memory(&data.features)
        .unwrap();
    assert_eq!(run.centers.rows(), 2);
}

#[test]
fn sim_cost_breakdown_is_consistent() {
    let data = blobs(2048, 3, 2, 0.3, 41);
    let run = BigFcm::new(small_cfg()).clusters(2).run_in_memory(&data.features).unwrap();
    let s = &run.sim;
    let total = s.total_s();
    let parts = s.job_startup_s + s.task_launch_s + s.hdfs_io_s + s.shuffle_s + s.compute_s;
    assert!((total - parts).abs() < 1e-9);
    // Exactly one job startup.
    assert!((s.job_startup_s - small_cfg().overhead.job_startup_s).abs() < 1e-9);
}

#[test]
fn empty_matrix_is_rejected() {
    let empty = Matrix::zeros(0, 3);
    assert!(BigFcm::new(small_cfg()).clusters(2).run_in_memory(&empty).is_err());
}

#[test]
fn m_1_2_small_distances_no_nan() {
    // Regression: at m=1.2 the exponent 1/(m-1)=5 used to underflow f32 in
    // the PJRT kernels and produce NaN weights; the ratio-normalised
    // formulation must stay finite even with near-duplicate records.
    let mut rows = Vec::new();
    for i in 0..512 {
        let v = (i % 3) as f32;
        rows.push(vec![v + 1e-6 * i as f32, v]);
    }
    let data = Matrix::from_rows(&rows);
    let mut cfg = small_cfg();
    cfg.fcm.fuzzifier = 1.2;
    cfg.cluster.block_records = 128;
    let run = BigFcm::new(cfg).clusters(3).run_in_memory(&data).unwrap();
    assert!(run.centers.as_slice().iter().all(|v| v.is_finite()));
    assert!(run.weights.iter().all(|w| w.is_finite()));
}
