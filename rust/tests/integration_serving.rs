//! Serving-subsystem acceptance tests: persisted bundles roundtrip
//! bitwise, the online service's rows match the single-shot membership
//! oracle within 1e-6 (and sum to 1), micro-batching actually coalesces,
//! and the bulk ScoreJob labels a store identically to the single-shot
//! path — on both the native and PJRT-shim backends, with fault-injected
//! re-execution never corrupting the output store. The registry/front
//! layer rides the same oracles: hot reload stays generation-consistent
//! under concurrent load, per-tenant quotas reject (and count) at
//! admission, and wire framing errors are isolated per connection.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bigfcm::config::OverheadConfig;
use bigfcm::data::normalize::Scaler;
use bigfcm::data::synth::blobs;
use bigfcm::data::Matrix;
use bigfcm::fcm::native::memberships;
use bigfcm::fcm::{
    BoundRows, Kernel, KernelBackend, NativeBackend, Partials, QuantMode, SessionAlgo, Variant,
};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{Engine, EngineOptions};
use bigfcm::prng::Pcg;
use bigfcm::runtime::PjrtShimBackend;
use bigfcm::serve::{
    client_call, dense_from_top_k, run_score_job, FrontOptions, Lane, ModelBundle, ModelRegistry,
    ScoreService, ServeFront, ServeOptions,
};
use bigfcm::Error;

/// A deterministic trained-ish bundle over blobs: centers picked from the
/// (normalized) data, min-max scaler attached.
fn fixture(seed: u64, n: usize, d: usize, c: usize) -> (ModelBundle, Matrix) {
    let data = blobs(n, d, c, 0.25, seed);
    let scaler = Scaler::min_max(&data.features);
    let mut normalized = data.features.clone();
    scaler.apply(&mut normalized);
    let mut centers = Matrix::zeros(c, d);
    for i in 0..c {
        centers.row_mut(i).copy_from_slice(normalized.row(i * (n / c)));
    }
    let mut bundle = ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0);
    bundle.scaler = Some(scaler);
    bundle.dataset = "blobs".into();
    bundle.trained_rows = n as u64;
    (bundle, data.features)
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bigfcm_serving_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn bundle_codec_roundtrips_bitwise_under_random_shapes() {
    for case in 0..8u64 {
        let mut rng = Pcg::new(5_000 + case);
        let c = 2 + rng.next_index(6);
        let d = 1 + rng.next_index(9);
        let mut centers = Matrix::zeros(c, d);
        for v in centers.as_mut_slice() {
            *v = rng.normal() as f32;
        }
        let algo = if case % 3 == 0 { SessionAlgo::KMeans } else { SessionAlgo::Fcm };
        let variant = if case % 2 == 0 { Variant::Fast } else { Variant::Classic };
        let mut b = ModelBundle::new(centers, algo, variant, 1.2 + rng.next_f64());
        b.weights = (0..c).map(|_| rng.next_f64() * 1e4).collect();
        if case % 2 == 1 {
            b.scaler = Some(Scaler {
                offset: (0..d).map(|_| rng.normal() as f32).collect(),
                scale: (0..d).map(|_| rng.next_f32() + 0.25).collect(),
            });
        }
        b.seed = case;
        b.dataset = format!("case-{case}");
        b.trained_rows = rng.next_u64() % 1_000_000;
        b.iterations = rng.next_u64() % 1_000;
        b.objective = rng.normal();
        b.converged = case % 2 == 0;
        b.records_pruned = rng.next_u64() % 1_000_000;
        let img = b.encode();
        let back = ModelBundle::decode(&img)
            .unwrap_or_else(|e| panic!("case {case}: decode failed: {e}"));
        assert_eq!(back.encode(), img, "case {case}: roundtrip is not bitwise");
    }
}

#[test]
fn bundle_save_load_detects_file_corruption() {
    let (bundle, _) = fixture(6_001, 400, 4, 3);
    let dir = tmp_dir("bundle");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.bfm");
    let bytes = bundle.save(&path).unwrap();
    let back = ModelBundle::load(&path).unwrap();
    assert_eq!(back.encode(), bundle.encode());
    let mut img = std::fs::read(&path).unwrap();
    assert_eq!(img.len() as u64, bytes);
    let mid = img.len() / 3;
    img[mid] ^= 0x04;
    std::fs::write(&path, &img).unwrap();
    assert!(ModelBundle::load(&path).is_err(), "flipped bit must fail the checksum");
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: service rows sum to 1 within 1e-6 and match the single-shot
/// `memberships()` oracle within 1e-6 — native and shim backends.
#[test]
fn service_rows_match_single_shot_on_native_and_shim() {
    let (bundle, raw) = fixture(6_100, 600, 5, 3);
    let centers = bundle.centers.clone();
    let scaler = bundle.scaler.clone().unwrap();
    let mut normalized = raw.clone();
    scaler.apply(&mut normalized);
    let oracle = memberships(&normalized, &centers, 2.0);
    let backends: Vec<(&str, Arc<dyn KernelBackend>)> = vec![
        ("native", Arc::new(NativeBackend)),
        ("pjrt-shim", Arc::new(PjrtShimBackend::new(128))),
    ];
    for (name, backend) in backends {
        let svc = ScoreService::builder(bundle.clone()).spawn(backend).unwrap();
        for k in (0..600).step_by(37) {
            let u = svc.score(raw.row(k)).unwrap();
            let s: f32 = u.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "{name} row {k}: sums to {s}");
            for (i, (a, b)) in u.iter().zip(oracle.row(k)).enumerate() {
                assert!(
                    (a - b).abs() < 1e-6,
                    "{name} row {k} center {i}: {a} vs oracle {b}"
                );
            }
        }
    }
}

#[test]
fn concurrent_clients_coalesce_and_percentiles_are_ordered() {
    let (bundle, raw) = fixture(6_200, 512, 4, 3);
    let svc = Arc::new(
        ScoreService::builder(bundle)
            .max_batch(16)
            .linger(Duration::from_millis(40))
            .spawn(Arc::new(NativeBackend))
            .unwrap(),
    );
    let raw = Arc::new(raw);
    let handles: Vec<_> = (0..6)
        .map(|ci| {
            let svc = Arc::clone(&svc);
            let x = Arc::clone(&raw);
            std::thread::spawn(move || {
                for r in 0..4usize {
                    svc.score(x.row((ci * 80 + r) % x.rows())).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let stats = svc.stats();
    assert_eq!(stats.requests, 24);
    assert!(
        stats.batch_fill > 1.0,
        "6 concurrent clients under a 40ms linger must coalesce (fill {}, {} batches)",
        stats.batch_fill,
        stats.batches
    );
    assert!(stats.p50_us <= stats.p95_us && stats.p95_us <= stats.p99_us);
    assert!(stats.p99_us <= stats.max_us);
    assert!(stats.queue_peak >= 1);
}

/// Acceptance: the bulk ScoreJob's output matches the single-shot
/// membership path within 1e-6 on every sampled record — native and shim.
#[test]
fn bulk_score_job_matches_single_shot_on_both_backends() {
    let (bundle, raw) = fixture(6_300, 2_048, 4, 4);
    let store = Arc::new(BlockStore::in_memory("raw", &raw, 256, 4).unwrap());
    let scaler = bundle.scaler.clone().unwrap();
    let mut normalized = raw.clone();
    scaler.apply(&mut normalized);
    let oracle = memberships(&normalized, &bundle.centers, 2.0);
    let backends: Vec<(&str, Arc<dyn KernelBackend>)> = vec![
        ("native", Arc::new(NativeBackend)),
        ("pjrt-shim", Arc::new(PjrtShimBackend::new(100))),
    ];
    for (name, backend) in backends {
        let dir = tmp_dir(&format!("bulk_{name}"));
        let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let outcome = run_score_job(
            &mut engine,
            &store,
            Arc::new(bundle.clone()),
            backend,
            4, // k = C: the sparse rows carry the full distribution
            QuantMode::Off,
            dir.clone(),
        )
        .unwrap();
        assert_eq!(outcome.totals.rows, 2_048, "{name}: row count");
        assert_eq!(outcome.store.num_blocks(), store.num_blocks(), "{name}: block count");
        for global in (0..2_048).step_by(111) {
            let (block, local) = (global / 256, global % 256);
            let rows = outcome.store.read_block(block).unwrap();
            let dense = dense_from_top_k(rows.row(local), 4);
            let s: f32 = dense.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "{name} record {global}: sums to {s}");
            for (i, (a, b)) in dense.iter().zip(oracle.row(global)).enumerate() {
                assert!(
                    (a - b).abs() < 1e-6,
                    "{name} record {global} center {i}: bulk {a} vs single-shot {b}"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn bulk_top_k_rows_are_the_descending_prefix_of_the_dense_row() {
    let (bundle, raw) = fixture(6_400, 1_024, 3, 4);
    let store = Arc::new(BlockStore::in_memory("raw", &raw, 128, 4).unwrap());
    let scaler = bundle.scaler.clone().unwrap();
    let mut normalized = raw.clone();
    scaler.apply(&mut normalized);
    let oracle = memberships(&normalized, &bundle.centers, 2.0);
    let dir = tmp_dir("topk");
    let mut engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
    let outcome = run_score_job(
        &mut engine,
        &store,
        Arc::new(bundle),
        Arc::new(NativeBackend),
        2,
        QuantMode::Off,
        dir.clone(),
    )
    .unwrap();
    assert_eq!(outcome.top_k, 2);
    assert_eq!(outcome.store.cols(), 4, "2 (center, membership) pairs per record");
    for global in (0..1_024).step_by(97) {
        let (block, local) = (global / 128, global % 128);
        let sparse = outcome.store.read_block(block).unwrap().row(local).to_vec();
        assert!(sparse[1] >= sparse[3], "record {global}: pairs not descending");
        // The kept entries are the two largest of the dense oracle row.
        let mut want: Vec<f32> = oracle.row(global).to_vec();
        want.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!((sparse[1] - want[0]).abs() < 1e-6, "record {global}: top-1 mismatch");
        assert!((sparse[3] - want[1]).abs() < 1e-6, "record {global}: top-2 mismatch");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The quantized candidate pre-pass: with C=8 centers and k=2, only the 4
/// approximately-nearest centers get exact math per record, yet the kept
/// top-k entries must stay close to the exact run — the skipped centers
/// only ever contribute far-tail membership mass.
#[test]
fn bulk_score_job_quant_candidates_match_exact_topk() {
    let (bundle, raw) = fixture(6_700, 1_024, 4, 8);
    let store = Arc::new(BlockStore::in_memory("raw", &raw, 128, 4).unwrap());
    let bundle = Arc::new(bundle);
    let exact_dir = tmp_dir("quant_exact");
    let mut exact_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
    let exact = run_score_job(
        &mut exact_engine,
        &store,
        Arc::clone(&bundle),
        Arc::new(NativeBackend),
        2,
        QuantMode::Off,
        exact_dir.clone(),
    )
    .unwrap();
    assert_eq!(exact.stats.records_pruned_quant, 0);
    assert_eq!(exact.stats.quant_sidecar_bytes, 0);
    let quant_dir = tmp_dir("quant_i8");
    let mut quant_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
    let quant = run_score_job(
        &mut quant_engine,
        &store,
        Arc::clone(&bundle),
        Arc::new(NativeBackend),
        2,
        QuantMode::I8,
        quant_dir.clone(),
    )
    .unwrap();
    assert_eq!(quant.stats.records_pruned_quant, 1_024, "every row goes through the pre-pass");
    assert!(quant.stats.quant_sidecar_bytes > 0);
    assert!(quant.stats.quant_build_s > 0.0);
    assert_eq!(quant.store.num_blocks(), exact.store.num_blocks());
    let mut top1_agree = 0usize;
    for b in 0..exact.store.num_blocks() {
        let (eb, qb) = (exact.store.read_block(b).unwrap(), quant.store.read_block(b).unwrap());
        for r in 0..eb.rows() {
            let (er, qr) = (eb.row(r), qb.row(r));
            top1_agree += (er[0] == qr[0]) as usize;
            // Kept memberships differ only by the quantized far-tail of
            // the denominator.
            assert!(
                (er[1] - qr[1]).abs() < 1e-2,
                "block {b} row {r}: top-1 membership {} vs exact {}",
                qr[1],
                er[1]
            );
        }
    }
    assert!(
        top1_agree as f64 >= 0.99 * 1_024.0,
        "quant candidate selection flipped too many top-1 centers ({top1_agree}/1024)"
    );
    std::fs::remove_dir_all(&exact_dir).ok();
    std::fs::remove_dir_all(&quant_dir).ok();
}

#[test]
fn bulk_score_job_survives_fault_injection_and_reopens() {
    let (bundle, raw) = fixture(6_500, 1_536, 4, 3);
    let store = Arc::new(BlockStore::in_memory("raw", &raw, 128, 4).unwrap());
    let bundle = Arc::new(bundle);
    let clean_dir = tmp_dir("clean");
    let mut clean_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
    let clean = run_score_job(
        &mut clean_engine,
        &store,
        Arc::clone(&bundle),
        Arc::new(NativeBackend),
        3,
        QuantMode::Off,
        clean_dir.clone(),
    )
    .unwrap();
    let faulty_dir = tmp_dir("faulty");
    let opts = EngineOptions { fault_rate: 0.4, fault_seed: 11, ..Default::default() };
    let mut faulty_engine = Engine::new(opts, OverheadConfig::default());
    let faulty = run_score_job(
        &mut faulty_engine,
        &store,
        Arc::clone(&bundle),
        Arc::new(NativeBackend),
        3,
        QuantMode::Off,
        faulty_dir.clone(),
    )
    .unwrap();
    assert!(faulty.stats.attempts > faulty.stats.map_tasks, "faults must have fired");
    assert_eq!(faulty.store.num_blocks(), clean.store.num_blocks());
    for b in 0..clean.store.num_blocks() {
        assert_eq!(
            faulty.store.read_block(b).unwrap(),
            clean.store.read_block(b).unwrap(),
            "block {b}: re-executed attempts corrupted the output store"
        );
    }
    // The labeled store is a first-class block store: reopenable from its
    // files alone and identical after the round trip.
    let reopened = BlockStore::open_disk("memberships", 4, faulty_dir.clone()).unwrap();
    assert_eq!(reopened.num_blocks(), clean.store.num_blocks());
    assert_eq!(reopened.read_block(0).unwrap(), clean.store.read_block(0).unwrap());
    std::fs::remove_dir_all(&clean_dir).ok();
    std::fs::remove_dir_all(&faulty_dir).ok();
}

/// A second bundle in the same feature space with visibly different
/// centers — the hot-reload payload.
fn shifted_bundle(base: &ModelBundle, raw: &Matrix) -> ModelBundle {
    let scaler = base.scaler.clone().unwrap();
    let mut normalized = raw.clone();
    scaler.apply(&mut normalized);
    let (c, d, n) = (base.centers.rows(), base.centers.cols(), normalized.rows());
    let mut centers = Matrix::zeros(c, d);
    for i in 0..c {
        centers.row_mut(i).copy_from_slice(normalized.row((i * (n / c) + 29) % n));
    }
    let mut b = base.clone();
    b.centers = centers;
    b
}

/// Acceptance: hot reload is observably atomic. Clients hammer the
/// service across a registry re-publish; every response must match the
/// oracle of exactly the generation it is stamped with — a torn read
/// (old scaler with new centers, or a half-swapped center matrix) would
/// match neither within 1e-6.
#[test]
fn registry_hot_reload_is_generation_consistent_under_load() {
    let (b1, raw) = fixture(7_000, 512, 4, 3);
    let b2 = shifted_bundle(&b1, &raw);
    let scaler = b1.scaler.clone().unwrap();
    let mut normalized = raw.clone();
    scaler.apply(&mut normalized);
    let oracle1 = memberships(&normalized, &b1.centers, 2.0);
    let oracle2 = memberships(&normalized, &b2.centers, 2.0);

    let reg = Arc::new(ModelRegistry::new(
        Arc::new(NativeBackend),
        ServeOptions { linger: Duration::from_micros(100), ..Default::default() },
    ));
    assert_eq!(reg.publish("m", b1).unwrap(), 1);
    let svc = reg.get("m").unwrap();
    let raw = Arc::new(raw);
    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..4)
        .map(|ci: usize| {
            let svc = Arc::clone(&svc);
            let raw = Arc::clone(&raw);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                let mut r = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let k = (ci * 101 + r * 7) % raw.rows();
                    let scored = svc.score_stamped(raw.row(k)).unwrap();
                    seen.push((k, scored.generation, scored.memberships));
                    r += 1;
                }
                seen
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    assert_eq!(reg.publish("m", b2).unwrap(), 2, "re-publish hot-reloads in place");
    assert_eq!(reg.reloads(), 1);
    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);

    let mut seen_by_gen = [0usize; 2];
    for h in handles {
        for (k, generation, u) in h.join().unwrap() {
            let s: f32 = u.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "gen {generation} row {k} sums to {s}");
            let oracle = match generation {
                1 => oracle1.row(k),
                2 => oracle2.row(k),
                g => panic!("impossible generation {g}"),
            };
            seen_by_gen[generation as usize - 1] += 1;
            for (i, (a, b)) in u.iter().zip(oracle).enumerate() {
                assert!(
                    (a - b).abs() < 1e-6,
                    "gen {generation} row {k} center {i}: {a} vs oracle {b} — torn reload?"
                );
            }
        }
    }
    assert!(
        seen_by_gen[0] > 0 && seen_by_gen[1] > 0,
        "load must span the swap (per-generation counts {seen_by_gen:?})"
    );
    // Requests admitted after the swap observe the new generation.
    assert_eq!(svc.score_stamped(raw.row(0)).unwrap().generation, 2);
}

/// Delegates kernel math to [`NativeBackend`] but parks the first
/// `score_chunk` call on a gate, pinning the batcher mid-execution so
/// queue residency (and therefore quota admission) is deterministic.
struct GatedBackend {
    entered: AtomicU64,
    release: AtomicBool,
}

impl KernelBackend for GatedBackend {
    fn exact_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> bigfcm::Result<Partials> {
        NativeBackend.exact_partials(kernel, x, v, w, m)
    }

    fn partials_with_bounds(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        rows: &mut BoundRows,
    ) -> bigfcm::Result<Partials> {
        NativeBackend.partials_with_bounds(kernel, x, v, w, m, rows)
    }

    fn name(&self) -> &'static str {
        "gated-native"
    }

    fn score_chunk(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        m: f64,
        u: &mut Matrix,
    ) -> bigfcm::Result<()> {
        if self.entered.fetch_add(1, Ordering::SeqCst) == 0 {
            let t0 = std::time::Instant::now();
            while !self.release.load(Ordering::SeqCst) {
                assert!(t0.elapsed() < Duration::from_secs(5), "gate never released");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        NativeBackend.score_chunk(kernel, x, v, m, u)
    }
}

/// Acceptance: per-tenant admission quotas reject deterministically and
/// the rejection is counted — while other tenants keep being admitted.
#[test]
fn tenant_quota_rejects_and_counts_at_admission() {
    let (bundle, raw) = fixture(7_200, 256, 4, 3);
    let backend =
        Arc::new(GatedBackend { entered: AtomicU64::new(0), release: AtomicBool::new(false) });
    let svc = Arc::new(
        ScoreService::builder(bundle)
            .max_batch(1)
            .linger(Duration::ZERO)
            .tenant_quota(2)
            .spawn(Arc::clone(&backend) as Arc<dyn KernelBackend>)
            .unwrap(),
    );
    let raw = Arc::new(raw);
    let noisy = |k: usize| {
        let svc = Arc::clone(&svc);
        let raw = Arc::clone(&raw);
        std::thread::spawn(move || svc.score_as(raw.row(k), "noisy", Lane::Normal))
    };
    let c1 = noisy(1);
    // Wait until the batcher is parked inside the gated kernel (request 1
    // claimed, queue empty again).
    let t0 = std::time::Instant::now();
    while backend.entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "batcher never reached the kernel");
        std::thread::sleep(Duration::from_millis(1));
    }
    let c2 = noisy(2);
    let c3 = noisy(3);
    // Wait until both are resident (queue peak counts admitted depth).
    let t0 = std::time::Instant::now();
    while svc.stats().queue_peak < 2 {
        assert!(t0.elapsed() < Duration::from_secs(5), "clients never became resident");
        std::thread::sleep(Duration::from_millis(1));
    }
    // Third resident same-tenant request: over quota, rejected up front.
    match svc.score_as(raw.row(4), "noisy", Lane::Normal) {
        Err(Error::QuotaExceeded(t)) => assert_eq!(t, "noisy"),
        Err(e) => panic!("expected QuotaExceeded, got {e}"),
        Ok(_) => panic!("expected QuotaExceeded, got a score"),
    }
    // A different tenant is unaffected by the noisy tenant's quota.
    let quiet = {
        let svc = Arc::clone(&svc);
        let raw = Arc::clone(&raw);
        std::thread::spawn(move || svc.score_as(raw.row(5), "quiet", Lane::High))
    };
    backend.release.store(true, Ordering::SeqCst);
    for h in [c1, c2, c3, quiet] {
        let scored = h.join().unwrap().unwrap();
        let s: f32 = scored.memberships.iter().sum();
        assert!((s - 1.0).abs() < 1e-5, "admitted request row sums to {s}");
    }
    let stats = svc.stats();
    assert_eq!(stats.quota_rejections, 1, "exactly the over-quota admission was rejected");
    assert_eq!(stats.requests, 4, "rejected requests never count as served");
}

/// Acceptance: the wire front isolates framing violations to their own
/// connection (process and sibling connections unaffected), and hot
/// reload works over the socket with generation-stamped replies.
#[test]
fn wire_front_isolates_framing_errors_and_reloads_over_socket() {
    let (b1, raw) = fixture(7_300, 256, 4, 3);
    let b2 = shifted_bundle(&b1, &raw);
    let reg = Arc::new(ModelRegistry::new(Arc::new(NativeBackend), ServeOptions::default()));
    reg.publish("m", b1).unwrap();
    let front = ServeFront::bind(
        Arc::clone(&reg),
        "127.0.0.1:0",
        FrontOptions::default(),
        OverheadConfig::default(),
    )
    .unwrap();
    let addr = front.local_addr().to_string();
    let timeout = Duration::from_secs(5);
    let csv: String =
        raw.row(3).iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",");

    // A healthy scoring round-trip, generation-stamped.
    let reply = client_call(&addr, &format!("score m tenant-a normal {csv}"), timeout).unwrap();
    assert!(reply.starts_with("ok 1 "), "unexpected score reply `{reply}`");

    // An application-level error answers `err ...` and keeps serving.
    let reply = client_call(&addr, "definitely-not-a-verb", timeout).unwrap();
    assert!(
        reply.starts_with("err ") && reply.contains("unknown command"),
        "got `{reply}`"
    );

    // A framing violation (absurd length prefix) kills only its own
    // connection.
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.flush().unwrap();
        let mut rest = Vec::new();
        let _ = s.read_to_end(&mut rest); // best-effort err frame, then close
    }
    let t0 = std::time::Instant::now();
    while front.stats().framing_errors < 1 {
        assert!(t0.elapsed() < Duration::from_secs(5), "framing error never counted");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(front.stats().framing_errors, 1);

    // Sibling connections keep working: hot-reload over the wire, then
    // score against the new generation.
    let dir = tmp_dir("wire_reload");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m2.bfm");
    b2.save(&path).unwrap();
    let reply =
        client_call(&addr, &format!("reload m {}", path.display()), timeout).unwrap();
    assert_eq!(reply, "ok 2", "reload reply `{reply}`");
    let reply = client_call(&addr, &format!("score m tenant-a high {csv}"), timeout).unwrap();
    assert!(reply.starts_with("ok 2 "), "post-reload reply `{reply}`");
    let memberships: Vec<f32> = reply
        .split(' ')
        .nth(2)
        .unwrap()
        .split(',')
        .map(|t| t.parse().unwrap())
        .collect();
    let s: f32 = memberships.iter().sum();
    assert!((s - 1.0).abs() < 1e-5, "wire memberships sum to {s}");

    let stats = front.stats();
    assert!(stats.scored >= 2);
    assert!(stats.modelled_net_s > 0.0, "wire bytes must charge the SimClock");
    front.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The scaler-guard satellite end-to-end: a constant feature column must
/// not poison serving (regression for the NaN-normalization hazard).
#[test]
fn constant_feature_columns_serve_finite_memberships() {
    let n = 300usize;
    let base = blobs(n, 3, 2, 0.3, 6_600);
    // Append a constant column to every record.
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut r = base.features.row(i).to_vec();
        r.push(7.5);
        rows.push(r);
    }
    let features = Matrix::from_rows(&rows);
    for fit in [Scaler::min_max, Scaler::z_score] {
        let scaler = fit(&features);
        let mut normalized = features.clone();
        scaler.apply(&mut normalized);
        assert!(normalized.as_slice().iter().all(|v| v.is_finite()));
        let mut centers = Matrix::zeros(2, 4);
        centers.row_mut(0).copy_from_slice(normalized.row(0));
        centers.row_mut(1).copy_from_slice(normalized.row(n / 2));
        let mut bundle = ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0);
        bundle.scaler = Some(scaler);
        let svc = ScoreService::builder(bundle).spawn(Arc::new(NativeBackend)).unwrap();
        for k in [1usize, 57, 299] {
            let u = svc.score(features.row(k)).unwrap();
            assert!(u.iter().all(|v| v.is_finite()), "row {k} carries non-finite memberships");
            let s: f32 = u.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {k} sums to {s}");
        }
    }
}
