//! Property-based tests over randomly generated cases (seeded,
//! deterministic). The offline build has no proptest, so each property runs
//! a seeded loop of random cases; failures print the case number + seed for
//! reproduction.

use std::sync::Arc;

use bigfcm::config::{Config, FlagPolicy};
use bigfcm::coordinator::BigFcm;
use bigfcm::data::synth::{blobs, gaussian_mixture, Component};
use bigfcm::data::Matrix;
use bigfcm::fcm::loops::{
    run_fcm, run_fcm_session, FcmParams, PruneConfig, SessionAlgo, Variant,
};
use bigfcm::fcm::native::{
    classic_partials_fused, classic_partials_native, classic_partials_scalar,
    fcm_partials_native, fcm_partials_scalar, kmeans_partials_native, kmeans_partials_scalar,
    memberships,
};
use bigfcm::fcm::{BlockBounds, BoundConfig, BoundModel, Kernel, QuantMode};
use bigfcm::fcm::seeding::random_records;
use bigfcm::fcm::{max_center_shift2, KernelBackend, NativeBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{Engine, EngineOptions, SessionOptions};
use bigfcm::metrics::hungarian_max;
use bigfcm::prng::Pcg;

const CASES: u64 = 30;

fn rand_matrix(rng: &mut Pcg, n: usize, d: usize, scale: f64) -> Matrix {
    let mut m = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.set(i, j, (rng.normal() * scale) as f32);
        }
    }
    m
}

fn rand_weights(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() + 0.01).collect()
}

/// Partials are associative under arbitrary splits: any partition of the
/// records merges to the full-pass result. This is THE combiner-correctness
/// property the MapReduce decomposition rests on.
#[test]
fn prop_partials_associative_under_random_splits() {
    for case in 0..CASES {
        let mut rng = Pcg::new(1000 + case);
        let n = 64 + rng.next_index(400);
        let d = 1 + rng.next_index(12);
        let c = 2 + rng.next_index(6);
        let m = [1.2, 1.7, 2.0, 3.0][rng.next_index(4)];
        let x = rand_matrix(&mut rng, n, d, 2.0);
        let v = rand_matrix(&mut rng, c, d, 2.0);
        let w = rand_weights(&mut rng, n);

        let full = fcm_partials_native(&x, &v, &w, m);
        // Random 3-way split.
        let cut1 = 1 + rng.next_index(n - 2);
        let cut2 = cut1 + 1 + rng.next_index(n - cut1 - 1);
        let mut merged = fcm_partials_native(&x.slice_rows(0, cut1), &v, &w[..cut1], m);
        merged.merge(&fcm_partials_native(
            &x.slice_rows(cut1, cut2),
            &v,
            &w[cut1..cut2],
            m,
        ));
        merged.merge(&fcm_partials_native(&x.slice_rows(cut2, n), &v, &w[cut2..], m));

        for (a, b) in merged.v_num.as_slice().iter().zip(full.v_num.as_slice()) {
            assert!(
                (a - b).abs() <= 1e-3 + 1e-4 * b.abs(),
                "case {case}: vnum {a} vs {b}"
            );
        }
        for (a, b) in merged.w_acc.iter().zip(&full.w_acc) {
            assert!((a - b).abs() <= 1e-6 + 1e-9 * b.abs(), "case {case}: wacc");
        }
    }
}

/// Memberships always form a probability distribution per record.
#[test]
fn prop_memberships_are_distributions() {
    for case in 0..CASES {
        let mut rng = Pcg::new(2000 + case);
        let n = 32 + rng.next_index(200);
        let d = 1 + rng.next_index(10);
        let c = 2 + rng.next_index(8);
        let m = [1.1, 1.5, 2.0, 4.0][rng.next_index(4)];
        let scale = [1e-3, 1.0, 1e3][rng.next_index(3)];
        let x = rand_matrix(&mut rng, n, d, scale);
        let v = rand_matrix(&mut rng, c, d, 1.0);
        let u = memberships(&x, &v, m);
        for i in 0..n {
            let mut s = 0.0f64;
            for j in 0..c {
                let val = u.get(i, j);
                assert!(val.is_finite() && val >= 0.0, "case {case}: u[{i},{j}]={val}");
                s += val as f64;
            }
            assert!((s - 1.0).abs() < 1e-4, "case {case}: row {i} sums to {s}");
        }
    }
}

/// The tiled f32-lane FCM kernel agrees with the scalar f64 reference on
/// awkward shapes: tail row-tiles (n not a multiple of the tile height),
/// d=1, C=1, C prime, and zero-weight padding suffixes — across the
/// fuzzifier regimes of the paper's experiments. Tolerances: 1e-3 absolute
/// on v_num; 1e-6 absolute + an f32-lane-rounding relative term on w_acc
/// and the objective (EXPERIMENTS.md §Perf documents the bound).
#[test]
fn prop_tiled_fcm_matches_scalar_reference() {
    for case in 0..CASES {
        let mut rng = Pcg::new(20_000 + case);
        let n = 1 + rng.next_index(300);
        let d = 1 + rng.next_index(12);
        let c = 1 + rng.next_index(9);
        let x = rand_matrix(&mut rng, n, d, 2.0);
        let v = rand_matrix(&mut rng, c, d, 2.0);
        let mut w = rand_weights(&mut rng, n);
        // Zero-weight padding rows (the runtime's tail-chunk contract).
        if n > 4 {
            for wk in w.iter_mut().skip(n - n / 4) {
                *wk = 0.0;
            }
        }
        for m in [1.2, 2.0, 2.8] {
            let a = fcm_partials_native(&x, &v, &w, m);
            let b = fcm_partials_scalar(&x, &v, &w, m);
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!(
                    (p - q).abs() <= 1e-3 + 1e-4 * q.abs(),
                    "case {case}: vnum {p} vs {q} (n={n} d={d} c={c} m={m})"
                );
            }
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!(
                    (p - q).abs() <= 1e-6 + 1e-4 * q.abs(),
                    "case {case}: wacc {p} vs {q} (n={n} d={d} c={c} m={m})"
                );
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "case {case}: objective rel {rel} (m={m})");
        }
    }
}

/// Same agreement for the classic (hoisted-powf) kernel against the
/// textbook per-pair-powf scalar reference.
#[test]
fn prop_tiled_classic_matches_scalar_reference() {
    for case in 0..CASES {
        let mut rng = Pcg::new(21_000 + case);
        let n = 1 + rng.next_index(200);
        let d = 1 + rng.next_index(10);
        let c = 1 + rng.next_index(7);
        let x = rand_matrix(&mut rng, n, d, 1.5);
        let v = rand_matrix(&mut rng, c, d, 1.5);
        let w = rand_weights(&mut rng, n);
        for m in [1.2, 2.0, 2.8] {
            let a = classic_partials_native(&x, &v, &w, m);
            let b = classic_partials_scalar(&x, &v, &w, m);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!(
                    (p - q).abs() <= 1e-6 + 1e-4 * q.abs(),
                    "case {case}: wacc {p} vs {q} (m={m})"
                );
            }
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "case {case}: vnum");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "case {case}: objective rel {rel} (m={m})");
        }
    }
}

/// Tiled K-Means preserves the assignment-insensitive aggregates exactly
/// (total mass) and the objective to f32-lane rounding. Per-cluster sums
/// are compared on separated data in `fcm::native::tests` — on arbitrary
/// random input a record can sit within f32 rounding of a bisector, where
/// tiled/scalar may legitimately disagree on the argmin.
#[test]
fn prop_tiled_kmeans_preserves_aggregates() {
    for case in 0..CASES {
        let mut rng = Pcg::new(22_000 + case);
        let n = 1 + rng.next_index(200);
        let d = 1 + rng.next_index(10);
        let c = 1 + rng.next_index(7);
        let x = rand_matrix(&mut rng, n, d, 2.0);
        let v = rand_matrix(&mut rng, c, d, 2.0);
        let w = rand_weights(&mut rng, n);
        let a = kmeans_partials_native(&x, &v, &w);
        let b = kmeans_partials_scalar(&x, &v, &w);
        let mass_a: f64 = a.w_acc.iter().sum();
        let mass_b: f64 = b.w_acc.iter().sum();
        assert!((mass_a - mass_b).abs() < 1e-9, "case {case}: mass {mass_a} vs {mass_b}");
        let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
        assert!(rel < 1e-4, "case {case}: objective rel {rel}");
    }
}

/// Streaming engine + small byte-budgeted block cache (with locality
/// scheduling and prefetch on) change nothing about the result: a pipeline
/// over an on-disk store with a budget far below the store size matches the
/// in-memory run bit-for-bit, while peak resident bytes stay within
/// `budget + workers × max_block_bytes`.
#[test]
fn prop_small_block_cache_preserves_results() {
    for case in 0..3u64 {
        let data = blobs(2048, 3, 3, 0.3, 30_000 + case);
        let mut cfg = Config::default();
        cfg.fcm.epsilon = 1e-9;
        cfg.cluster.block_records = 256;
        // Pin the flag: the FCM-vs-WFCMPB race is timing-dependent by design.
        cfg.fcm.flag_policy = FlagPolicy::ForceFcm;
        let dir = std::env::temp_dir()
            .join(format!("bigfcm_prop_cache_{}_{case}", std::process::id()));
        let disk =
            Arc::new(BlockStore::on_disk("t", &data.features, 256, 4, dir.clone()).unwrap());
        let mem = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
        let workers = 4u64;
        let block_bytes = disk.max_block_bytes();
        let budget = 2 * block_bytes; // room for 2 of the 8 blocks
        let mut engine = Engine::new(
            EngineOptions { workers: 4, block_cache_bytes: budget, ..Default::default() },
            cfg.overhead.clone(),
        );
        let a = BigFcm::new(cfg.clone())
            .clusters(3)
            .run_with_engine(&disk, &mut engine)
            .unwrap();
        let b = BigFcm::new(cfg).clusters(3).run_store(&mem).unwrap();
        assert_eq!(a.centers.as_slice(), b.centers.as_slice(), "case {case}");
        assert!(
            engine.block_cache().peak_resident_bytes() <= budget + workers * block_bytes,
            "case {case}: peak resident bytes {} > budget + workers × block",
            engine.block_cache().peak_resident_bytes()
        );
        std::fs::remove_dir_all(dir).ok();
    }
}

/// Byte-budgeted eviction never exceeds the budget plus one in-flight block
/// per reader, under concurrent random access with skewed block sizes —
/// the residency envelope the scale harness relies on, hammered directly
/// at the cache layer.
#[test]
fn prop_byte_budget_bounds_residency_under_concurrency() {
    for case in 0..5u64 {
        let mut rng = Pcg::new(40_000 + case);
        // Skewed blocks: a small block_records over a row count chosen so
        // the tail block is short.
        let n = 600 + rng.next_index(900);
        let d = 2 + rng.next_index(6);
        let block_records = 64 + rng.next_index(128);
        let data = blobs(n, d, 2, 0.4, 41_000 + case);
        let store =
            Arc::new(BlockStore::in_memory("t", &data.features, block_records, 4).unwrap());
        let max_block = store.max_block_bytes();
        let readers = 2 + rng.next_index(4); // 2..=5 concurrent readers
        let budget = (1 + rng.next_index(4)) as u64 * max_block;
        let cache = Arc::new(bigfcm::mapreduce::BlockCache::with_budget_bytes(budget));

        let handles: Vec<_> = (0..readers)
            .map(|r| {
                let cache = Arc::clone(&cache);
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    let mut rng = Pcg::new(42_000 + case * 100 + r as u64);
                    for _ in 0..200 {
                        let id = rng.next_index(store.num_blocks());
                        let block = cache.get_or_read(&store, id).unwrap();
                        // Touch the data so the block stays in flight.
                        std::hint::black_box(block.data().get(0, 0));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }

        let envelope = budget + readers as u64 * max_block;
        assert!(
            cache.peak_resident_bytes() <= envelope,
            "case {case}: peak {} > budget {budget} + {readers} readers × {max_block}",
            cache.peak_resident_bytes()
        );
        assert!(cache.cached_bytes() <= budget, "case {case}");
        // The meters agree with a fresh drain: clearing with no holders
        // returns residency to zero (the `clear()` per-job-peak contract).
        cache.clear();
        assert_eq!(cache.resident_bytes(), 0, "case {case}");
        assert_eq!(cache.peak_resident_bytes(), 0, "case {case}");
    }
}

/// A pruned iteration-resident session converges to the same centers as
/// the exact (pruning-disabled) path, within epsilon-scale drift — for
/// both the Fast and Classic chunk-math variants, on seeded synth blobs.
/// The pruned run must actually prune (tail iterations have tiny shifts),
/// and convergence is only ever accepted from an exact pass.
#[test]
fn prop_pruned_session_converges_to_exact_centers() {
    for case in 0..4u64 {
        for variant in [Variant::Fast, Variant::Classic] {
            let data = blobs(1536, 3, 3, 0.25, 50_000 + case);
            let store =
                Arc::new(BlockStore::in_memory("t", &data.features, 192, 4).unwrap());
            let mut rng = Pcg::new(51_000 + case);
            let v0 = random_records(&data.features, 3, &mut rng);
            let params = FcmParams { epsilon: 1e-10, variant, ..Default::default() };
            let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
            let mut e1 = Engine::new(EngineOptions::default(), Config::default().overhead);
            let exact = run_fcm_session(
                &mut e1,
                &store,
                Arc::clone(&backend),
                SessionAlgo::Fcm,
                v0.clone(),
                &params,
                &PruneConfig::disabled(),
                SessionOptions::default(),
                None,
            )
            .unwrap();
            let mut e2 = Engine::new(EngineOptions::default(), Config::default().overhead);
            let pruned = run_fcm_session(
                &mut e2,
                &store,
                Arc::clone(&backend),
                SessionAlgo::Fcm,
                v0,
                &params,
                &PruneConfig::default(),
                SessionOptions::default(),
                None,
            )
            .unwrap();
            assert!(exact.result.converged, "case {case} {variant:?}: exact arm stalled");
            assert!(pruned.result.converged, "case {case} {variant:?}: pruned arm stalled");
            assert!(
                pruned.records_pruned > 0,
                "case {case} {variant:?}: session never pruned over {} iterations",
                pruned.jobs
            );
            let shift = max_center_shift2(&exact.result.centers, &pruned.result.centers);
            assert!(shift < 1e-3, "case {case} {variant:?}: pruned drift {shift}");
        }
    }
}

/// Engine-level tree combine is a drop-in for the flat reduce even on
/// non-commutative-looking `CombinerOut` orderings: the full BigFCM
/// pipeline (whose combiner output pools weighted centers — order visibly
/// matters to the reduce's WFCM input) must produce bit-identical centers
/// with the combine tree on and off, because ordered pool concatenation
/// over the fixed merge topology reproduces block order exactly.
#[test]
fn prop_tree_combine_is_drop_in_for_flat_reduce() {
    for case in 0..4u64 {
        for reducers in [1usize, 4] {
            let data = blobs(2048, 3, 3, 0.3, 60_000 + case);
            let store =
                Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
            let mut cfg = Config::default();
            cfg.fcm.epsilon = 1e-9;
            cfg.fcm.flag_policy = FlagPolicy::ForceFcm;
            cfg.cluster.reducers = reducers;
            cfg.cluster.tree_combine = true;
            let tree = BigFcm::new(cfg.clone()).clusters(3).run_store(&store).unwrap();
            cfg.cluster.tree_combine = false;
            let flat = BigFcm::new(cfg).clusters(3).run_store(&store).unwrap();
            assert_eq!(
                tree.centers.as_slice(),
                flat.centers.as_slice(),
                "case {case} reducers {reducers}: tree combine changed the pipeline result"
            );
            assert_eq!(flat.job.combine_depth, 0, "case {case}: flat path not taken");
            if reducers == 1 {
                assert!(tree.job.combine_depth > 0, "case {case}: tree path not taken");
                assert!(
                    tree.job.reduce_parts < flat.job.reduce_parts,
                    "case {case}: tree reduce saw {} parts vs flat {}",
                    tree.job.reduce_parts,
                    flat.job.reduce_parts
                );
            } else {
                // The multi-reducer two-level WFCM is keyed on the part
                // count; CombineJob stands its combiner down so that path
                // behaves exactly as before.
                assert_eq!(
                    tree.job.combine_depth, 0,
                    "case {case}: tree combine must stand down for reducers > 1"
                );
            }
        }
    }
}

/// Sharding the engine is a drop-in for the single-engine pipeline: the
/// exact two-level merge computes every node of the single engine's f32
/// combine DAG exactly once at its global leaf slot, so the final centers
/// must be bit-identical at every shard count — including the flat
/// multi-reducer path (reducers > 1, combiner stood down), where segments
/// are per-block and the driver-side fold reproduces block order exactly.
#[test]
fn prop_sharded_exact_merge_is_drop_in_for_single_engine() {
    for case in 0..2u64 {
        for reducers in [1usize, 4] {
            let data = blobs(2048, 3, 3, 0.3, 80_000 + case);
            let store =
                Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
            let mut cfg = Config::default();
            cfg.fcm.epsilon = 1e-9;
            cfg.fcm.flag_policy = FlagPolicy::ForceFcm;
            cfg.cluster.reducers = reducers;
            cfg.cluster.tree_combine = true;
            let mut baseline = None;
            for shards in [1usize, 2, 4] {
                cfg.cluster.shards = shards;
                let run = BigFcm::new(cfg.clone()).clusters(3).run_store(&store).unwrap();
                if shards == 1 {
                    assert!(
                        run.per_shard.is_empty(),
                        "case {case} reducers {reducers}: single-engine run grew shard rows"
                    );
                    baseline = Some(run);
                    continue;
                }
                let base = baseline.as_ref().unwrap();
                assert_eq!(
                    run.centers.as_slice(),
                    base.centers.as_slice(),
                    "case {case} reducers {reducers} shards {shards}: sharded pipeline diverged"
                );
                assert_eq!(
                    run.per_shard.len(),
                    shards,
                    "case {case} reducers {reducers} shards {shards}: missing shard stats"
                );
                // Every block maps on exactly one shard.
                let shard_tasks: usize = run.per_shard.iter().map(|s| s.map_tasks).sum();
                assert_eq!(
                    shard_tasks, base.job.map_tasks,
                    "case {case} reducers {reducers} shards {shards}: map tasks lost or doubled"
                );
                // Startup is charged once per shard; the merged modelled
                // wall takes the critical shard, so it can only shrink or
                // hold as map compute spreads (modulo the extra startups).
                assert!(
                    run.job.sim.job_startup_s > base.job.sim.job_startup_s,
                    "case {case} shards {shards}: per-shard startup not charged"
                );
            }
        }
    }
}

/// Adaptive prefetch depth never grows the residency envelope: with a
/// budget roomy enough to trigger depth-2 prefetches (≥ 2 max-blocks of
/// slack throughout), peak resident bytes still stay within
/// `budget + workers × max_block_bytes`, and results are unchanged.
#[test]
fn prop_adaptive_prefetch_depth_keeps_residency_envelope() {
    for case in 0..3u64 {
        let data = blobs(2048, 4, 2, 0.4, 70_000 + case);
        let dir = std::env::temp_dir()
            .join(format!("bigfcm_prop_prefetch_{}_{case}", std::process::id()));
        let disk =
            Arc::new(BlockStore::on_disk("t", &data.features, 128, 4, dir.clone()).unwrap());
        let workers = 4u64;
        let block_bytes = disk.max_block_bytes();
        // 8 of 16 blocks fit: plenty of slack early (deep prefetch fires),
        // saturated later (depth falls back to 1).
        let budget = 8 * block_bytes;
        let mut cfg = Config::default();
        cfg.fcm.epsilon = 1e-6;
        cfg.fcm.flag_policy = FlagPolicy::ForceFcm;
        let mut engine = Engine::new(
            EngineOptions { workers: 4, block_cache_bytes: budget, ..Default::default() },
            cfg.overhead.clone(),
        );
        let run = BigFcm::new(cfg)
            .clusters(2)
            .run_with_engine(&disk, &mut engine)
            .unwrap();
        assert!(run.centers.as_slice().iter().all(|v| v.is_finite()));
        let bc = engine.block_cache();
        assert!(
            bc.peak_resident_bytes() <= budget + workers * block_bytes,
            "case {case}: deep prefetch broke the envelope ({} > {budget} + {workers}×{block_bytes})",
            bc.peak_resident_bytes()
        );
        assert!(bc.cached_bytes() <= budget, "case {case}");
        std::fs::remove_dir_all(dir).ok();
    }
}

/// Fast (Kolen–Hutcheson) and classic formulations agree on random input.
#[test]
fn prop_fast_equals_classic() {
    for case in 0..CASES {
        let mut rng = Pcg::new(3000 + case);
        let n = 32 + rng.next_index(128);
        let d = 1 + rng.next_index(8);
        let c = 2 + rng.next_index(5);
        let m = [1.3, 2.0, 2.5][rng.next_index(3)];
        let x = rand_matrix(&mut rng, n, d, 1.5);
        let v = rand_matrix(&mut rng, c, d, 1.5);
        let w = rand_weights(&mut rng, n);
        let a = fcm_partials_native(&x, &v, &w, m);
        let b = classic_partials_native(&x, &v, &w, m);
        for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
            assert!((p - q).abs() <= 1e-5 + 1e-6 * q.abs(), "case {case}: {p} vs {q}");
        }
    }
}

/// The FCM objective is non-increasing along iterations from any start.
#[test]
fn prop_objective_monotone() {
    for case in 0..15 {
        let mut rng = Pcg::new(4000 + case);
        let k = 2 + rng.next_index(3);
        let data = blobs(300 + rng.next_index(300), 2 + rng.next_index(4), k, 0.5, 5000 + case);
        let v0 = random_records(&data.features, k, &mut rng);
        let w = vec![1.0f32; data.features.rows()];
        let mut v = v0;
        let mut last = f64::INFINITY;
        for _ in 0..12 {
            let p = fcm_partials_native(&data.features, &v, &w, 2.0);
            assert!(
                p.objective <= last * (1.0 + 1e-6),
                "case {case}: objective rose {} -> {}",
                last,
                p.objective
            );
            last = p.objective;
            v = p.into_centers(&v);
        }
    }
}

/// Cluster relabeling invariance: permuting seed order cannot change the
/// *set* of final centers the pipeline produces.
#[test]
fn prop_center_set_invariant_to_seed_permutation() {
    for case in 0..10 {
        let mut rng = Pcg::new(6000 + case);
        let k = 2 + rng.next_index(3);
        let data = blobs(600, 3, k, 0.3, 7000 + case);
        let v0 = random_records(&data.features, k, &mut rng);
        // Permute rows of v0.
        let mut perm: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut perm);
        let v0_perm = v0.select_rows(&perm);
        let w = vec![1.0f32; 600];
        let params = FcmParams { epsilon: 1e-12, ..Default::default() };
        let a = run_fcm(&NativeBackend, &data.features, &w, v0, &params).unwrap();
        let b = run_fcm(&NativeBackend, &data.features, &w, v0_perm, &params).unwrap();
        for i in 0..k {
            let best = (0..k)
                .map(|j| bigfcm::data::matrix::dist2(a.centers.row(i), b.centers.row(j)))
                .fold(f64::INFINITY, f64::min);
            assert!(best < 1e-6, "case {case}: center sets differ ({best})");
        }
    }
}

/// Pipeline invariance to block size: the number of HDFS blocks must not
/// change what clustering the pipeline finds (only how it is scheduled).
#[test]
fn prop_block_size_does_not_change_clustering() {
    for case in 0..6 {
        let data = blobs(2048, 3, 3, 0.3, 8000 + case);
        let mut cfg = Config::default();
        cfg.fcm.epsilon = 1e-9;
        let mut results = Vec::new();
        for block in [256usize, 512, 2048] {
            cfg.cluster.block_records = block;
            let store = Arc::new(BlockStore::in_memory("t", &data.features, block, 4).unwrap());
            let run = BigFcm::new(cfg.clone()).clusters(3).run_store(&store).unwrap();
            results.push(run.centers);
        }
        for other in &results[1..] {
            for i in 0..3 {
                let best = (0..3)
                    .map(|j| bigfcm::data::matrix::dist2(results[0].row(i), other.row(j)))
                    .fold(f64::INFINITY, f64::min);
                assert!(best < 0.05, "case {case}: block size changed clustering ({best})");
            }
        }
    }
}

/// Weighted runs are equivalent to record duplication: weight k on a record
/// ≈ k copies of it (the WFCM soundness argument, Hore et al.).
#[test]
fn prop_weight_equals_duplication() {
    for case in 0..CASES {
        let mut rng = Pcg::new(9000 + case);
        let n = 16 + rng.next_index(64);
        let d = 1 + rng.next_index(6);
        let c = 2 + rng.next_index(3);
        let x = rand_matrix(&mut rng, n, d, 2.0);
        let v = rand_matrix(&mut rng, c, d, 2.0);
        // Duplicate record 0 three times vs weight 3.
        let mut w = vec![1.0f32; n];
        w[0] = 3.0;
        let weighted = fcm_partials_native(&x, &v, &w, 2.0);

        let mut x_dup = Matrix::zeros(0, d);
        for _ in 0..3 {
            x_dup.push_row(x.row(0));
        }
        for i in 1..n {
            x_dup.push_row(x.row(i));
        }
        let dup = fcm_partials_native(&x_dup, &v, &vec![1.0f32; n + 2], 2.0);
        for (a, b) in weighted.v_num.as_slice().iter().zip(dup.v_num.as_slice()) {
            assert!((a - b).abs() <= 1e-3 + 1e-5 * b.abs(), "case {case}: {a} vs {b}");
        }
    }
}

/// Hungarian assignment really is optimal: verify against brute force on
/// small random matrices.
#[test]
fn prop_hungarian_optimal_vs_bruteforce() {
    fn permutations(n: usize) -> Vec<Vec<usize>> {
        if n == 1 {
            return vec![vec![0]];
        }
        let mut out = Vec::new();
        for p in permutations(n - 1) {
            for i in 0..n {
                let mut q = p.clone();
                q.insert(i, n - 1);
                out.push(q);
            }
        }
        out
    }
    for case in 0..CASES {
        let mut rng = Pcg::new(10_000 + case);
        let n = 2 + rng.next_index(4); // up to 5x5
        let w: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.next_below(100)).collect())
            .collect();
        let assignment = hungarian_max(&w);
        let got: u64 = assignment.iter().enumerate().map(|(i, &j)| w[i][j]).sum();
        let best = permutations(n)
            .into_iter()
            .map(|p| p.iter().enumerate().map(|(i, &j)| w[i][j]).sum::<u64>())
            .max()
            .unwrap();
        assert_eq!(got, best, "case {case}: hungarian {got} vs brute force {best}");
    }
}

/// Variant equivalence survives the full loop on mixtures of any imbalance.
#[test]
fn prop_variants_converge_same_on_imbalanced_mixtures() {
    for case in 0..8 {
        let mut rng = Pcg::new(11_000 + case);
        let d = 2 + rng.next_index(4);
        let comps = vec![
            Component {
                mean: (0..d).map(|_| rng.normal() * 3.0).collect(),
                std: vec![0.4; d],
                weight: 0.85,
                label: 0,
            },
            Component {
                mean: (0..d).map(|_| rng.normal() * 3.0).collect(),
                std: vec![0.4; d],
                weight: 0.15,
                label: 1,
            },
        ];
        let data = gaussian_mixture(800, &comps, 12_000 + case, "imb");
        let v0 = random_records(&data.features, 2, &mut rng);
        let w = vec![1.0f32; 800];
        let fast = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0.clone(),
            &FcmParams { epsilon: 1e-12, variant: Variant::Fast, ..Default::default() },
        )
        .unwrap();
        let classic = run_fcm(
            &NativeBackend,
            &data.features,
            &w,
            v0,
            &FcmParams { epsilon: 1e-12, variant: Variant::Classic, ..Default::default() },
        )
        .unwrap();
        let shift = max_center_shift2(&fast.centers, &classic.centers);
        assert!(shift < 1e-3, "case {case}: variants diverged {shift}");
    }
}

/// Backend object safety: the pipeline accepts Arc<dyn KernelBackend> of any
/// implementation and produces finite results.
#[test]
fn prop_pipeline_finite_for_random_configs() {
    for case in 0..8 {
        let mut rng = Pcg::new(13_000 + case);
        let c = 2 + rng.next_index(4);
        let data = blobs(1024, 2 + rng.next_index(6), c, 0.2 + rng.next_f64() * 0.5, 14_000 + case);
        let mut cfg = Config::default();
        cfg.cluster.block_records = 128 << rng.next_index(3);
        cfg.cluster.workers = 1 + rng.next_index(6);
        cfg.fcm.fuzzifier = [1.2, 2.0, 2.8][rng.next_index(3)];
        cfg.fcm.epsilon = [5e-3, 5e-7, 5e-11][rng.next_index(3)];
        cfg.seed = rng.next_u64();
        let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
        let run = BigFcm::new(cfg)
            .backend(backend)
            .clusters(c)
            .run_in_memory(&data.features)
            .unwrap();
        assert!(run.centers.as_slice().iter().all(|v| v.is_finite()), "case {case}");
        assert!(run.weights.iter().all(|w| w.is_finite() && *w >= 0.0), "case {case}");
        assert_eq!(run.centers.rows(), c);
    }
}

/// The fused (pair-loop-free) classic kernel agrees with the textbook
/// per-pair-powf scalar oracle — the oracle contract of the ROADMAP's
/// "skip the O(C²) pair loop" follow-up, across the fuzzifier regimes.
#[test]
fn prop_fused_classic_matches_pair_oracle() {
    for case in 0..CASES {
        let mut rng = Pcg::new(23_000 + case);
        let n = 1 + rng.next_index(200);
        let d = 1 + rng.next_index(10);
        let c = 1 + rng.next_index(7);
        let x = rand_matrix(&mut rng, n, d, 1.5);
        let v = rand_matrix(&mut rng, c, d, 1.5);
        let w = rand_weights(&mut rng, n);
        for m in [1.2, 2.0, 2.8] {
            let a = classic_partials_fused(&x, &v, &w, m);
            let b = classic_partials_scalar(&x, &v, &w, m);
            for (p, q) in a.w_acc.iter().zip(&b.w_acc) {
                assert!(
                    (p - q).abs() <= 1e-6 + 1e-4 * q.abs(),
                    "case {case}: wacc {p} vs {q} (m={m})"
                );
            }
            for (p, q) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
                assert!((p - q).abs() <= 1e-3 + 1e-4 * q.abs(), "case {case}: vnum");
            }
            let rel = (a.objective - b.objective).abs() / b.objective.max(1e-9);
            assert!(rel < 1e-4, "case {case}: objective rel {rel} (m={m})");
        }
    }
}

/// Bound-model equivalence: over a sequence of small center shifts, the
/// dmin- and elkan-pruned partials both stay within the perturbation
/// tolerance of the exact pass — for the Fast and (fused) Classic kernels
/// at m = 2 and m ≠ 2 — and the per-center elkan bound prunes at least as
/// many records as the single-d_min bound on the pass right after a
/// common refresh (where domination is exact: δ_j ≤ δ_max and
/// lb_j ≥ d_min), and in total.
#[test]
fn prop_elkan_vs_dmin_vs_exact_partials_equivalence() {
    for case in 0..4u64 {
        for kernel in [Kernel::FcmFast, Kernel::FcmClassic] {
            for m in [2.0, 1.7] {
                let data = blobs(400, 3, 3, 0.2, 80_000 + case);
                let x = &data.features;
                let w = vec![1.0f32; 400];
                // Settle centers first so records hold comfortable bounds,
                // then drift them in small steps (the mid/late-shift
                // regime pruning targets).
                let mut rng = Pcg::new(81_000 + case);
                let v0 = random_records(x, 3, &mut rng);
                let params = FcmParams { epsilon: 1e-8, m, ..Default::default() };
                let settled = run_fcm(&NativeBackend, x, &w, v0, &params).unwrap().centers;
                let tol = 1e-2;
                let cfg = |model| BoundConfig {
                    model,
                    tolerance: tol,
                    refresh_every: 16,
                    quant: QuantMode::Off,
                };
                let mut st_dmin = BlockBounds::default();
                let mut st_elkan = BlockBounds::default();
                let (mut dmin_first, mut elkan_first) = (0usize, 0usize);
                let (mut dmin_total, mut elkan_total) = (0usize, 0usize);
                let mut v = settled.clone();
                for t in 0..6 {
                    let (pd, nd) = NativeBackend
                        .pruned_partials(kernel, x, &v, &w, m, &mut st_dmin, &cfg(BoundModel::DMin))
                        .unwrap();
                    let nd = nd.pruned;
                    let (pe, ne) = NativeBackend
                        .pruned_partials(kernel, x, &v, &w, m, &mut st_elkan, &cfg(BoundModel::Elkan))
                        .unwrap();
                    let ne = ne.pruned;
                    let exact = NativeBackend.exact_partials(kernel, x, &v, &w, m).unwrap();
                    for arm in [&pd, &pe] {
                        for (a, b) in arm.w_acc.iter().zip(&exact.w_acc) {
                            let rel = (a - b).abs() / b.abs().max(1e-9);
                            assert!(
                                rel < 10.0 * tol,
                                "case {case} {kernel:?} m={m} t={t}: w_acc drift {rel}"
                            );
                        }
                        let rel =
                            (arm.objective - exact.objective).abs() / exact.objective.max(1e-9);
                        assert!(
                            rel < 10.0 * tol,
                            "case {case} {kernel:?} m={m} t={t}: objective drift {rel}"
                        );
                    }
                    if t == 1 {
                        dmin_first = nd;
                        elkan_first = ne;
                    }
                    dmin_total += nd;
                    elkan_total += ne;
                    // The mid-shift regime: one center keeps drifting while
                    // the others are all but settled. The single-d_min
                    // bound pays the worst center's shift everywhere; the
                    // per-center bound only charges center 0's drift
                    // against records actually near center 0.
                    for val in v.row_mut(0).iter_mut() {
                        *val += 4e-4;
                    }
                    for j in 1..3 {
                        for val in v.row_mut(j).iter_mut() {
                            *val += 2e-5;
                        }
                    }
                }
                assert!(
                    dmin_first > 0,
                    "case {case} {kernel:?} m={m}: dmin never pruned after refresh"
                );
                assert!(
                    elkan_first >= dmin_first,
                    "case {case} {kernel:?} m={m}: elkan ({elkan_first}) under dmin ({dmin_first}) right after refresh"
                );
                assert!(
                    elkan_total >= dmin_total,
                    "case {case} {kernel:?} m={m}: elkan total {elkan_total} under dmin {dmin_total}"
                );
            }
        }
    }
}

/// Hamerly bound model (single fast bound over the elkan lower bounds):
/// over a drifting-center sequence the pruned partials stay within the
/// perturbation tolerance of the exact pass — Fast and fused Classic
/// kernels, m = 2 and m ≠ 2 — and because the fast test falls back to the
/// per-center elkan test, the hamerly-pruned set contains elkan's on
/// every pass.
#[test]
fn prop_hamerly_matches_exact_and_contains_elkan() {
    for case in 0..4u64 {
        for kernel in [Kernel::FcmFast, Kernel::FcmClassic] {
            for m in [2.0, 1.7] {
                let data = blobs(400, 3, 3, 0.2, 85_000 + case);
                let x = &data.features;
                let w = vec![1.0f32; 400];
                let mut rng = Pcg::new(86_000 + case);
                let v0 = random_records(x, 3, &mut rng);
                let params = FcmParams { epsilon: 1e-8, m, ..Default::default() };
                let settled = run_fcm(&NativeBackend, x, &w, v0, &params).unwrap().centers;
                let tol = 1e-2;
                let cfg = |model| BoundConfig {
                    model,
                    tolerance: tol,
                    refresh_every: 16,
                    quant: QuantMode::Off,
                };
                let mut st_elkan = BlockBounds::default();
                let mut st_ham = BlockBounds::default();
                let (mut elkan_total, mut ham_total) = (0usize, 0usize);
                let mut v = settled.clone();
                for t in 0..6 {
                    let (_, ne) = NativeBackend
                        .pruned_partials(kernel, x, &v, &w, m, &mut st_elkan, &cfg(BoundModel::Elkan))
                        .unwrap();
                    let ne = ne.pruned;
                    let (ph, nh) = NativeBackend
                        .pruned_partials(
                            kernel,
                            x,
                            &v,
                            &w,
                            m,
                            &mut st_ham,
                            &cfg(BoundModel::Hamerly),
                        )
                        .unwrap();
                    let nh = nh.pruned;
                    assert!(
                        nh >= ne,
                        "case {case} {kernel:?} m={m} t={t}: hamerly ({nh}) under elkan ({ne})"
                    );
                    elkan_total += ne;
                    ham_total += nh;
                    let exact = NativeBackend.exact_partials(kernel, x, &v, &w, m).unwrap();
                    for (a, b) in ph.w_acc.iter().zip(&exact.w_acc) {
                        let rel = (a - b).abs() / b.abs().max(1e-9);
                        assert!(
                            rel < 10.0 * tol,
                            "case {case} {kernel:?} m={m} t={t}: w_acc drift {rel}"
                        );
                    }
                    let rel =
                        (ph.objective - exact.objective).abs() / exact.objective.max(1e-9);
                    assert!(
                        rel < 10.0 * tol,
                        "case {case} {kernel:?} m={m} t={t}: objective drift {rel}"
                    );
                    // One center drifts, the rest barely move (the regime
                    // the per-center fallback exists for).
                    for val in v.row_mut(0).iter_mut() {
                        *val += 4e-4;
                    }
                    for j in 1..3 {
                        for val in v.row_mut(j).iter_mut() {
                            *val += 2e-5;
                        }
                    }
                }
                assert!(
                    ham_total >= elkan_total,
                    "case {case} {kernel:?} m={m}: hamerly total {ham_total} under elkan {elkan_total}"
                );
                assert!(ham_total > 0, "case {case} {kernel:?} m={m}: hamerly never pruned");
            }
        }
    }
}

/// The slab spill codec is bitwise under random shapes and both bound
/// models: a spilled-and-reloaded state re-serialises to the identical
/// image and drives the next pruned pass to identical partials and
/// pruning decisions.
#[test]
fn prop_spill_roundtrip_preserves_pruning_bitwise() {
    use bigfcm::mapreduce::SlabState;
    for case in 0..8u64 {
        let mut rng = Pcg::new(90_000 + case);
        let n = 32 + rng.next_index(200);
        let d = 1 + rng.next_index(8);
        let c = 2 + rng.next_index(5);
        let kernel = [Kernel::FcmFast, Kernel::FcmClassic, Kernel::KMeans][rng.next_index(3)];
        let model =
            [BoundModel::DMin, BoundModel::Elkan, BoundModel::Hamerly][rng.next_index(3)];
        let x = rand_matrix(&mut rng, n, d, 2.0);
        let mut v = rand_matrix(&mut rng, c, d, 2.0);
        let w = rand_weights(&mut rng, n);
        let quant = if rng.next_index(2) == 0 { QuantMode::Off } else { QuantMode::I8 };
        let cfg = BoundConfig { model, tolerance: 1e-2, refresh_every: 8, quant };
        let mut state = BlockBounds::default();
        for _ in 0..2 {
            NativeBackend.pruned_partials(kernel, &x, &v, &w, 2.0, &mut state, &cfg).unwrap();
            for val in v.as_mut_slice().iter_mut() {
                *val += 1e-4;
            }
        }
        let img = state.spill().expect("case {case}: bounds must be spillable");
        let mut restored = BlockBounds::unspill(&img)
            .unwrap_or_else(|| panic!("case {case}: image failed to decode"));
        assert_eq!(img, restored.spill().unwrap(), "case {case}: re-spill differs");
        // The quant sidecar travels in the image: same byte charge back,
        // and a non-zero one whenever the pass ran quantized.
        assert_eq!(
            state.quant_sidecar_bytes(),
            restored.quant_sidecar_bytes(),
            "case {case}: sidecar bytes diverged across the spill"
        );
        if quant.enabled() {
            assert!(
                restored.quant_sidecar_bytes() > 0,
                "case {case}: quantized state reloaded without its sidecar"
            );
        }
        let (pa, na) =
            NativeBackend.pruned_partials(kernel, &x, &v, &w, 2.0, &mut state, &cfg).unwrap();
        let (pb, nb) = NativeBackend
            .pruned_partials(kernel, &x, &v, &w, 2.0, &mut restored, &cfg)
            .unwrap();
        assert_eq!(na, nb, "case {case}: pruning decisions diverged after reload");
        assert_eq!(pa.w_acc, pb.w_acc, "case {case}");
        assert_eq!(pa.v_num.as_slice(), pb.v_num.as_slice(), "case {case}");
        assert_eq!(pa.objective, pb.objective, "case {case}");
    }
}

/// The quant certificate is a true error bound: over random record and
/// center shapes, signs and magnitudes — including centers drawn wider
/// than the block's coded range, where the i16 center codes clamp — the
/// certified radius brackets the exact squared distance for every
/// (record, center) pair: `|d̃² − d²| ≤ err`.
#[test]
fn prop_quant_certificate_is_true_upper_bound() {
    use bigfcm::fcm::QuantSidecar;
    for case in 0..CASES {
        let mut rng = Pcg::new(95_000 + case);
        let n = 16 + rng.next_index(120);
        let d = 1 + rng.next_index(12);
        let c = 1 + rng.next_index(6);
        let scale = [0.5, 2.0, 40.0][rng.next_index(3)];
        let x = rand_matrix(&mut rng, n, d, scale);
        // 1.5× wider than the records: some center coordinates land
        // outside the sidecar's per-column range, exercising the clamped
        // residual path of the certificate.
        let v = rand_matrix(&mut rng, c, d, scale * 1.5);
        let sidecar = QuantSidecar::build(&x);
        let qc = sidecar.prep_centers(&v);
        let mut d2 = vec![0.0f64; c];
        let mut err = vec![0.0f64; c];
        for k in 0..n {
            sidecar.row_distances(k, &qc, &mut d2, &mut err);
            for j in 0..c {
                let exact = x.row_dist2(k, v.row(j));
                assert!(
                    (d2[j] - exact).abs() <= err[j],
                    "case {case} k={k} j={j}: |{} - {exact}| = {} > err {}",
                    d2[j],
                    (d2[j] - exact).abs(),
                    err[j]
                );
            }
        }
    }
}

/// The quant second chance preserves the session twin's accuracy
/// envelope where the shift bound structurally cannot: on a
/// wander-and-return center schedule the memoryful δ accumulates path
/// length (it overcharges trajectories that come back), eventually
/// abandoning every record's own-center bound, while the memoryless
/// certified i8 interval re-certifies them against the refresh-time
/// bounds. The pass then stays fully pruned and its replayed partials
/// match the exact pass within 1e-6 on every return-to-refresh step —
/// Fast and fused Classic kernels, m = 2 and m ≠ 2.
#[test]
fn prop_quant_rescue_matches_exact_on_return_passes() {
    for case in 0..4u64 {
        for kernel in [Kernel::FcmFast, Kernel::FcmClassic] {
            for m in [2.0, 1.7] {
                let mut rng = Pcg::new(96_000 + case);
                let (n, d, c) = (240usize, 4usize, 3usize);
                // Ring construction: centers ≥ 4 apart (center j offset on
                // axis j), each record on a ring of radius [0.8, 1.2]
                // around its own center. Far centers keep passing the
                // primary shift test for the whole schedule (δ stays well
                // under tol·lb_far); only the own-center bound ever needs
                // the quant rescue, and its certified interval has ample
                // slack inside the ±tol band at this data range.
                let mut v = Matrix::zeros(c, d);
                for j in 0..c {
                    v.row_mut(j)[j] = 4.0;
                }
                let mut x = Matrix::zeros(n, d);
                for i in 0..n {
                    let j = i % c;
                    let r = 0.8 + 0.4 * rng.next_f32() as f64;
                    let mut u = [0.0f64; 4];
                    let mut norm = 0.0f64;
                    for ut in u.iter_mut() {
                        *ut = rng.normal();
                        norm += *ut * *ut;
                    }
                    let norm = norm.sqrt().max(1e-9);
                    for t in 0..d {
                        x.row_mut(i)[t] = v.row(j)[t] + (u[t] / norm * r) as f32;
                    }
                }
                let w = rand_weights(&mut rng, n);
                let cfg = BoundConfig {
                    model: BoundModel::Elkan,
                    tolerance: 0.4,
                    refresh_every: 64,
                    quant: QuantMode::I8,
                };
                let mut st = BlockBounds::default();
                // Refresh pass: builds the sidecar and caches exact bounds.
                NativeBackend.pruned_partials(kernel, &x, &v, &w, m, &mut st, &cfg).unwrap();
                let mut last_quant = 0usize;
                for t in 1..=6u32 {
                    // Wander out on odd steps, return on even ones. The
                    // path length δ grows by 0.12 every step either way.
                    let step = if t % 2 == 1 { 0.12f32 } else { -0.12f32 };
                    for j in 0..c {
                        v.row_mut(j)[0] += step;
                    }
                    let (p, stats) = NativeBackend
                        .pruned_partials(kernel, &x, &v, &w, m, &mut st, &cfg)
                        .unwrap();
                    assert_eq!(
                        stats.pruned, n,
                        "case {case} {kernel:?} m={m} t={t}: a record fell through to the \
                         exact gather (quant rescued {})",
                        stats.quant
                    );
                    last_quant = stats.quant;
                    if t % 2 == 0 {
                        // Centers are back at the refresh positions: the
                        // replayed partials must match the exact pass to
                        // floating-point noise, not just to tolerance.
                        let exact =
                            NativeBackend.exact_partials(kernel, &x, &v, &w, m).unwrap();
                        for (a, b) in p.w_acc.iter().zip(&exact.w_acc) {
                            let rel = (a - b).abs() / b.abs().max(1e-9);
                            assert!(
                                rel < 1e-6,
                                "case {case} {kernel:?} m={m} t={t}: w_acc drift {rel}"
                            );
                        }
                        for (a, b) in p.v_num.as_slice().iter().zip(exact.v_num.as_slice()) {
                            assert!(
                                (a - b).abs() < 1e-6 + 1e-4 * b.abs(),
                                "case {case} {kernel:?} m={m} t={t}: v_num {a} vs {b}"
                            );
                        }
                        let rel = (p.objective - exact.objective).abs()
                            / exact.objective.abs().max(1e-9);
                        assert!(
                            rel < 1e-4,
                            "case {case} {kernel:?} m={m} t={t}: objective drift {rel}"
                        );
                    }
                }
                // By the end δ ≈ 0.72 > tol·lb_own everywhere: every
                // record was abandoned by the primary test and owes its
                // pruning to the certified second chance.
                assert_eq!(
                    last_quant, n,
                    "case {case} {kernel:?} m={m}: final pass should be all-quant-rescued"
                );
            }
        }
    }
}
