//! PJRT runtime integration: AOT artifacts vs golden vectors vs the native
//! backend. Requires `make artifacts` (skips gracefully when absent so
//! `cargo test` works on a fresh checkout, but CI always builds artifacts
//! first).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use bigfcm::config::Config;
use bigfcm::coordinator::BigFcm;
use bigfcm::data::synth::blobs;
use bigfcm::data::Matrix;
use bigfcm::fcm::{KernelBackend, NativeBackend};
use bigfcm::json;
use bigfcm::runtime::{Graph, PjrtRuntime, PjrtShimBackend};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn manifest_covers_experiment_matrix() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::open(&dir).unwrap();
    for (d, c) in [(4, 3), (8, 2), (18, 2), (18, 6), (18, 10), (28, 2), (28, 50), (41, 23)] {
        for g in [Graph::Fcm, Graph::Classic, Graph::Kmeans] {
            assert!(rt.supports(g, d, c), "missing artifact {g:?} d={d} c={c}");
        }
    }
}

/// The AOT golden vectors (emitted from the pure-jnp oracle) must match
/// what the compiled artifacts produce through the whole rust path.
#[test]
fn pjrt_matches_python_golden_vectors() {
    let dir = require_artifacts!();
    let golden = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let v = json::parse(&golden).unwrap();
    let rt = PjrtRuntime::open(&dir).unwrap();
    for case in v.require("cases").unwrap().as_array().unwrap() {
        let graph = Graph::parse(case.get("graph").unwrap().as_str().unwrap()).unwrap();
        let d = case.get("dims").unwrap().as_usize().unwrap();
        let c = case.get("clusters").unwrap().as_usize().unwrap();
        let n = case.get("chunk").unwrap().as_usize().unwrap();
        let m = case.get("m").unwrap().as_f64().unwrap();
        let x = Matrix::from_vec(case.get("x").unwrap().as_f32_vec().unwrap(), n, d);
        let vc = Matrix::from_vec(case.get("v").unwrap().as_f32_vec().unwrap(), c, d);
        let w = case.get("w").unwrap().as_f32_vec().unwrap();
        let exp_vnum = case.get("out_vnum").unwrap().as_f32_vec().unwrap();
        let exp_wacc = case.get("out_wacc").unwrap().as_f32_vec().unwrap();
        let exp_obj = case.get("out_obj").unwrap().as_f64().unwrap();

        let got = match graph {
            Graph::Fcm => rt.fcm_partials(&x, &vc, &w, m).unwrap(),
            Graph::Classic => rt.classic_partials(&x, &vc, &w, m).unwrap(),
            Graph::Kmeans => rt.kmeans_partials(&x, &vc, &w).unwrap(),
        };
        let name = format!("{graph:?} d={d} c={c}");
        for (a, e) in got.v_num.as_slice().iter().zip(&exp_vnum) {
            assert!(
                (a - e).abs() <= 2e-3 + 2e-3 * e.abs(),
                "{name}: vnum {a} vs {e}"
            );
        }
        for (a, e) in got.w_acc.iter().zip(&exp_wacc) {
            assert!(
                (a - *e as f64).abs() <= 2e-3 + 2e-3 * e.abs() as f64,
                "{name}: wacc {a} vs {e}"
            );
        }
        assert!(
            (got.objective - exp_obj).abs() <= 1e-2 + 2e-3 * exp_obj.abs(),
            "{name}: obj {} vs {exp_obj}",
            got.objective
        );
    }
}

/// PJRT and native backends must agree on random inputs (fp32 tolerance),
/// including padded tail chunks.
#[test]
fn pjrt_agrees_with_native_backend() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::open(&dir).unwrap();
    // 5000 rows → one full 4096 chunk + one padded 904-row chunk.
    let data = blobs(5000, 18, 6, 0.8, 3);
    let v = data.features.slice_rows(0, 6);
    let w: Vec<f32> = (0..5000).map(|i| 0.5 + (i % 7) as f32 * 0.2).collect();
    for m in [1.2, 2.0] {
        let a = rt.fcm_partials(&data.features, &v, &w, m).unwrap();
        let b = NativeBackend.fcm_partials(&data.features, &v, &w, m).unwrap();
        for (x, y) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
            assert!((x - y).abs() <= 2e-2 + 2e-3 * y.abs(), "vnum {x} vs {y} at m={m}");
        }
        for (x, y) in a.w_acc.iter().zip(&b.w_acc) {
            assert!((x - y).abs() <= 1e-2 + 2e-3 * y.abs(), "wacc {x} vs {y} at m={m}");
        }
    }
    let a = rt.kmeans_partials(&data.features, &v, &w).unwrap();
    let b = NativeBackend.kmeans_partials(&data.features, &v, &w).unwrap();
    for (x, y) in a.w_acc.iter().zip(&b.w_acc) {
        assert!((x - y).abs() <= 1e-3 + 1e-4 * y.abs(), "kmeans counts {x} vs {y}");
    }
}

/// Full BigFCM pipeline on the PJRT backend matches the native pipeline.
#[test]
fn full_pipeline_pjrt_vs_native() {
    let dir = require_artifacts!();
    let rt: Arc<dyn KernelBackend> = Arc::new(PjrtRuntime::open(&dir).unwrap());
    let data = blobs(6000, 18, 6, 0.6, 9);
    let mut cfg = Config::default();
    cfg.cluster.block_records = 2048;
    cfg.fcm.epsilon = 1e-8;
    let pjrt_run = BigFcm::new(cfg.clone())
        .backend(rt)
        .clusters(6)
        .run_in_memory(&data.features)
        .unwrap();
    let native_run = BigFcm::new(cfg)
        .backend(Arc::new(NativeBackend))
        .clusters(6)
        .run_in_memory(&data.features)
        .unwrap();
    for i in 0..6 {
        let best = (0..6)
            .map(|j| {
                bigfcm::data::matrix::dist2(pjrt_run.centers.row(i), native_run.centers.row(j))
            })
            .fold(f64::INFINITY, f64::min);
        assert!(best < 0.05, "pjrt/native divergence at center {i}: {best}");
    }
    assert!(pjrt_run.weights.iter().all(|w| w.is_finite()));
}

/// Executable cache: repeated runs reuse the compiled artifact.
#[test]
fn executables_are_cached() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::open(&dir).unwrap();
    let data = blobs(1000, 4, 3, 0.5, 1);
    let v = data.features.slice_rows(0, 3);
    let w = vec![1.0f32; 1000];
    rt.fcm_partials(&data.features, &v, &w, 2.0).unwrap();
    rt.fcm_partials(&data.features, &v, &w, 2.0).unwrap();
    let stats = rt.stats().unwrap();
    assert_eq!(stats.compiled, 1, "artifact should compile once");
    assert_eq!(stats.chunks, 2, "two chunk executions expected");
}

/// Unsupported shapes produce a clear error naming the fix.
#[test]
fn unsupported_shape_error_is_actionable() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::open(&dir).unwrap();
    let x = Matrix::zeros(10, 7); // d=7 not in the matrix
    let v = Matrix::zeros(2, 7);
    let err = rt.fcm_partials(&x, &v, &[1.0; 10], 2.0).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("aot.py"), "error should point at the AOT matrix: {msg}");
}

/// The offline PJRT shim needs no artifacts: its padded-chunk marshalling
/// (the device execution shape) must agree with the straight native
/// kernels on every kernel — including the padded tail chunk — and its
/// bound-emitting pass must let the portable pruning protocol prune.
#[test]
fn shim_backend_agrees_with_native_and_prunes() {
    use bigfcm::fcm::{BlockBounds, BoundConfig, BoundModel, Kernel, QuantMode};
    let shim = PjrtShimBackend::new(4096);
    // 5000 rows → one full 4096 chunk + one padded 904-row chunk.
    let data = blobs(5000, 18, 6, 0.8, 3);
    let v = data.features.slice_rows(0, 6);
    let w: Vec<f32> = (0..5000).map(|i| 0.5 + (i % 7) as f32 * 0.2).collect();
    for kernel in [Kernel::FcmFast, Kernel::FcmClassic, Kernel::FcmClassicPair, Kernel::KMeans] {
        let a = shim.exact_partials(kernel, &data.features, &v, &w, 2.0).unwrap();
        let b = NativeBackend.exact_partials(kernel, &data.features, &v, &w, 2.0).unwrap();
        for (x, y) in a.w_acc.iter().zip(&b.w_acc) {
            assert!((x - y).abs() <= 1e-6 + 1e-6 * y.abs(), "{kernel:?}: wacc {x} vs {y}");
        }
        for (x, y) in a.v_num.as_slice().iter().zip(b.v_num.as_slice()) {
            assert!((x - y).abs() <= 1e-2 + 1e-4 * y.abs(), "{kernel:?}: vnum {x} vs {y}");
        }
    }
    // Pruning survives the backend swap: same centers twice → the whole
    // block replays from the shim-refreshed bounds.
    let cfg = BoundConfig {
        model: BoundModel::Elkan,
        tolerance: 1e-2,
        refresh_every: 8,
        quant: QuantMode::Off,
    };
    let mut state = BlockBounds::default();
    let uniform = vec![1.0f32; 5000];
    let (_, p0) = shim
        .pruned_partials(Kernel::FcmFast, &data.features, &v, &uniform, 2.0, &mut state, &cfg)
        .unwrap();
    assert_eq!(p0.pruned, 0, "first shim pass refreshes");
    let (_, p1) = shim
        .pruned_partials(Kernel::FcmFast, &data.features, &v, &uniform, 2.0, &mut state, &cfg)
        .unwrap();
    assert_eq!(p1.pruned, 5000, "unmoved centers must whole-block prune on the shim");
}

/// The runtime is shareable across threads (handle to the device thread).
#[test]
fn runtime_is_send_sync_across_threads() {
    let dir = require_artifacts!();
    let rt = Arc::new(PjrtRuntime::open(&dir).unwrap());
    let data = Arc::new(blobs(2000, 4, 3, 0.5, 2));
    let mut handles = Vec::new();
    for _ in 0..4 {
        let rt = Arc::clone(&rt);
        let data = Arc::clone(&data);
        handles.push(std::thread::spawn(move || {
            let v = data.features.slice_rows(0, 3);
            let w = vec![1.0f32; data.features.rows()];
            rt.fcm_partials(&data.features, &v, &w, 2.0).unwrap()
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &results[1..] {
        assert_eq!(r.v_num.as_slice(), results[0].v_num.as_slice());
    }
}
