//! Observability integration: the tracing + metrics layer end to end over
//! real session runs. The instrumented code paths record into the
//! process-global tracer, so every test here serializes on one lock and
//! resets the collector around itself — `cargo test` runs test threads
//! concurrently and span counts would otherwise cross-pollute.

use std::sync::{Arc, Mutex, MutexGuard};

use bigfcm::config::OverheadConfig;
use bigfcm::data::synth::blobs;
use bigfcm::fcm::loops::{
    run_fcm_session, FcmParams, PruneConfig, SessionAlgo, SessionRunResult,
};
use bigfcm::fcm::{seeding, KernelBackend, NativeBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::json::{self, Value};
use bigfcm::mapreduce::{Engine, EngineOptions, SessionOptions};
use bigfcm::prng::Pcg;
use bigfcm::telemetry::metrics::MetricsRegistry;
use bigfcm::telemetry::{chrome_trace_json, trace};

static TRACE_LOCK: Mutex<()> = Mutex::new(());

/// Exclusive use of the global tracer: reset it, arm it, and hand back the
/// guard the test must hold until it has drained.
fn armed_tracer() -> MutexGuard<'static, ()> {
    let guard = TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let t = trace::global();
    t.enable(false);
    t.reset();
    t.set_max_spans(trace::DEFAULT_MAX_SPANS);
    t.set_slow_span_us(0);
    t.enable(true);
    guard
}

fn disarm_tracer() {
    let t = trace::global();
    t.enable(false);
    t.reset();
}

/// A small fixed-seed session run: 4096 records in 16 blocks, 3 clusters,
/// 4 workers — enough parallelism to exercise the sharded span buffers.
fn run_small_session(seed: u64, workers: usize) -> SessionRunResult {
    let data = blobs(4096, 4, 3, 0.25, seed);
    let store = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
    let mut rng = Pcg::new(seed ^ 0x7ACE);
    let v0 = seeding::random_records(&data.features, 3, &mut rng);
    let params = FcmParams { epsilon: 1e-9, max_iterations: 6, ..Default::default() };
    let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
    let mut engine = Engine::new(
        EngineOptions { workers, ..Default::default() },
        OverheadConfig::default(),
    );
    run_fcm_session(
        &mut engine,
        &store,
        backend,
        SessionAlgo::Fcm,
        v0,
        &params,
        &PruneConfig::disabled(),
        SessionOptions::default(),
        None,
    )
    .unwrap()
}

/// The exported Chrome trace must parse with our own JSON parser, every
/// `ph:"X"` event's parent must resolve (or be 0 = root), durations must be
/// present and non-negative, and the span taxonomy of a session run must
/// all be there.
#[test]
fn session_chrome_trace_parses_and_parents_resolve() {
    let _guard = armed_tracer();
    let _run = run_small_session(11, 4);
    let data = trace::global().drain();
    disarm_tracer();

    let txt = chrome_trace_json(&data, &[("compute", 1.0), ("shuffle", 0.25)]);
    let doc = json::parse(&txt).expect("chrome trace must parse");
    let events = match doc.get("traceEvents") {
        Some(Value::Array(a)) => a,
        other => panic!("missing traceEvents array: {other:?}"),
    };
    let complete: Vec<&Value> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .collect();
    assert!(!complete.is_empty(), "no complete events exported");

    let ids: Vec<f64> = complete
        .iter()
        .filter_map(|e| e.get("args").and_then(|a| a.get("id")).and_then(|x| x.as_f64()))
        .collect();
    let mut names = std::collections::BTreeSet::new();
    for e in &complete {
        if let Some(n) = e.get("name").and_then(|n| n.as_str()) {
            names.insert(n.to_string());
        }
        let dur = e.get("dur").and_then(|d| d.as_f64());
        assert!(dur.is_some_and(|d| d >= 0.0), "event without a non-negative dur: {e:?}");
        if let Some(p) = e.get("args").and_then(|a| a.get("parent")).and_then(|x| x.as_f64())
        {
            assert!(p == 0.0 || ids.contains(&p), "dangling parent id {p}");
        }
    }
    for want in ["session", "iteration", "job", "map_task", "combine"] {
        assert!(names.contains(want), "span {want:?} missing (have {names:?})");
    }
}

/// Per-iteration span durations are stamped from the exact `JobStats` wall
/// (`set_dur`), so the trace and the report must agree within 1% — and the
/// span count must equal the iteration count.
#[test]
fn iteration_spans_agree_with_reported_walls() {
    let _guard = armed_tracer();
    let run = run_small_session(23, 2);
    let data = trace::global().drain();
    disarm_tracer();

    let iter_spans = data.by_name("iteration");
    assert_eq!(
        iter_spans.len(),
        run.per_iteration.len(),
        "one iteration span per engine iteration"
    );
    let span_total_s = data.total_s("iteration");
    let report_total_s: f64 = run.per_iteration.iter().map(|s| s.wall.as_secs_f64()).sum();
    assert!(report_total_s > 0.0, "degenerate run: zero reported wall");
    let rel = (span_total_s - report_total_s).abs() / report_total_s;
    assert!(
        rel <= 0.01,
        "iteration span total {span_total_s:.6}s vs reported {report_total_s:.6}s ({rel:.4} rel)"
    );
}

/// Four workers recording concurrently into the sharded buffers must not
/// lose spans: the trace holds exactly one `map_task` span per map task the
/// engine reports, and one `job` span per engine job.
#[test]
fn concurrent_worker_spans_merge_without_loss() {
    let _guard = armed_tracer();
    let run = run_small_session(37, 4);
    let data = trace::global().drain();
    disarm_tracer();

    assert_eq!(data.dropped, 0, "span cap engaged on a tiny run");
    let expect_tasks: usize = run.per_iteration.iter().map(|s| s.map_tasks).sum();
    assert_eq!(
        data.by_name("map_task").len(),
        expect_tasks,
        "map_task spans vs engine-reported map tasks"
    );
    assert_eq!(data.by_name("job").len(), run.jobs, "job spans vs engine jobs");
    // Multiple worker threads actually recorded (the buffers were shared).
    let tids: std::collections::BTreeSet<u64> =
        data.by_name("map_task").iter().map(|s| s.tid).collect();
    assert!(tids.len() > 1, "expected map tasks across threads, got {tids:?}");
}

/// The registry view is a bit-identical projection of the legacy stats
/// structs: publishing a fixed-seed run and reading the counters back must
/// reproduce the struct fields exactly (no float laundering of integers).
#[test]
fn registry_counters_match_legacy_structs_exactly() {
    // No tracing needed, but the session run records spans whenever some
    // parallel test has the global tracer enabled — serialize anyway.
    let _guard = TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let run = run_small_session(53, 2);

    let reg = MetricsRegistry::new();
    run.publish_metrics(&reg);

    assert_eq!(reg.counter("session.jobs").get(), run.jobs as u64);
    assert_eq!(
        reg.counter("session.iterations").get(),
        run.result.iterations as u64
    );
    assert_eq!(reg.counter("session.records_pruned").get(), run.records_pruned);
    assert_eq!(
        reg.counter("session.peak_resident_bytes").get(),
        run.peak_resident_bytes
    );

    let map_tasks: usize = run.per_iteration.iter().map(|s| s.map_tasks).sum();
    let shuffle: u64 = run.per_iteration.iter().map(|s| s.shuffle_bytes).sum();
    let attempts: usize = run.per_iteration.iter().map(|s| s.attempts).sum();
    assert_eq!(reg.counter("job.map_tasks").get(), map_tasks as u64);
    assert_eq!(reg.counter("job.shuffle_bytes").get(), shuffle);
    assert_eq!(reg.counter("job.attempts").get(), attempts as u64);

    let wall_s: f64 = run.per_iteration.iter().map(|s| s.wall.as_secs_f64()).sum();
    let got = reg.value("job.wall_s").expect("job.wall_s published");
    assert!((got - wall_s).abs() <= 1e-9 + 1e-9 * wall_s.abs());

    // And the exposition surface carries them under Prometheus names.
    let text = reg.prometheus_text();
    assert!(text.contains("# TYPE session_jobs counter"));
    assert!(text.contains(&format!("job_map_tasks {map_tasks}")));
    assert!(text.contains("# TYPE job_wall_s gauge"));
}

/// With the tracer disabled (the default), an instrumented session run
/// records nothing at all — the off path is a relaxed load, not a buffered
/// span.
#[test]
fn disabled_tracer_records_no_spans_from_a_session() {
    let _guard = TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let t = trace::global();
    t.enable(false);
    t.reset();
    let _run = run_small_session(71, 2);
    let data = t.drain();
    assert!(data.spans.is_empty(), "disabled tracer retained {} spans", data.spans.len());
    assert_eq!(data.dropped, 0);
}
