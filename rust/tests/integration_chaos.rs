//! Chaos test matrix: the deterministic fault layer (`bigfcm::faults`)
//! driven through every recovery path at fixed seeds.
//!
//! The contract under test, per fault site:
//!   * recovered faults are *transparent* — session centers and bulk-score
//!     output are bitwise identical to the fault-free run (recovery only
//!     adds modelled backoff time and counter ticks);
//!   * unrecoverable faults are *structured* — a typed error naming the
//!     failing unit (`TaskFailed`, `Timeout`, bundle/checkpoint messages)
//!     or a metered degraded path (spill slots recompute, connections
//!     close), never a panic and never a hang;
//!   * the same seed replays the same schedule, so every assertion here is
//!     deterministic.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bigfcm::config::{OverheadConfig, QuantMode};
use bigfcm::data::synth::blobs;
use bigfcm::data::Matrix;
use bigfcm::error::Result;
use bigfcm::faults::{FaultPlan, FaultSite};
use bigfcm::fcm::loops::{
    run_fcm_session, CheckpointPolicy, FcmParams, PruneConfig, SessionAlgo, SessionRunResult,
    Variant,
};
use bigfcm::fcm::{seeding, KernelBackend, NativeBackend, SessionCheckpoint};
use bigfcm::hdfs::BlockStore;
use bigfcm::mapreduce::{
    DistributedCache, Engine, EngineOptions, MapReduceJob, SessionOptions, TaskCtx,
};
use bigfcm::prng::Pcg;
use bigfcm::serve::{
    client_call, run_score_job, FrontOptions, ModelBundle, ModelRegistry, ServeFront, ServeOptions,
};
use bigfcm::Error;

/// The three fixed seeds the whole matrix replays at.
const SEEDS: [u64; 3] = [11, 12, 13];

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bigfcm_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Small-but-real session fixture: 8 blocks of 256 records, 3 clusters.
fn session_fixture(seed: u64) -> (Arc<BlockStore>, Matrix, FcmParams, Arc<dyn KernelBackend>) {
    let data = blobs(2048, 3, 3, 0.25, seed);
    let store = Arc::new(BlockStore::in_memory("chaos", &data.features, 256, 4).unwrap());
    let mut rng = Pcg::new(seed ^ 0x5E55);
    let v0 = seeding::random_records(&data.features, 3, &mut rng);
    let params = FcmParams { epsilon: 1e-10, max_iterations: 60, ..Default::default() };
    (store, v0, params, Arc::new(NativeBackend))
}

/// Chaos engines disable the prefetcher so every block goes through the
/// demand-read fault site in a deterministic op order (the prefetcher has
/// its own site, exercised by the cache unit tests).
fn engine_with(faults: Option<Arc<FaultPlan>>) -> Engine {
    let opts = EngineOptions { prefetch: false, faults, ..Default::default() };
    Engine::new(opts, OverheadConfig::default())
}

#[allow(clippy::too_many_arguments)]
fn run_session(
    engine: &mut Engine,
    store: &Arc<BlockStore>,
    backend: &Arc<dyn KernelBackend>,
    v0: &Matrix,
    params: &FcmParams,
    prune: &PruneConfig,
    checkpoint: Option<&CheckpointPolicy>,
) -> SessionRunResult {
    run_fcm_session(
        engine,
        store,
        Arc::clone(backend),
        SessionAlgo::Fcm,
        v0.clone(),
        params,
        prune,
        SessionOptions::default(),
        checkpoint,
    )
    .unwrap()
}

fn assert_bitwise(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!(a.rows(), b.rows(), "{what}: row count");
    assert_eq!(a.cols(), b.cols(), "{what}: col count");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

/// Recovered demand-read faults — one transient retry and one checksum
/// quarantine — are invisible in the results at every seed: centers
/// bitwise-match the fault-free run, only the recovery meters move.
#[test]
fn session_centers_bitwise_identical_under_recovered_read_faults() {
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let (store, v0, params, backend) = session_fixture(seed);
        let prune = PruneConfig::disabled();
        let mut clean = engine_with(None);
        let base = run_session(&mut clean, &store, &backend, &v0, &params, &prune, None);

        for corrupt in [false, true] {
            let plan = if corrupt {
                FaultPlan::tripping_corrupt(seed, FaultSite::BlockRead, i as u64)
            } else {
                FaultPlan::tripping(seed, FaultSite::BlockRead, i as u64)
            };
            let mut engine = engine_with(Some(Arc::clone(&plan)));
            let run = run_session(&mut engine, &store, &backend, &v0, &params, &prune, None);
            assert_eq!(
                plan.injected_at(FaultSite::BlockRead),
                1,
                "seed {seed}: the tripped fault must fire exactly once"
            );
            let cache = engine.block_cache();
            if corrupt {
                assert_eq!(cache.quarantines(), 1, "seed {seed}: corrupt read quarantined");
            } else {
                assert_eq!(cache.read_retries(), 1, "seed {seed}: transient read retried");
                assert!(
                    run.sim.backoff_s > 0.0,
                    "seed {seed}: retry backoff must be charged to the modelled clock"
                );
            }
            assert_eq!(cache.read_aborts(), 0, "seed {seed}: one fault never exhausts retries");
            assert_bitwise(
                &base.result.centers,
                &run.result.centers,
                &format!("seed {seed} corrupt={corrupt}"),
            );
            assert_eq!(run.result.iterations, base.result.iterations, "seed {seed}");
        }
    }
}

/// A spill ring whose every slot read faults persistently degrades to
/// recompute — the session still converges to a finite objective, with the
/// retries and aborts metered, instead of erroring or hanging.
#[test]
fn spill_ring_degrades_to_recompute_under_persistent_read_faults() {
    let seed = SEEDS[1];
    let (store, v0, params, backend) = session_fixture(seed);
    let dir = tmp_dir("spill");
    let prune = PruneConfig {
        slab_bytes: 16 * 1024,
        spill_dir: Some(dir.clone()),
        ..PruneConfig::default()
    };

    let mut clean = engine_with(None);
    let base = run_session(&mut clean, &store, &backend, &v0, &params, &prune, None);
    assert!(
        base.slab_spilled_bytes > 0 && base.slab_reloads > 0,
        "fixture must exercise the spill ring (spilled {} B, {} reloads)",
        base.slab_spilled_bytes,
        base.slab_reloads
    );

    let plan = FaultPlan::for_site(seed, FaultSite::SpillRead, 1.0, 0.0);
    let mut engine = engine_with(Some(Arc::clone(&plan)));
    let run = run_session(&mut engine, &store, &backend, &v0, &params, &prune, None);
    assert!(plan.injected_at(FaultSite::SpillRead) > 0, "spill reads must have been attempted");
    assert!(run.slab_spill_retries > 0, "exhaustion walks through the retry budget first");
    assert!(run.result.converged, "recompute degradation must not block convergence");
    assert!(run.result.objective.is_finite());
    assert!(run.sim.backoff_s > 0.0, "spill retries charge modelled backoff");
    std::fs::remove_dir_all(&dir).ok();
}

/// Bulk scoring writes byte-identical membership blocks whether or not a
/// recovered fault hit the input path.
#[test]
fn bulk_score_output_is_byte_identical_under_recovered_faults() {
    let seed = SEEDS[2];
    let data = blobs(1536, 4, 3, 0.25, seed);
    let store = Arc::new(BlockStore::in_memory("chaos_score", &data.features, 256, 4).unwrap());
    let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
    let mk_bundle = || {
        let mut centers = Matrix::zeros(3, 4);
        for i in 0..3 {
            centers.row_mut(i).copy_from_slice(data.features.row(i * 512));
        }
        ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0)
    };

    let out_clean = tmp_dir("score_clean");
    let mut clean = engine_with(None);
    let a = run_score_job(
        &mut clean,
        &store,
        Arc::new(mk_bundle()),
        Arc::clone(&backend),
        2,
        QuantMode::Off,
        out_clean.clone(),
    )
    .unwrap();

    let out_chaos = tmp_dir("score_chaos");
    let plan = FaultPlan::tripping(seed, FaultSite::BlockRead, 1);
    let mut engine = engine_with(Some(Arc::clone(&plan)));
    let b = run_score_job(
        &mut engine,
        &store,
        Arc::new(mk_bundle()),
        Arc::clone(&backend),
        2,
        QuantMode::Off,
        out_chaos.clone(),
    )
    .unwrap();

    assert_eq!(plan.injected_at(FaultSite::BlockRead), 1, "the tripped read fault must fire");
    assert_eq!(engine.block_cache().read_retries(), 1);
    assert_eq!(a.totals.rows, b.totals.rows);
    assert_eq!(a.totals.top1_mass.to_bits(), b.totals.top1_mass.to_bits());
    assert_eq!(a.store.num_blocks(), b.store.num_blocks());
    for blk in 0..a.store.num_blocks() {
        let ma = a.store.read_block(blk).unwrap();
        let mb = b.store.read_block(blk).unwrap();
        assert_bitwise(&ma, &mb, &format!("membership block {blk}"));
    }
    std::fs::remove_dir_all(&out_clean).ok();
    std::fs::remove_dir_all(&out_chaos).ok();
}

/// Trivial sum job for the worker-failure path.
struct Sum;

impl MapReduceJob for Sum {
    type MapOut = f64;
    type Output = f64;

    fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
        Ok(block.as_slice().iter().map(|&v| v as f64).sum())
    }

    fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
        Ok(parts.into_iter().sum())
    }

    fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
        8
    }

    fn name(&self) -> &str {
        "chaos_sum"
    }
}

/// A map task that exhausts its attempt budget surfaces as
/// `Error::TaskFailed` naming the task — no panic — and the engine (pool,
/// cache, clock) keeps working: the very next job on it succeeds exactly.
#[test]
fn map_task_exhaustion_is_structured_and_engine_survives() {
    let data = blobs(1024, 3, 2, 0.3, 17);
    let store = Arc::new(BlockStore::in_memory("chaos_task", &data.features, 256, 4).unwrap());
    let expected: f64 = data.features.as_slice().iter().map(|&v| v as f64).sum();

    let plan = FaultPlan::tripping(17, FaultSite::MapTask, 0);
    let mut engine = engine_with(Some(Arc::clone(&plan)));
    let err = engine
        .run_job(Arc::new(Sum), &store, Arc::new(DistributedCache::new()))
        .unwrap_err();
    match err {
        Error::TaskFailed { task, attempts } => {
            assert_eq!(task, 0, "the tripped task is the one named");
            assert!(attempts >= 1);
        }
        other => panic!("expected TaskFailed, got: {other}"),
    }

    // The trip is consumed: the same engine runs the next job to completion.
    let (total, stats) = engine
        .run_job(Arc::new(Sum), &store, Arc::new(DistributedCache::new()))
        .unwrap();
    assert_eq!(stats.map_tasks as usize, store.num_blocks());
    assert!(
        (total - expected).abs() <= 1e-6 * expected.abs().max(1.0),
        "{total} vs {expected}"
    );
}

/// Kill-at-iteration-k recovery: a session checkpointed every iteration and
/// stopped at 3 resumes from the checkpoint file to the *bitwise* same
/// final centers as the uninterrupted run, in exactly the remaining
/// iterations; a corrupted checkpoint is rejected loudly instead of being
/// resumed from.
#[test]
fn checkpointed_session_resumes_bitwise_and_rejects_corruption() {
    let seed = SEEDS[0];
    let (store, v0, params, backend) = session_fixture(seed);
    let prune = PruneConfig::disabled();

    let mut full_engine = engine_with(None);
    let full = run_session(&mut full_engine, &store, &backend, &v0, &params, &prune, None);

    let dir = tmp_dir("ckpt");
    let path = dir.join("session.ckpt");
    let killed_params = FcmParams { max_iterations: 3, ..params };
    let policy = CheckpointPolicy { every: 1, path: path.clone() };
    let killed = run_session(
        &mut engine_with(None),
        &store,
        &backend,
        &v0,
        &killed_params,
        &prune,
        Some(&policy),
    );
    assert_eq!(killed.checkpoints_written, 3);
    assert!(killed.checkpoint_bytes > 0);

    let cp = SessionCheckpoint::load(&path).unwrap();
    assert_eq!(cp.iteration, 3);
    assert_bitwise(&cp.centers, &killed.result.centers, "checkpoint vs killed run");

    let mut resumed_engine = engine_with(None);
    let resumed =
        run_session(&mut resumed_engine, &store, &backend, &cp.centers, &params, &prune, None);
    assert_bitwise(&full.result.centers, &resumed.result.centers, "resumed vs uninterrupted");
    assert_eq!(
        cp.iteration as usize + resumed.result.iterations,
        full.result.iterations,
        "resume picks up exactly where the checkpoint left off"
    );

    // Any torn byte must refuse to resume, loudly.
    let mut img = std::fs::read(&path).unwrap();
    let mid = img.len() / 2;
    img[mid] ^= 0x10;
    std::fs::write(&path, &img).unwrap();
    let err = SessionCheckpoint::load(&path).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("checkpoint") && msg.contains(&path.display().to_string()),
        "rejection must name the file: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Serving fixture: a tiny valid bundle for the wire tests.
fn wire_bundle() -> ModelBundle {
    let data = blobs(256, 4, 3, 0.25, 23);
    let mut centers = Matrix::zeros(3, 4);
    for i in 0..3 {
        centers.row_mut(i).copy_from_slice(data.features.row(i * 64));
    }
    ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0)
}

/// The `health` verb answers without touching the registry, and a front
/// whose every connection is chaos-dropped returns structured errors to
/// clients promptly — never a hang — while metering the drops.
#[test]
fn front_health_answers_and_injected_conn_drops_never_hang() {
    let reg = Arc::new(ModelRegistry::new(Arc::new(NativeBackend), ServeOptions::default()));
    reg.publish("m", wire_bundle()).unwrap();

    let front = ServeFront::bind(
        Arc::clone(&reg),
        "127.0.0.1:0",
        FrontOptions::default(),
        OverheadConfig::default(),
    )
    .unwrap();
    let addr = front.local_addr().to_string();
    assert_eq!(client_call(&addr, "health", Duration::from_secs(5)).unwrap(), "ok up");
    drop(front);

    let plan = FaultPlan::for_site(23, FaultSite::Connection, 1.0, 0.0);
    let fopts = FrontOptions { faults: Some(Arc::clone(&plan)), ..FrontOptions::default() };
    let front =
        ServeFront::bind(Arc::clone(&reg), "127.0.0.1:0", fopts, OverheadConfig::default())
            .unwrap();
    let addr = front.local_addr().to_string();
    let t0 = Instant::now();
    let err = client_call(&addr, "health", Duration::from_secs(5)).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "a dropped connection must error before the client timeout: {err}"
    );
    let t0 = Instant::now();
    while front.stats().conn_drops < 1 {
        assert!(t0.elapsed() < Duration::from_secs(5), "injected drop never metered");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// `client_call` separates "down" (refused — `Error::Job`, fails fast) from
/// "slow" (peer up but unresponsive — `Error::Timeout` after the budget).
#[test]
fn client_call_distinguishes_down_from_slow() {
    // Down: nothing listens on the reserved port — connection refused.
    let err = client_call("127.0.0.1:1", "ping", Duration::from_secs(2)).unwrap_err();
    assert!(
        !matches!(err, Error::Timeout(_)),
        "a refused connection is down, not slow: {err}"
    );
    assert!(err.to_string().contains("connect"), "down must name the connect step: {err}");

    // Slow: a listener that never accepts — the kernel completes the
    // handshake, then the response read times out.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let t0 = Instant::now();
    let err = client_call(&addr, "ping", Duration::from_millis(400)).unwrap_err();
    assert!(
        matches!(err, Error::Timeout(_)),
        "an unresponsive peer is slow, not down: {err}"
    );
    assert!(t0.elapsed() >= Duration::from_millis(300), "the timeout budget must be honored");
    drop(listener);
}
