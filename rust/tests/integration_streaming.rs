//! Scale-harness-in-miniature (the CI-sized twin of
//! `examples/scale_susy.rs`): stream an on-disk store through the engine
//! under a byte budget far below the store size, with locality-aware
//! scheduling and prefetch on, and pin the envelopes the multi-GiB harness
//! asserts — resident bytes bounded by `budget + workers × max_block`,
//! locality hits and prefetch hits both observed, results exact.

use std::sync::Arc;

use bigfcm::config::OverheadConfig;
use bigfcm::data::synth::blobs;
use bigfcm::data::Matrix;
use bigfcm::error::Result;
use bigfcm::fcm::loops::{run_fcm_session, FcmParams, PruneConfig, SessionAlgo};
use bigfcm::fcm::{max_center_shift2, ChunkBackend, NativeBackend};
use bigfcm::hdfs::BlockStoreWriter;
use bigfcm::mapreduce::{
    DistributedCache, Engine, EngineOptions, MapReduceJob, SessionOptions, TaskCtx,
};

/// Sum job whose compute deliberately dominates a tiny block decode (many
/// passes over the block), so the prefetcher reliably wins its race and the
/// prefetch-hit envelope is testable without a multi-GiB store.
struct SpinSum;

const PASSES: usize = 60;

impl MapReduceJob for SpinSum {
    type MapOut = (f64, usize);
    type Output = (f64, usize);

    fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
        let mut acc = 0.0f64;
        for _ in 0..PASSES {
            acc += block.as_slice().iter().map(|&v| v as f64).sum::<f64>();
        }
        Ok((acc / PASSES as f64, block.rows()))
    }

    fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
        Ok(parts.into_iter().fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
    }

    fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
        16
    }

    fn name(&self) -> &str {
        "spin_sum"
    }
}

/// Build an on-disk store through the streaming writer: `blocks` blocks of
/// `rows` rows each, `cols` features. Returns the store and its directory
/// (for cleanup).
fn disk_store(
    blocks: usize,
    rows: usize,
    cols: usize,
    workers: usize,
    tag: &str,
) -> (Arc<bigfcm::hdfs::BlockStore>, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!("bigfcm_scale_mini_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut w = BlockStoreWriter::create("mini", cols, workers, dir.clone()).unwrap();
    for b in 0..blocks {
        let d = blobs(rows, cols, 2, 0.4, 9000 + b as u64);
        w.append(&d.features).unwrap();
    }
    (Arc::new(w.finish().unwrap()), dir)
}

#[test]
fn mini_scale_harness_envelopes_hold() {
    let workers = 4usize;
    // 48 blocks x 4096 rows x 8 cols ≈ 128 KiB serialised per block, 6 MiB
    // total; budget of 4 blocks ≈ 512 KiB — 12x below the store.
    let (store, dir) = disk_store(48, 4096, 8, workers, "envelopes");
    let block_bytes = store.max_block_bytes();
    let budget = 4 * block_bytes;
    let opts = EngineOptions {
        workers,
        block_cache_bytes: budget,
        ..Default::default()
    };
    let mut engine = Engine::new(opts, OverheadConfig::default());

    // Expected total from a direct sequential pass.
    let mut expected = 0.0f64;
    for b in 0..store.num_blocks() {
        expected += store
            .read_block(b)
            .unwrap()
            .as_slice()
            .iter()
            .map(|&v| v as f64)
            .sum::<f64>();
    }

    let ((total, rows), stats) = engine
        .run_job(Arc::new(SpinSum), &store, Arc::new(DistributedCache::new()))
        .unwrap();

    // Results exact: streaming, caching, locality and prefetch change
    // scheduling and memory only.
    assert_eq!(rows, 48 * 4096);
    assert!(
        (total - expected).abs() <= 1e-6 * expected.abs().max(1.0),
        "{total} vs {expected}"
    );

    // Resident-byte envelope: budget + one in-flight block per worker.
    let bc = engine.block_cache();
    let envelope = budget + workers as u64 * block_bytes;
    assert!(
        bc.peak_resident_bytes() <= envelope,
        "peak resident bytes {} > envelope {envelope} (budget {budget} + {workers} x {block_bytes})",
        bc.peak_resident_bytes()
    );
    // The cache itself never exceeds its budget.
    assert!(bc.cached_bytes() <= budget, "{} > {budget}", bc.cached_bytes());

    // Mechanism liveness: every claim accounted, locality honoured for at
    // least part of the map, and the prefetcher won races (compute per
    // block >> decode per block by construction).
    assert_eq!(stats.locality_hits + stats.locality_steals, 48);
    assert!(stats.locality_hits > 0, "scheduler never honoured a locality hint");
    assert!(
        stats.prefetch_hits > 0,
        "no prefetch hit: hits {} misses {} prefetches {}",
        bc.hits(),
        bc.misses(),
        bc.prefetches()
    );
    // Every distinct block was decoded at least once, on demand or ahead.
    assert!(bc.misses() + bc.prefetches() >= 48);

    std::fs::remove_dir_all(dir).ok();
}

/// CI-sized twin of the scale harness's iteration-residency phase: an FCM
/// convergence loop over an on-disk store through an `IterativeSession`,
/// with shift-bounded pruning on. Pins the acceptance envelope:
/// `records_pruned > 0` after iteration 2, final centers within epsilon-
/// scale distance of the exact (pruning-disabled) run, job startup charged
/// once, and the byte-budget residency envelope intact throughout.
#[test]
fn mini_scale_session_fcm_prunes_and_matches_exact() {
    let workers = 4usize;
    // One coherent blob structure split across 12 on-disk blocks (the
    // session loop clusters globally, so every block must come from the
    // same mixture).
    let data = blobs(12 * 1024, 6, 3, 0.25, 9100);
    let dir = std::env::temp_dir()
        .join(format!("bigfcm_scale_mini_session_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut w = BlockStoreWriter::create("mini", 6, workers, dir.clone()).unwrap();
    for b in 0..12 {
        w.append(&data.features.slice_rows(b * 1024, (b + 1) * 1024)).unwrap();
    }
    let store = Arc::new(w.finish().unwrap());
    let block_bytes = store.max_block_bytes();
    let budget = 6 * block_bytes;

    let mut rng = bigfcm::prng::Pcg::new(9101);
    let v0 = bigfcm::fcm::seeding::random_records(&data.features, 3, &mut rng);
    let params = FcmParams { epsilon: 1e-10, ..Default::default() };
    let backend: Arc<dyn ChunkBackend> = Arc::new(NativeBackend);
    let overhead = OverheadConfig::default();
    let opts = EngineOptions { workers, block_cache_bytes: budget, ..Default::default() };

    let mut exact_engine = Engine::new(opts.clone(), overhead.clone());
    let exact = run_fcm_session(
        &mut exact_engine,
        &store,
        Arc::clone(&backend),
        SessionAlgo::Fcm,
        v0.clone(),
        &params,
        &PruneConfig::disabled(),
        SessionOptions::default(),
    )
    .unwrap();

    let mut engine = Engine::new(opts, overhead.clone());
    let run = run_fcm_session(
        &mut engine,
        &store,
        backend,
        SessionAlgo::Fcm,
        v0,
        &params,
        &PruneConfig::default(),
        SessionOptions::default(),
    )
    .unwrap();

    assert!(exact.result.converged && run.result.converged);
    // Acceptance: pruning live after iteration 2.
    let pruned_after_two: u64 = run
        .per_iteration
        .iter()
        .skip(2)
        .map(|s| s.records_pruned)
        .sum();
    assert!(
        pruned_after_two > 0,
        "no records pruned after iteration 2 across {} iterations",
        run.jobs
    );
    // Acceptance: final centers within epsilon-scale distance of exact.
    let shift = max_center_shift2(&exact.result.centers, &run.result.centers);
    assert!(shift < 1e-3, "pruned session drifted from exact: {shift}");
    // Iteration residency: the whole loop charged startup once.
    assert!(
        (run.sim.job_startup_s - overhead.job_startup_s).abs() < 1e-9,
        "resident loop charged startup more than once: {}",
        run.sim.job_startup_s
    );
    // The streaming envelope holds across all iterations: the run result
    // carries the max over per-iteration peaks (the session resets the
    // per-job meters between iterations, so a post-loop gauge read would
    // only see the last one).
    assert!(
        run.peak_resident_bytes <= budget + workers as u64 * block_bytes,
        "session iterations broke the residency envelope: {} > {budget} + {workers}×{block_bytes}",
        run.peak_resident_bytes
    );
    assert!(run.peak_resident_bytes > 0, "peak meter never observed");
    // Slab stayed within its own budget and was metered.
    let last = run.per_iteration.last().unwrap();
    assert!(last.slab_bytes <= PruneConfig::default().slab_bytes);
    assert!(run.per_iteration.iter().any(|s| s.slab_bytes > 0));
    // Tree combine funnels few parts into each iteration's reduce.
    assert!(last.reduce_parts < 12, "tree combine inactive: {} parts", last.reduce_parts);

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn mini_scale_second_pass_reuses_warm_budget() {
    // Second job over the same store: the cache can only retain `budget`
    // bytes, so warm hits are at most the budget's worth of blocks and the
    // envelope still holds across jobs.
    let workers = 2usize;
    let (store, dir) = disk_store(16, 2048, 6, workers, "second");
    let block_bytes = store.max_block_bytes();
    let budget = 3 * block_bytes;
    let opts = EngineOptions {
        workers,
        block_cache_bytes: budget,
        ..Default::default()
    };
    let mut engine = Engine::new(opts, OverheadConfig::default());
    let cache = Arc::new(DistributedCache::new());
    let (out1, _) = engine.run_job(Arc::new(SpinSum), &store, Arc::clone(&cache)).unwrap();
    let (out2, stats2) = engine.run_job(Arc::new(SpinSum), &store, cache).unwrap();
    assert_eq!(out1.1, out2.1);
    assert!((out1.0 - out2.0).abs() <= 1e-9 * out1.0.abs().max(1.0));
    assert_eq!(stats2.locality_hits + stats2.locality_steals, 16);
    let bc = engine.block_cache();
    assert!(bc.peak_resident_bytes() <= budget + workers as u64 * block_bytes);
    assert!(bc.cached_bytes() <= budget);

    std::fs::remove_dir_all(dir).ok();
}
