//! Scale-harness-in-miniature (the CI-sized twin of
//! `examples/scale_susy.rs`): stream an on-disk store through the engine
//! under a byte budget far below the store size, with locality-aware
//! scheduling and prefetch on, and pin the envelopes the multi-GiB harness
//! asserts — resident bytes bounded by `budget + workers × max_block`,
//! locality hits and prefetch hits both observed, results exact.

use std::sync::Arc;

use bigfcm::config::{OverheadConfig, QuantMode};
use bigfcm::data::synth::blobs;
use bigfcm::data::Matrix;
use bigfcm::error::Result;
use bigfcm::fcm::loops::{
    run_fcm_session, run_fcm_session_sharded, FcmParams, PruneConfig, SessionAlgo,
};
use bigfcm::fcm::{max_center_shift2, KernelBackend, NativeBackend};
use bigfcm::hdfs::BlockStoreWriter;
use bigfcm::mapreduce::{
    DistributedCache, Engine, EngineOptions, MapReduceJob, SessionOptions, ShardMergeMode,
    ShardedEngine, TaskCtx,
};
use bigfcm::runtime::PjrtShimBackend;

/// Sum job whose compute deliberately dominates a tiny block decode (many
/// passes over the block), so the prefetcher reliably wins its race and the
/// prefetch-hit envelope is testable without a multi-GiB store.
struct SpinSum;

const PASSES: usize = 60;

impl MapReduceJob for SpinSum {
    type MapOut = (f64, usize);
    type Output = (f64, usize);

    fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
        let mut acc = 0.0f64;
        for _ in 0..PASSES {
            acc += block.as_slice().iter().map(|&v| v as f64).sum::<f64>();
        }
        Ok((acc / PASSES as f64, block.rows()))
    }

    fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
        Ok(parts.into_iter().fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
    }

    fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
        16
    }

    fn name(&self) -> &str {
        "spin_sum"
    }
}

/// Build an on-disk store through the streaming writer: `blocks` blocks of
/// `rows` rows each, `cols` features. Returns the store and its directory
/// (for cleanup).
fn disk_store(
    blocks: usize,
    rows: usize,
    cols: usize,
    workers: usize,
    tag: &str,
) -> (Arc<bigfcm::hdfs::BlockStore>, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!("bigfcm_scale_mini_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut w = BlockStoreWriter::create("mini", cols, workers, dir.clone()).unwrap();
    for b in 0..blocks {
        let d = blobs(rows, cols, 2, 0.4, 9000 + b as u64);
        w.append(&d.features).unwrap();
    }
    (Arc::new(w.finish().unwrap()), dir)
}

#[test]
fn mini_scale_harness_envelopes_hold() {
    let workers = 4usize;
    // 48 blocks x 4096 rows x 8 cols ≈ 128 KiB serialised per block, 6 MiB
    // total; budget of 4 blocks ≈ 512 KiB — 12x below the store.
    let (store, dir) = disk_store(48, 4096, 8, workers, "envelopes");
    let block_bytes = store.max_block_bytes();
    let budget = 4 * block_bytes;
    let opts = EngineOptions {
        workers,
        block_cache_bytes: budget,
        ..Default::default()
    };
    let mut engine = Engine::new(opts, OverheadConfig::default());

    // Expected total from a direct sequential pass.
    let mut expected = 0.0f64;
    for b in 0..store.num_blocks() {
        expected += store
            .read_block(b)
            .unwrap()
            .as_slice()
            .iter()
            .map(|&v| v as f64)
            .sum::<f64>();
    }

    let ((total, rows), stats) = engine
        .run_job(Arc::new(SpinSum), &store, Arc::new(DistributedCache::new()))
        .unwrap();

    // Results exact: streaming, caching, locality and prefetch change
    // scheduling and memory only.
    assert_eq!(rows, 48 * 4096);
    assert!(
        (total - expected).abs() <= 1e-6 * expected.abs().max(1.0),
        "{total} vs {expected}"
    );

    // Resident-byte envelope: budget + one in-flight block per worker.
    let bc = engine.block_cache();
    let envelope = budget + workers as u64 * block_bytes;
    assert!(
        bc.peak_resident_bytes() <= envelope,
        "peak resident bytes {} > envelope {envelope} (budget {budget} + {workers} x {block_bytes})",
        bc.peak_resident_bytes()
    );
    // The cache itself never exceeds its budget.
    assert!(bc.cached_bytes() <= budget, "{} > {budget}", bc.cached_bytes());

    // Mechanism liveness: every claim accounted, locality honoured for at
    // least part of the map, and the prefetcher won races (compute per
    // block >> decode per block by construction).
    assert_eq!(stats.locality_hits + stats.locality_steals, 48);
    assert!(stats.locality_hits > 0, "scheduler never honoured a locality hint");
    assert!(
        stats.prefetch_hits > 0,
        "no prefetch hit: hits {} misses {} prefetches {}",
        bc.hits(),
        bc.misses(),
        bc.prefetches()
    );
    // Every distinct block was decoded at least once, on demand or ahead.
    assert!(bc.misses() + bc.prefetches() >= 48);

    std::fs::remove_dir_all(dir).ok();
}

/// CI-sized twin of the scale harness's iteration-residency phase: an FCM
/// convergence loop over an on-disk store through an `IterativeSession`,
/// with shift-bounded pruning on — run across **four backends/bound
/// models** (native-exact, native-dmin, native-elkan, PJRT-shim) through
/// the one `KernelBackend` interface. Pins the acceptance envelope:
///
/// * all four arms converge to centers within 1e-6 (squared shift) of one
///   another — convergence is only ever accepted from an exact pass;
/// * `records_pruned(elkan) ≥ records_pruned(dmin) > 0` after iteration 2
///   (the per-center bound is implied by the single-d_min bound);
/// * the shim arm prunes too — the session layer's bounds survive the
///   backend swap;
/// * job startup charged once per arm, the byte-budget residency envelope
///   intact throughout.
struct SessionTwin {
    store: Arc<bigfcm::hdfs::BlockStore>,
    dir: std::path::PathBuf,
    v0: bigfcm::data::Matrix,
    params: FcmParams,
    opts: EngineOptions,
    budget: u64,
    workers: usize,
}

fn session_twin_setup(tag: &str) -> SessionTwin {
    let workers = 4usize;
    // One coherent blob structure split across 12 on-disk blocks (the
    // session loop clusters globally, so every block must come from the
    // same mixture).
    let data = blobs(12 * 1024, 6, 3, 0.25, 9100);
    let dir = std::env::temp_dir()
        .join(format!("bigfcm_scale_mini_session_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut w = BlockStoreWriter::create("mini", 6, workers, dir.clone()).unwrap();
    for b in 0..12 {
        w.append(&data.features.slice_rows(b * 1024, (b + 1) * 1024)).unwrap();
    }
    let store = Arc::new(w.finish().unwrap());
    let block_bytes = store.max_block_bytes();
    let budget = 6 * block_bytes;
    let mut rng = bigfcm::prng::Pcg::new(9101);
    let v0 = bigfcm::fcm::seeding::random_records(&data.features, 3, &mut rng);
    let params = FcmParams { epsilon: 1e-10, ..Default::default() };
    let opts = EngineOptions { workers, block_cache_bytes: budget, ..Default::default() };
    SessionTwin { store, dir, v0, params, opts, budget, workers }
}

fn run_twin_arm(
    twin: &SessionTwin,
    backend: Arc<dyn KernelBackend>,
    prune: &PruneConfig,
) -> bigfcm::fcm::SessionRunResult {
    let mut engine = Engine::new(twin.opts.clone(), OverheadConfig::default());
    run_fcm_session(
        &mut engine,
        &twin.store,
        backend,
        SessionAlgo::Fcm,
        twin.v0.clone(),
        &twin.params,
        prune,
        SessionOptions::default(),
        None,
    )
    .unwrap()
}

fn pruned_after_two(run: &bigfcm::fcm::SessionRunResult) -> u64 {
    run.per_iteration.iter().skip(2).map(|s| s.records_pruned).sum()
}

#[test]
fn mini_scale_session_backends_agree_and_elkan_dominates() {
    let twin = session_twin_setup("backends");
    let native: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
    let shim: Arc<dyn KernelBackend> = Arc::new(PjrtShimBackend::new(4096));

    let exact = run_twin_arm(&twin, Arc::clone(&native), &PruneConfig::disabled());
    // The dmin-vs-elkan dominance claim is about the bound model, so the
    // A/B controls the refresh cadence: adaptive cap scaling off, both
    // arms refresh on the identical fixed schedule. (The adaptive policy
    // has its own exactness test in fcm::loops.)
    let dmin = run_twin_arm(
        &twin,
        Arc::clone(&native),
        &PruneConfig { adaptive_refresh: false, ..PruneConfig::dmin() },
    );
    let elkan = run_twin_arm(
        &twin,
        Arc::clone(&native),
        &PruneConfig { adaptive_refresh: false, ..PruneConfig::default() },
    );
    let shim_run = run_twin_arm(&twin, shim, &PruneConfig::default());

    let arms =
        [("exact", &exact), ("dmin", &dmin), ("elkan", &elkan), ("pjrt-shim", &shim_run)];
    for (name, run) in &arms {
        assert!(run.result.converged, "{name} arm did not converge in {} iters", run.jobs);
        let startup = OverheadConfig::default().job_startup_s;
        assert!(
            (run.sim.job_startup_s - startup).abs() < 1e-9,
            "{name}: resident loop charged startup more than once"
        );
        assert!(
            run.peak_resident_bytes <= twin.budget + twin.workers as u64 * twin.store.max_block_bytes(),
            "{name}: residency envelope broken"
        );
    }
    // Acceptance: every pair of backends/bound models lands within 1e-6.
    for (na, ra) in &arms {
        for (nb, rb) in &arms {
            let shift = max_center_shift2(&ra.result.centers, &rb.result.centers);
            assert!(shift < 1e-6, "{na} vs {nb}: centers diverged by {shift}");
        }
    }
    // Acceptance: pruning live after iteration 2, per-center bound at
    // least as deep as the single-d_min bound, shim pruning too.
    assert_eq!(exact.records_pruned, 0);
    let d2 = pruned_after_two(&dmin);
    let e2 = pruned_after_two(&elkan);
    let s2 = pruned_after_two(&shim_run);
    assert!(d2 > 0, "dmin arm never pruned after iteration 2");
    assert!(e2 >= d2, "elkan ({e2}) must prune at least as much as dmin ({d2})");
    assert!(s2 > 0, "shim arm never pruned — bounds did not survive the backend swap");
    // Slab metered and within its own budget.
    let last = elkan.per_iteration.last().unwrap();
    assert!(last.slab_bytes <= PruneConfig::default().slab_bytes);
    assert!(elkan.per_iteration.iter().any(|s| s.slab_bytes > 0));
    // Tree combine funnels few parts into each iteration's reduce.
    assert!(last.reduce_parts < 12, "tree combine inactive: {} parts", last.reduce_parts);

    std::fs::remove_dir_all(&twin.dir).ok();
}

/// Acceptance for the certified quant pre-pass (ISSUE 6 tentpole): the
/// four-arm session twin — exact / elkan / elkan+quant / shim+quant —
/// converges to identical centers within 1e-6, and because the i8 second
/// chance only examines records the primary shift bound already abandoned,
/// the quant arm's post-iteration-2 pruning dominates plain elkan's on the
/// identical fixed refresh schedule. The sidecar is built, byte-accounted
/// and surfaced through the session counters.
#[test]
fn mini_scale_session_quant_arms_agree_and_dominate() {
    let twin = session_twin_setup("quant");
    let native: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
    let shim: Arc<dyn KernelBackend> = Arc::new(PjrtShimBackend::new(4096));

    let exact = run_twin_arm(&twin, Arc::clone(&native), &PruneConfig::disabled());
    // Fixed refresh cadence on both native arms: the dominance claim is
    // about the bound test, so the A/B must hold the schedule constant.
    let elkan = run_twin_arm(
        &twin,
        Arc::clone(&native),
        &PruneConfig { adaptive_refresh: false, ..PruneConfig::default() },
    );
    let quant = run_twin_arm(
        &twin,
        Arc::clone(&native),
        &PruneConfig {
            adaptive_refresh: false,
            quant: QuantMode::I8,
            ..PruneConfig::default()
        },
    );
    let shim_quant = run_twin_arm(
        &twin,
        shim,
        &PruneConfig { quant: QuantMode::I8, ..PruneConfig::default() },
    );

    let arms = [
        ("exact", &exact),
        ("elkan", &elkan),
        ("elkan+quant", &quant),
        ("shim+quant", &shim_quant),
    ];
    for (name, run) in &arms {
        assert!(run.result.converged, "{name} arm did not converge in {} iters", run.jobs);
    }
    // Survivors replay exact f32 math, so the quant arms stay inside the
    // same 1e-6 envelope as the bound-only arms.
    for (na, ra) in &arms {
        for (nb, rb) in &arms {
            let shift = max_center_shift2(&ra.result.centers, &rb.result.centers);
            assert!(shift < 1e-6, "{na} vs {nb}: centers diverged by {shift}");
        }
    }
    // Structural dominance: the second chance only adds pruned records.
    let e2 = pruned_after_two(&elkan);
    let q2 = pruned_after_two(&quant);
    assert!(e2 > 0, "elkan arm never pruned after iteration 2");
    assert!(
        q2 >= e2,
        "elkan+quant ({q2}) must prune at least as much as elkan ({e2})"
    );
    // Sidecar built, byte-accounted and visible in the run counters; the
    // exact and plain-elkan arms must not be charged for one.
    assert!(quant.quant_sidecar_bytes > 0, "quant arm reported no sidecar bytes");
    assert!(quant.quant_build_s > 0.0, "quant arm reported no sidecar build time");
    assert_eq!(exact.quant_sidecar_bytes, 0);
    assert_eq!(elkan.quant_sidecar_bytes, 0);
    assert_eq!(elkan.records_pruned_quant, 0);
    // The shim forwards the native pruned path, so quant survives the
    // backend swap too.
    assert!(
        shim_quant.records_pruned > 0,
        "shim+quant arm never pruned — pre-pass did not survive the backend swap"
    );

    std::fs::remove_dir_all(&twin.dir).ok();
}

/// Acceptance: a slab budget of one block's state forces the disk spill
/// ring (`slab_spilled_bytes > 0`, `slab_reloads > 0`) without changing
/// results **bitwise** — the spill codec is exact, so every pruning
/// decision and replayed contribution is reproduced.
#[test]
fn mini_scale_session_slab_spill_is_bitwise() {
    let twin = session_twin_setup("spill");
    let native: Arc<dyn KernelBackend> = Arc::new(NativeBackend);

    let roomy = run_twin_arm(&twin, Arc::clone(&native), &PruneConfig::default());
    assert_eq!(roomy.slab_spilled_bytes, 0);
    assert_eq!(roomy.slab_reloads, 0);

    // ≈ one block's elkan state: 1024 rows × 4·(2C+2) B + block constants.
    let one_block_state = 1024 * 4 * (2 * 3 + 2) + 16 * 1024;
    let spill_dir = twin.dir.join("slab_ring");
    let tight = PruneConfig {
        slab_bytes: one_block_state,
        spill_dir: Some(spill_dir.clone()),
        ..PruneConfig::default()
    };
    let spilled = run_twin_arm(&twin, native, &tight);

    assert!(spilled.slab_spilled_bytes > 0, "1-block budget must spill");
    assert!(spilled.slab_reloads > 0, "spilled state must reload on the next touch");
    assert!(spilled.result.converged);
    assert_eq!(
        roomy.result.centers.as_slice(),
        spilled.result.centers.as_slice(),
        "spill/reload roundtrip changed results — the codec is not bitwise"
    );
    assert_eq!(roomy.records_pruned, spilled.records_pruned, "pruning decisions diverged");
    assert_eq!(roomy.jobs, spilled.jobs);

    std::fs::remove_dir_all(&twin.dir).ok();
}

/// Sharded twin of the session harness (the scale-out tentpole's CI
/// acceptance): the same convergence loop across 2 engine shards.
///
/// * **exact merge** is a bitwise drop-in for the single-engine session —
///   with a balanced plan (4 workers / 2 shards) *and* under induced
///   imbalance (3 workers / 2 shards), because stolen blocks keep their
///   global merge slots;
/// * steal counters fire **only** under the induced imbalance;
/// * pruning is live on **every** shard (per-shard `records_pruned > 0`);
/// * **representative merge** converges with a finite, recorded objective
///   delta and lands within the documented 1e-2 squared-shift tolerance of
///   the exact centers (EXPERIMENTS.md §Sharding).
#[test]
fn mini_scale_session_sharded_merges() {
    let twin = session_twin_setup("sharded");
    let native: Arc<dyn KernelBackend> = Arc::new(NativeBackend);

    let single = run_twin_arm(&twin, Arc::clone(&native), &PruneConfig::default());

    let run_sharded = |workers: usize, merge: ShardMergeMode, params: &FcmParams, prune: &PruneConfig| {
        let opts = EngineOptions { workers, ..twin.opts.clone() };
        let mut engine =
            ShardedEngine::new(&twin.store, &opts, OverheadConfig::default(), 2, 4.0);
        run_fcm_session_sharded(
            &mut engine,
            &twin.store,
            Arc::clone(&native),
            SessionAlgo::Fcm,
            twin.v0.clone(),
            params,
            prune,
            SessionOptions::default(),
            None,
            merge,
        )
        .unwrap()
    };

    // Balanced plan: 12 blocks / 2 shards / 4 workers — no steal pressure.
    let exact = run_sharded(4, ShardMergeMode::Exact, &twin.params, &PruneConfig::default());
    assert_eq!(
        exact.run.result.centers.as_slice(),
        single.result.centers.as_slice(),
        "sharded exact merge is not a bitwise drop-in"
    );
    assert_eq!(exact.shard_steals, 0, "balanced 4-worker/2-shard plan must not steal");
    assert_eq!(exact.merge_objective_delta_max, 0.0);
    assert_eq!(exact.records_pruned_per_shard.len(), 2);
    for (i, &p) in exact.records_pruned_per_shard.iter().enumerate() {
        assert!(p > 0, "shard {i} never pruned — slab not shard-resident?");
    }

    // Induced imbalance: 3 workers split 2/1, so shard 1 would finish its
    // half of the store at half shard 0's rate — the plan must steal, the
    // stolen bytes must be metered, and the result must stay bitwise.
    let skew = run_sharded(3, ShardMergeMode::Exact, &twin.params, &PruneConfig::default());
    assert!(skew.shard_steals > 0, "2/1 worker split induced no steals");
    assert!(skew.shard_steal_bytes > 0, "steals metered no bytes");
    assert_eq!(
        skew.run.result.centers.as_slice(),
        single.result.centers.as_slice(),
        "stolen blocks broke bitwise exactness — global slots not kept?"
    );

    // Representative exchange: centers + fuzzy counts per shard. Epsilon
    // relaxed to the reconstruction noise floor; the objective delta is
    // measured every iteration against an uncharged exact merge.
    let rep_params = FcmParams { epsilon: 1e-7, ..twin.params };
    let rep =
        run_sharded(4, ShardMergeMode::Representative, &rep_params, &PruneConfig::disabled());
    assert!(rep.run.result.converged, "representative arm did not converge");
    assert!(
        rep.merge_objective_delta.is_finite() && rep.merge_objective_delta >= 0.0,
        "objective delta not recorded"
    );
    assert!(rep.merge_objective_delta_max >= rep.merge_objective_delta);
    let shift = max_center_shift2(&single.result.centers, &rep.run.result.centers);
    assert!(
        shift < 1e-2,
        "representative merge drifted {shift} beyond the documented 1e-2 tolerance"
    );

    std::fs::remove_dir_all(&twin.dir).ok();
}

#[test]
fn mini_scale_second_pass_reuses_warm_budget() {
    // Second job over the same store: the cache can only retain `budget`
    // bytes, so warm hits are at most the budget's worth of blocks and the
    // envelope still holds across jobs.
    let workers = 2usize;
    let (store, dir) = disk_store(16, 2048, 6, workers, "second");
    let block_bytes = store.max_block_bytes();
    let budget = 3 * block_bytes;
    let opts = EngineOptions {
        workers,
        block_cache_bytes: budget,
        ..Default::default()
    };
    let mut engine = Engine::new(opts, OverheadConfig::default());
    let cache = Arc::new(DistributedCache::new());
    let (out1, _) = engine.run_job(Arc::new(SpinSum), &store, Arc::clone(&cache)).unwrap();
    let (out2, stats2) = engine.run_job(Arc::new(SpinSum), &store, cache).unwrap();
    assert_eq!(out1.1, out2.1);
    assert!((out1.0 - out2.0).abs() <= 1e-9 * out1.0.abs().max(1.0));
    assert_eq!(stats2.locality_hits + stats2.locality_steals, 16);
    let bc = engine.block_cache();
    assert!(bc.peak_resident_bytes() <= budget + workers as u64 * block_bytes);
    assert!(bc.cached_bytes() <= budget);

    std::fs::remove_dir_all(dir).ok();
}
