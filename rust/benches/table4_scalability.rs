//! `cargo bench --bench table4_scalability` — regenerates time-vs-data-size
//! (paper Table 4) and the Figure 3 series.
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table4 --full`.

use bigfcm::bench::tables::{fig3, table4, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table4(&ctx) {
        Ok(table) => println!("{table}"),
        Err(e) => {
            eprintln!("table4 failed: {e}");
            std::process::exit(1);
        }
    }
    // Figure 3: the same sweep as series (the paper plots BigFCM ×100 for
    // visibility; we print raw values plus the ×100 column).
    match fig3(&ctx) {
        Ok(series) => {
            println!("\n== Figure 3 series (SUSY-like, C=6, eps=5e-11) ==");
            println!(
                "{:>10} {:>12} {:>14} {:>12} {:>12}",
                "records", "BigFCM(s)", "BigFCMx100(s)", "KM(s)", "FKM(s)"
            );
            for (n, big, km, fkm) in series {
                println!(
                    "{n:>10} {big:>12.1} {:>14.1} {km:>12.1} {fkm:>12.1}",
                    big * 100.0
                );
            }
        }
        Err(e) => {
            eprintln!("fig3 failed: {e}");
            std::process::exit(1);
        }
    }
    println!("regenerated in {:.1?}", t0.elapsed());
}
