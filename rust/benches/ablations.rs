//! `cargo bench --bench ablations` — the design-choice ablations of
//! DESIGN.md §6: driver pre-clustering on/off, fast-vs-classic FCM update,
//! weighted-vs-unweighted reduce merge.

use bigfcm::bench::tables::{ablation_driver, ablation_fast_vs_classic, ablation_weighted_merge, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    for result in [
        ablation_driver(&ctx),
        ablation_fast_vs_classic(&ctx),
        ablation_weighted_merge(&ctx),
    ] {
        match result {
            Ok(table) => println!("{table}"),
            Err(e) => {
                eprintln!("ablation failed: {e}");
                std::process::exit(1);
            }
        }
    }
    println!("regenerated in {:.1?}", t0.elapsed());
}
