//! `cargo bench --bench table5_clusters` — regenerates time vs cluster count (paper Table 5).
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table5 --full`.

use bigfcm::bench::tables::{table5, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table5(&ctx) {
        Ok(table) => {
            println!("{table}");
            println!("regenerated in {:.1?}", t0.elapsed());
        }
        Err(e) => {
            eprintln!("table5_clusters failed: {e}");
            std::process::exit(1);
        }
    }
}
