//! `cargo bench --bench table2_driver_epsilon` — regenerates driver-epsilon sweep (paper Table 2).
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table2 --full`.

use bigfcm::bench::tables::{table2, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table2(&ctx) {
        Ok(table) => {
            println!("{table}");
            println!("regenerated in {:.1?}", t0.elapsed());
        }
        Err(e) => {
            eprintln!("table2_driver_epsilon failed: {e}");
            std::process::exit(1);
        }
    }
}
