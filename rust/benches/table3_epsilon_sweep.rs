//! `cargo bench --bench table3_epsilon_sweep` — regenerates the
//! method × epsilon grid (paper Table 3) and the Figure 2 series.
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table3 --full`.

use bigfcm::bench::tables::{fig2, table3, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table3(&ctx) {
        Ok(table) => println!("{table}"),
        Err(e) => {
            eprintln!("table3 failed: {e}");
            std::process::exit(1);
        }
    }
    // Figure 2: epsilon vs modelled time, BigFCM vs Mahout FKM on SUSY.
    match fig2(&ctx) {
        Ok(series) => {
            println!("\n== Figure 2 series (SUSY, C=2, m=2) ==");
            println!("{:>10} {:>14} {:>14}", "epsilon", "BigFCM(s)", "MahoutFKM(s)");
            for (eps, big, fkm) in series {
                println!("{eps:>10.0e} {big:>14.1} {fkm:>14.1}");
            }
        }
        Err(e) => {
            eprintln!("fig2 failed: {e}");
            std::process::exit(1);
        }
    }
    println!("regenerated in {:.1?}", t0.elapsed());
}
