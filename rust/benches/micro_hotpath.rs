//! `cargo bench --bench micro_hotpath` — micro-benchmarks of the per-chunk
//! hot path (the §Perf working set): native vs PJRT chunk step, chunk-size
//! sensitivity, and marshalling overhead. Results feed EXPERIMENTS.md §Perf.

use std::path::Path;
use std::time::Instant;

use bigfcm::data::synth::susy_like;
use bigfcm::fcm::native::fcm_partials_native;
use bigfcm::fcm::ChunkBackend;
use bigfcm::runtime::PjrtRuntime;

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up then min-of-N (robust to scheduler noise).
    f();
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    println!("{label:<44} {:>10.3} ms", best * 1e3);
    best
}

fn main() {
    let data = susy_like(65_536, 1);
    let v = data.features.slice_rows(0, 6);
    let w = vec![1.0f32; data.features.rows()];

    println!("== micro_hotpath (SUSY-like 65 536 x 18, C=6, m=2) ==");

    // Native chunk math at various slice sizes (cache behaviour).
    for rows in [4_096usize, 16_384, 65_536] {
        let x = data.features.slice_rows(0, rows);
        let ws = &w[..rows];
        bench(&format!("native fcm_partials {rows} rows"), 5, || {
            std::hint::black_box(fcm_partials_native(&x, &v, ws, 2.0));
        });
    }

    // Throughput summary for the full pass.
    let t = bench("native fcm_partials 65536 rows (again)", 5, || {
        std::hint::black_box(fcm_partials_native(&data.features, &v, &w, 2.0));
    });
    let flops = 65_536.0 * 6.0 * (3.0 * 18.0 + 8.0); // dist + um + accum est.
    println!(
        "native throughput ≈ {:.2} GFLOP/s ({:.1} Mrec/s)",
        flops / t / 1e9,
        65_536.0 / t / 1e6
    );

    // PJRT path (when artifacts exist): end-to-end chunk execution incl.
    // marshalling, and the marshalling alone.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = PjrtRuntime::open(&dir).expect("open runtime");
        bench("pjrt fcm_partials 65536 rows (16 chunks)", 3, || {
            std::hint::black_box(rt.fcm_partials(&data.features, &v, &w, 2.0).unwrap());
        });
        let x4096 = data.features.slice_rows(0, 4096);
        bench("pjrt fcm_partials 4096 rows (1 chunk)", 5, || {
            std::hint::black_box(rt.fcm_partials(&x4096, &v, &w[..4096], 2.0).unwrap());
        });
        let stats = rt.stats().unwrap();
        println!(
            "pjrt device time: {:?} over {} chunks ({:.3} ms/chunk)",
            stats.exec_time,
            stats.chunks,
            stats.exec_time.as_secs_f64() * 1e3 / stats.chunks.max(1) as f64
        );
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT rows)");
    }
}
