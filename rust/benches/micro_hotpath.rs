//! `cargo bench --bench micro_hotpath` — micro-benchmarks of the per-chunk
//! hot path (the §Perf working set): scalar vs tiled native chunk step
//! (an honest same-run A/B), chunk-size sensitivity, PJRT marshalling
//! overhead, and the **session-vs-per-job A/B** (iteration-resident
//! session with pruning + tree combine against the Mahout-style
//! one-job-per-iteration control, same seeds, same store). Results feed
//! EXPERIMENTS.md §Perf / §Iteration-residency and are also emitted as
//! machine-readable `BENCH_micro_hotpath.json` (label → best-of-N seconds,
//! Mrec/s, plus the `session` counter object) so the perf trajectory is
//! tracked across PRs.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use bigfcm::config::{params_hash, OverheadConfig, QuantMode};
use bigfcm::data::synth::susy_like;
use bigfcm::data::Matrix;
use bigfcm::fcm::loops::{
    run_fcm_session, run_fcm_session_sharded, FcmParams, PruneConfig, SessionAlgo,
};
use bigfcm::fcm::native::{fcm_partials_native, fcm_partials_scalar};
use bigfcm::fcm::{Kernel, KernelBackend, NativeBackend};
use bigfcm::hdfs::BlockStore;
use bigfcm::json;
use bigfcm::mapreduce::{Engine, EngineOptions, SessionOptions, ShardMergeMode, ShardedEngine};
use bigfcm::runtime::{PjrtRuntime, PjrtShimBackend};

const N: usize = 65_536;

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up then min-of-N (robust to scheduler noise).
    f();
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    println!("{label:<44} {:>10.3} ms", best * 1e3);
    best
}

/// (json key, best seconds, rows processed per pass).
struct Row {
    key: &'static str,
    best_s: f64,
    rows: usize,
}

fn main() {
    let data = susy_like(N, 1);
    let v = data.features.slice_rows(0, 6);
    let w = vec![1.0f32; data.features.rows()];
    let mut rows_out: Vec<Row> = Vec::new();

    println!("== micro_hotpath (SUSY-like 65 536 x 18, C=6, m=2) ==");

    // The A/B: scalar reference vs tiled kernel on the identical full pass.
    let t_scalar = bench("scalar fcm_partials 65536 rows", 5, || {
        std::hint::black_box(fcm_partials_scalar(&data.features, &v, &w, 2.0));
    });
    rows_out.push(Row { key: "scalar_fcm_65536", best_s: t_scalar, rows: N });

    // Tiled chunk math at various slice sizes (cache behaviour).
    for rows in [4_096usize, 16_384, 65_536] {
        let x = data.features.slice_rows(0, rows);
        let ws = &w[..rows];
        let t = bench(&format!("tiled fcm_partials {rows} rows"), 5, || {
            std::hint::black_box(fcm_partials_native(&x, &v, ws, 2.0));
        });
        match rows {
            4_096 => rows_out.push(Row { key: "tiled_fcm_4096", best_s: t, rows }),
            16_384 => rows_out.push(Row { key: "tiled_fcm_16384", best_s: t, rows }),
            _ => rows_out.push(Row { key: "tiled_fcm_65536", best_s: t, rows }),
        }
    }

    // Generic-m arm (powf path) at full size.
    let t_m28 = bench("tiled fcm_partials 65536 rows (m=2.8)", 5, || {
        std::hint::black_box(fcm_partials_native(&data.features, &v, &w, 2.8));
    });
    rows_out.push(Row { key: "tiled_fcm_65536_m2.8", best_s: t_m28, rows: N });

    // Serving-path kernel (`score_chunk`, crate::serve hot path): the
    // native direct membership kernel vs the shim's padded-chunk
    // derivation over the same rows.
    let mut u = Matrix::zeros(N, 6);
    let t_score = bench("score_chunk 65536 rows (native)", 5, || {
        NativeBackend.score_chunk(Kernel::FcmFast, &data.features, &v, 2.0, &mut u).unwrap();
        std::hint::black_box(u.get(0, 0));
    });
    rows_out.push(Row { key: "score_fcm_65536", best_s: t_score, rows: N });
    let shim = PjrtShimBackend::new(4096);
    let t_score_shim = bench("score_chunk 65536 rows (pjrt-shim)", 3, || {
        shim.score_chunk(Kernel::FcmFast, &data.features, &v, 2.0, &mut u).unwrap();
        std::hint::black_box(u.get(0, 0));
    });
    rows_out.push(Row { key: "score_fcm_shim_65536", best_s: t_score_shim, rows: N });

    // Throughput summary of the A/B.
    let t_tiled = rows_out
        .iter()
        .find(|r| r.key == "tiled_fcm_65536")
        .map(|r| r.best_s)
        .unwrap();
    let flops = N as f64 * 6.0 * (3.0 * 18.0 + 8.0); // dist + um + accum est.
    println!(
        "scalar throughput ≈ {:.2} GFLOP/s ({:.1} Mrec/s)",
        flops / t_scalar / 1e9,
        N as f64 / t_scalar / 1e6
    );
    println!(
        "tiled  throughput ≈ {:.2} GFLOP/s ({:.1} Mrec/s)",
        flops / t_tiled / 1e9,
        N as f64 / t_tiled / 1e6
    );
    println!("tiled vs scalar: {:.2}x", t_scalar / t_tiled);

    // PJRT path (when artifacts exist): end-to-end chunk execution incl.
    // marshalling, and the marshalling alone.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = PjrtRuntime::open(&dir).expect("open runtime");
        let t = bench("pjrt fcm_partials 65536 rows (16 chunks)", 3, || {
            std::hint::black_box(rt.fcm_partials(&data.features, &v, &w, 2.0).unwrap());
        });
        rows_out.push(Row { key: "pjrt_fcm_65536", best_s: t, rows: N });
        let x4096 = data.features.slice_rows(0, 4096);
        let t = bench("pjrt fcm_partials 4096 rows (1 chunk)", 5, || {
            std::hint::black_box(rt.fcm_partials(&x4096, &v, &w[..4096], 2.0).unwrap());
        });
        rows_out.push(Row { key: "pjrt_fcm_4096", best_s: t, rows: 4096 });
        let stats = rt.stats().unwrap();
        println!(
            "pjrt device time: {:?} over {} chunks ({:.3} ms/chunk)",
            stats.exec_time,
            stats.chunks,
            stats.exec_time.as_secs_f64() * 1e3 / stats.chunks.max(1) as f64
        );
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT rows)");
    }

    // --- Iteration-resident session vs per-job A/B ---------------------
    // Same store, same seeds, same epsilon: the Mahout-style control pays
    // job startup + flat reduce every iteration and never prunes; the
    // session charges startup once, tree-combines partials on the workers
    // and serves bounded records from the sticky slab.
    println!("\n== session vs per-job (FCM loop, 32 blocks x 2048 rows) ==");
    let store =
        Arc::new(BlockStore::in_memory("susy", &data.features, 2_048, 4).expect("shard store"));
    let mut rng = bigfcm::prng::Pcg::new(0xAB);
    let v0 = bigfcm::fcm::seeding::random_records(&data.features, 6, &mut rng);
    let params = FcmParams { epsilon: 1e-9, max_iterations: 60, ..Default::default() };
    let backend: Arc<dyn KernelBackend> = Arc::new(NativeBackend);
    let overhead = OverheadConfig::default();

    let mut per_job_engine = Engine::new(EngineOptions::default(), overhead.clone());
    let per_job = run_fcm_session(
        &mut per_job_engine,
        &store,
        Arc::clone(&backend),
        SessionAlgo::Fcm,
        v0.clone(),
        &params,
        &PruneConfig::disabled(),
        SessionOptions::per_job(),
        None,
    )
    .expect("per-job arm");

    let mut dmin_engine = Engine::new(EngineOptions::default(), overhead.clone());
    let session_dmin = run_fcm_session(
        &mut dmin_engine,
        &store,
        Arc::clone(&backend),
        SessionAlgo::Fcm,
        v0.clone(),
        &params,
        &PruneConfig::dmin(),
        SessionOptions::default(),
        None,
    )
    .expect("dmin session arm");

    let mut session_engine = Engine::new(EngineOptions::default(), overhead.clone());
    let session = run_fcm_session(
        &mut session_engine,
        &store,
        Arc::clone(&backend),
        SessionAlgo::Fcm,
        v0.clone(),
        &params,
        &PruneConfig::default(), // elkan bounds
        SessionOptions::default(),
        None,
    )
    .expect("session arm");

    // Quant A/B arm: same elkan bounds plus the certified i8 pre-pass.
    // The second-chance test only runs on records the shift bound
    // abandons, so its prune count dominates the plain-elkan arm's by
    // construction — bench_diff.sh flags any run where it does not.
    let mut quant_engine = Engine::new(EngineOptions::default(), overhead.clone());
    let session_quant = run_fcm_session(
        &mut quant_engine,
        &store,
        Arc::clone(&backend),
        SessionAlgo::Fcm,
        v0.clone(),
        &params,
        &PruneConfig { quant: QuantMode::I8, ..PruneConfig::default() },
        SessionOptions::default(),
        None,
    )
    .expect("quant session arm");

    // Sharded A/B arm: the identical elkan session across 2 engine shards
    // with the exact two-level merge — bitwise the single-engine arm's
    // result, while startup is charged once per shard and the merged
    // modelled time takes the critical shard (wall = max over shards).
    let mut sharded_engine =
        ShardedEngine::new(&store, &EngineOptions::default(), overhead.clone(), 2, 4.0);
    let session_sharded = run_fcm_session_sharded(
        &mut sharded_engine,
        &store,
        backend,
        SessionAlgo::Fcm,
        v0,
        &params,
        &PruneConfig::default(),
        SessionOptions::default(),
        None,
        ShardMergeMode::Exact,
    )
    .expect("sharded session arm");

    let wall_sum = |runs: &[bigfcm::mapreduce::JobStats]| -> f64 {
        runs.iter().map(|s| s.reduce_wall_s).sum()
    };
    let per_job_reduce_wall = wall_sum(&per_job.per_iteration);
    let session_reduce_wall = wall_sum(&session.per_iteration);
    let combine_depth = session
        .per_iteration
        .iter()
        .map(|s| s.combine_depth)
        .max()
        .unwrap_or(0);
    // Modelled reduce wall scales the measured reduce seconds by the
    // calibrated compute factor — the comparison the session claim is
    // about (per-iteration parts funneled: O(blocks) vs O(log blocks)).
    let scale = overhead.compute_scale;
    println!(
        "per-job: {} jobs, reduce wall {:.3} ms (modelled {:.3} ms), modelled total {:.0}s, objective {:.3e}",
        per_job.jobs,
        per_job_reduce_wall * 1e3,
        per_job_reduce_wall * scale * 1e3,
        per_job.sim.total_s(),
        per_job.result.objective
    );
    println!(
        "session: {} jobs, reduce wall {:.3} ms (modelled {:.3} ms), modelled total {:.0}s, objective {:.3e}",
        session.jobs,
        session_reduce_wall * 1e3,
        session_reduce_wall * scale * 1e3,
        session.sim.total_s(),
        session.result.objective
    );
    println!(
        "session counters: records_pruned {}, combine depth {}, reduce parts/iter {} -> {}",
        session.records_pruned,
        combine_depth,
        per_job.per_iteration.first().map(|s| s.reduce_parts).unwrap_or(0),
        session.per_iteration.first().map(|s| s.reduce_parts).unwrap_or(0),
    );
    // Bound-model A/B (same store, seeds and epsilon): the per-center
    // elkan bounds should prune at least as many records as the single
    // d_min bound, at identical convergence.
    println!(
        "bounds A/B: dmin pruned {} over {} jobs, elkan pruned {} over {} jobs",
        session_dmin.records_pruned, session_dmin.jobs, session.records_pruned, session.jobs,
    );
    println!(
        "quant A/B: elkan+i8 pruned {} ({} via quant second chance) over {} jobs, \
         sidecar peak {} B built in {:.3}s",
        session_quant.records_pruned,
        session_quant.records_pruned_quant,
        session_quant.jobs,
        session_quant.quant_sidecar_bytes,
        session_quant.quant_build_s,
    );
    let steal_ratio = session_sharded.shard_steals as f64
        / sharded_engine.plan().total_blocks.max(1) as f64;
    println!(
        "sharded A/B: 2 shards exact merge, bitwise match {}, steals {} \
         (ratio {:.3}, {} B), modelled total {:.0}s (single-engine {:.0}s)",
        session_sharded.run.result.centers.as_slice() == session.result.centers.as_slice(),
        session_sharded.shard_steals,
        steal_ratio,
        session_sharded.shard_steal_bytes,
        session_sharded.run.sim.total_s(),
        session.sim.total_s(),
    );

    // Tracing overhead A/B (observability acceptance gate: the disabled
    // tracer must cost ≤ 3% on the hot path). Same chunked tiled-kernel
    // pass both times — one span per chunk, the way map tasks trace —
    // with the global tracer off, then on.
    let chunk = 4_096usize;
    let chunks: Vec<Matrix> = (0..N / chunk)
        .map(|i| data.features.slice_rows(i * chunk, (i + 1) * chunk))
        .collect();
    let tracer = bigfcm::telemetry::trace::global();
    let mut chunked_pass = || {
        for (i, x) in chunks.iter().enumerate() {
            let mut span = tracer.span("map_task", "bench");
            span.attr("block", i.to_string());
            std::hint::black_box(fcm_partials_native(x, &v, &w[..chunk], 2.0));
        }
    };
    tracer.enable(false);
    let t_trace_off = bench("chunked pass (16 spans), tracing off", 5, &mut chunked_pass);
    tracer.enable(true);
    let t_trace_on = bench("chunked pass (16 spans), tracing on", 5, &mut chunked_pass);
    tracer.enable(false);
    let trace_spans = tracer.drain().spans.len();
    let trace_overhead = t_trace_on / t_trace_off - 1.0;
    println!(
        "trace A/B: off {:.3} ms, on {:.3} ms ({:+.2}% overhead, {} spans recorded)",
        t_trace_off * 1e3,
        t_trace_on * 1e3,
        trace_overhead * 100.0,
        trace_spans,
    );

    // Machine-readable emission for cross-PR tracking.
    let results = json::Value::Object(
        rows_out
            .iter()
            .map(|r| {
                (
                    r.key.to_string(),
                    json::obj(vec![
                        ("best_s", json::num(r.best_s)),
                        ("mrec_per_s", json::num(r.rows as f64 / r.best_s / 1e6)),
                    ]),
                )
            })
            .collect(),
    );
    let session_obj = json::obj(vec![
        ("per_job_jobs", json::num(per_job.jobs as f64)),
        ("session_jobs", json::num(session.jobs as f64)),
        ("per_job_reduce_wall_s", json::num(per_job_reduce_wall)),
        ("session_reduce_wall_s", json::num(session_reduce_wall)),
        ("per_job_modelled_s", json::num(per_job.sim.total_s())),
        ("session_modelled_s", json::num(session.sim.total_s())),
        ("records_pruned", json::num(session.records_pruned as f64)),
        ("records_pruned_dmin", json::num(session_dmin.records_pruned as f64)),
        ("records_pruned_elkan", json::num(session.records_pruned as f64)),
        ("records_pruned_elkan_quant", json::num(session_quant.records_pruned as f64)),
        ("records_pruned_quant", json::num(session_quant.records_pruned_quant as f64)),
        ("quant_sidecar_bytes", json::num(session_quant.quant_sidecar_bytes as f64)),
        ("quant_build_s", json::num(session_quant.quant_build_s)),
        ("quant_modelled_s", json::num(session_quant.sim.total_s())),
        ("quant_objective", json::num(session_quant.result.objective)),
        ("dmin_modelled_s", json::num(session_dmin.sim.total_s())),
        ("slab_spilled_bytes", json::num(session.slab_spilled_bytes as f64)),
        ("slab_reloads", json::num(session.slab_reloads as f64)),
        // Recovery counters: all zero on fault-free bench runs, but kept in
        // the trajectory so a chaos-configured run diffs cleanly and
        // bench_diff.sh can flag retries that became aborts.
        (
            "read_retries",
            json::num(session.per_iteration.iter().map(|s| s.read_retries).sum::<u64>() as f64),
        ),
        (
            "read_aborts",
            json::num(session.per_iteration.iter().map(|s| s.read_aborts).sum::<u64>() as f64),
        ),
        (
            "quarantines",
            json::num(session.per_iteration.iter().map(|s| s.quarantines).sum::<u64>() as f64),
        ),
        (
            "prefetch_errors",
            json::num(session.per_iteration.iter().map(|s| s.prefetch_errors).sum::<u64>() as f64),
        ),
        ("slab_spill_retries", json::num(session.slab_spill_retries as f64)),
        ("slab_spill_quarantines", json::num(session.slab_spill_quarantines as f64)),
        ("backoff_s", json::num(session.sim.backoff_s)),
        ("checkpoints_written", json::num(session.checkpoints_written as f64)),
        ("combine_depth", json::num(combine_depth as f64)),
        ("per_job_objective", json::num(per_job.result.objective)),
        ("session_objective", json::num(session.result.objective)),
        // Sharded scale-out trajectory: steal volume is a topology property
        // (plan-time rebalance), so a ratio drift flags a scheduler change;
        // the modelled time is the wall = max-over-shards headline.
        ("shard_steals", json::num(session_sharded.shard_steals as f64)),
        ("shard_steal_ratio", json::num(steal_ratio)),
        ("sharded_modelled_s", json::num(session_sharded.run.sim.total_s())),
        ("sharded_objective", json::num(session_sharded.run.result.objective)),
    ]);
    // Config/params fingerprint: bench_diff.sh refuses to diff two BENCH
    // files whose hashes disagree (apples-to-oranges guard). The hash
    // covers the hard-coded workload knobs of the session A/B above,
    // including the sharded arm's topology (shards, merge mode, penalty).
    let hash =
        params_hash("fcm", "elkan", QuantMode::I8.as_str(), 4, 0xAB, 2, ShardMergeMode::Exact, 4.0);
    let doc = json::obj(vec![
        ("bench", json::s("micro_hotpath")),
        ("workload", json::s("susy_like 65536x18 C=6")),
        ("config_hash", json::s(&hash)),
        ("results", results),
        ("session", session_obj),
        (
            "trace",
            json::obj(vec![
                ("off_s", json::num(t_trace_off)),
                ("on_s", json::num(t_trace_on)),
                ("overhead_frac", json::num(trace_overhead)),
                ("spans", json::num(trace_spans as f64)),
            ]),
        ),
    ]);
    let path = "BENCH_micro_hotpath.json";
    match std::fs::write(path, json::to_string(&doc)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
