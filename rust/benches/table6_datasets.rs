//! `cargo bench --bench table6_datasets` — regenerates cross-dataset comparison (paper Table 6).
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table6 --full`.

use bigfcm::bench::tables::{table6, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table6(&ctx) {
        Ok(table) => {
            println!("{table}");
            println!("regenerated in {:.1?}", t0.elapsed());
        }
        Err(e) => {
            eprintln!("table6_datasets failed: {e}");
            std::process::exit(1);
        }
    }
}
