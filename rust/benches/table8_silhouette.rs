//! `cargo bench --bench table8_silhouette` — regenerates silhouette width (paper Table 8).
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table8 --full`.

use bigfcm::bench::tables::{table8, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table8(&ctx) {
        Ok(table) => {
            println!("{table}");
            println!("regenerated in {:.1?}", t0.elapsed());
        }
        Err(e) => {
            eprintln!("table8_silhouette failed: {e}");
            std::process::exit(1);
        }
    }
}
