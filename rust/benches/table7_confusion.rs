//! `cargo bench --bench table7_confusion` — regenerates confusion-matrix accuracy (paper Table 7).
//!
//! Quick scale by default; run the heavier sweep with
//! `target/release/bigfcm bench --exp table7 --full`.

use bigfcm::bench::tables::{table7, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let ctx = Ctx::quick();
    match table7(&ctx) {
        Ok(table) => {
            println!("{table}");
            println!("regenerated in {:.1?}", t0.elapsed());
        }
        Err(e) => {
            eprintln!("table7_confusion failed: {e}");
            std::process::exit(1);
        }
    }
}
