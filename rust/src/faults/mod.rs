//! Deterministic chaos layer: seeded fault injection at every I/O boundary.
//!
//! BigFCM's premise is that the MapReduce substrate makes FCM practical on
//! *unreliable* commodity clusters, so the reproduction needs faults it can
//! actually study. A [`FaultPlan`] is built once from the `[faults]` config
//! section and threaded (as `Option<Arc<FaultPlan>>`) into every layer that
//! touches a real or modelled device: block-store/cache reads, the slab's
//! spill ring, model-bundle loads, the prefetcher, map-task bodies and
//! serve-front connections. Each site calls [`FaultPlan::check`] per
//! operation; `None` (the `[faults]`-absent default everywhere) is a single
//! `Option` test on the hot path.
//!
//! Determinism is the whole point: the decision for operation *n* at a site
//! is a pure hash of `(seed, site, n)` — independent of thread scheduling
//! wherever the op counter itself is drawn deterministically (the engine
//! pre-draws map-task faults in task order; read sites draw per block read,
//! which chaos tests pin by fixing the access sequence). Same seed ⇒ same
//! fault schedule ⇒ every chaos run is replayable.
//!
//! Recovery at the sites is bounded, never best-effort-forever: transient
//! read faults retry up to [`MAX_READ_RETRIES`] times with the modelled
//! exponential backoff of [`backoff_s`] charged to the [`SimClock`]'s
//! `backoff_s` cost class (cluster time, not wall time — retries are cheap
//! to simulate and expensive on a real cluster); detected corruption gets
//! exactly one quarantine re-read before the site's degraded path engages
//! (spill slots recompute, cache blocks refetch, bundle loads fail loudly).
//!
//! [`SimClock`]: crate::mapreduce::SimClock

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::FaultsConfig;
use crate::error::{Error, Result};

/// Bounded retry budget for transient read faults: the first read plus this
/// many retries, after which the site degrades (recompute / refetch / error).
pub const MAX_READ_RETRIES: u32 = 3;

/// Modelled exponential backoff before retry `attempt` (1-based), in
/// simulated cluster seconds: 0.1 s, 0.2 s, 0.4 s, … The schedule is charged
/// to the clock, never slept — consistent with every other `SimClock` cost.
pub fn backoff_s(attempt: u32) -> f64 {
    0.1 * f64::from(1u32 << (attempt.saturating_sub(1)).min(16))
}

/// Total modelled backoff of `n` consecutive retry attempts (1..=n) — the
/// closed form the property tests assert the clock charge against.
pub fn backoff_total_s(attempts: u32) -> f64 {
    (1..=attempts).map(backoff_s).sum()
}

/// The injectable fault sites — one per I/O boundary the layers expose.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// `BlockStore`/`BlockCache` demand read of a record block.
    BlockRead,
    /// Slab spill-ring slot read (session state reload).
    SpillRead,
    /// Slab spill-ring slot write.
    SpillWrite,
    /// `ModelBundle` load from disk.
    BundleLoad,
    /// Prefetcher background read (advisory — never retried).
    Prefetch,
    /// Map-task body (worker-task failure, pre-drawn per task attempt).
    MapTask,
    /// Serve-front connection handling.
    Connection,
}

/// Every site, in the fixed order the per-site rate/counter arrays use.
pub const ALL_SITES: [FaultSite; 7] = [
    FaultSite::BlockRead,
    FaultSite::SpillRead,
    FaultSite::SpillWrite,
    FaultSite::BundleLoad,
    FaultSite::Prefetch,
    FaultSite::MapTask,
    FaultSite::Connection,
];

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::BlockRead => 0,
            FaultSite::SpillRead => 1,
            FaultSite::SpillWrite => 2,
            FaultSite::BundleLoad => 3,
            FaultSite::Prefetch => 4,
            FaultSite::MapTask => 5,
            FaultSite::Connection => 6,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::BlockRead => "block_read",
            FaultSite::SpillRead => "spill_read",
            FaultSite::SpillWrite => "spill_write",
            FaultSite::BundleLoad => "bundle_load",
            FaultSite::Prefetch => "prefetch",
            FaultSite::MapTask => "map_task",
            FaultSite::Connection => "connection",
        }
    }
}

impl std::str::FromStr for FaultSite {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        ALL_SITES
            .into_iter()
            .find(|site| site.as_str() == s)
            .ok_or_else(|| {
                Error::InvalidArgument(format!(
                    "unknown fault site `{s}` (block_read|spill_read|spill_write|bundle_load|prefetch|map_task|connection)"
                ))
            })
    }
}

/// A named fault kind, decided deterministically at [`FaultPlan::check`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Injected {
    /// Transient I/O error — the read/write fails once; retry may succeed.
    TransientIo,
    /// Bit-flip corruption — the payload arrives, checksum-detectably torn.
    Corrupt,
    /// Latency spike of this many microseconds (charged, not slept).
    Latency(u64),
    /// Connection drop — the peer goes away mid-exchange.
    ConnDrop,
    /// Worker-task failure — the map attempt dies and is re-executed.
    TaskFail,
}

/// SplitMix64 finalizer — the one hash the whole schedule derives from.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform draw in [0, 1) from `(seed, site, op, salt)` — pure, replayable.
fn draw(seed: u64, site: usize, op: u64, salt: u64) -> f64 {
    let h = mix(seed ^ (site as u64).wrapping_mul(0xA076_1D64_78BD_642F) ^ op ^ salt.wrapping_mul(0xE703_7ED1_A0B4_28DB));
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The seeded fault schedule. Immutable after construction except for the
/// per-site op/injection counters, so it is shared as `Arc<FaultPlan>`
/// across the engine, slab, serve front and tests.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rates: [f64; ALL_SITES.len()],
    /// Probability an injected read fault is corruption (vs transient I/O).
    corrupt: f64,
    /// Latency-spike magnitude for connection faults, microseconds.
    latency_us: u64,
    /// Deterministic "trip at the Nth op of this site" schedule (0-based).
    trip: Option<(FaultSite, u64)>,
    /// Per-site operation counters — the op index of the next check.
    ops: [AtomicU64; ALL_SITES.len()],
    /// Per-site injected-fault counters (observability / test assertions).
    injected: [AtomicU64; ALL_SITES.len()],
}

impl FaultPlan {
    /// Build a plan from the `[faults]` config section; `None` when the
    /// section is absent/inert, so every site's check compiles down to one
    /// `Option` test with no plan allocated at all.
    pub fn from_config(cfg: &FaultsConfig) -> Result<Option<Arc<FaultPlan>>> {
        if !cfg.enabled() {
            return Ok(None);
        }
        let trip = if cfg.trip_site.is_empty() {
            None
        } else {
            Some((cfg.trip_site.parse::<FaultSite>()?, cfg.trip_at))
        };
        let mut rates = [0.0; ALL_SITES.len()];
        rates[FaultSite::BlockRead.index()] = cfg.block_read;
        rates[FaultSite::SpillRead.index()] = cfg.spill_read;
        rates[FaultSite::SpillWrite.index()] = cfg.spill_write;
        rates[FaultSite::BundleLoad.index()] = cfg.bundle_load;
        rates[FaultSite::Prefetch.index()] = cfg.prefetch;
        rates[FaultSite::MapTask.index()] = cfg.map_task;
        rates[FaultSite::Connection.index()] = cfg.connection;
        Ok(Some(Arc::new(FaultPlan {
            seed: cfg.seed,
            rates,
            corrupt: cfg.corrupt,
            latency_us: cfg.latency_us,
            trip,
            ops: Default::default(),
            injected: Default::default(),
        })))
    }

    /// A rate-only plan for tests: `rate` at exactly one site.
    pub fn for_site(seed: u64, site: FaultSite, rate: f64, corrupt: f64) -> Arc<FaultPlan> {
        let mut rates = [0.0; ALL_SITES.len()];
        rates[site.index()] = rate;
        Arc::new(FaultPlan {
            seed,
            rates,
            corrupt,
            latency_us: 0,
            trip: None,
            ops: Default::default(),
            injected: Default::default(),
        })
    }

    /// A schedule-only plan for tests: trip exactly the `at`-th operation
    /// (0-based) of `site`, nothing else, ever.
    pub fn tripping(seed: u64, site: FaultSite, at: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            seed,
            rates: [0.0; ALL_SITES.len()],
            corrupt: 0.0,
            latency_us: 0,
            trip: Some((site, at)),
            ops: Default::default(),
            injected: Default::default(),
        })
    }

    /// Like [`Self::tripping`], but the tripped fault is a corruption —
    /// pins the checksum-quarantine paths without any statistical draw.
    pub fn tripping_corrupt(seed: u64, site: FaultSite, at: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            seed,
            rates: [0.0; ALL_SITES.len()],
            corrupt: 1.0,
            latency_us: 0,
            trip: Some((site, at)),
            ops: Default::default(),
            injected: Default::default(),
        })
    }

    /// Decide whether this operation at `site` faults, and how. Advances
    /// the site's op counter exactly once per call — a retry of the same
    /// logical read is a *new* operation, so a transient fault usually
    /// clears on retry (and a rate-1.0 site never does, pinning the
    /// exhaustion paths).
    pub fn check(&self, site: FaultSite) -> Option<Injected> {
        let i = site.index();
        let op = self.ops[i].fetch_add(1, Ordering::Relaxed);
        let tripped = self.trip == Some((site, op));
        if !tripped {
            let rate = self.rates[i];
            if rate <= 0.0 || draw(self.seed, i, op, 1) >= rate {
                return None;
            }
        }
        self.injected[i].fetch_add(1, Ordering::Relaxed);
        Some(self.kind(site, op))
    }

    /// The fault kind for an injected fault at `(site, op)` — pure.
    fn kind(&self, site: FaultSite, op: u64) -> Injected {
        match site {
            FaultSite::BlockRead | FaultSite::SpillRead | FaultSite::BundleLoad => {
                if draw(self.seed, site.index(), op, 2) < self.corrupt {
                    Injected::Corrupt
                } else {
                    Injected::TransientIo
                }
            }
            FaultSite::SpillWrite | FaultSite::Prefetch => Injected::TransientIo,
            FaultSite::MapTask => Injected::TaskFail,
            FaultSite::Connection => {
                if self.latency_us > 0 && draw(self.seed, site.index(), op, 2) < 0.5 {
                    Injected::Latency(self.latency_us)
                } else {
                    Injected::ConnDrop
                }
            }
        }
    }

    /// The plan's master seed (sites that physically corrupt bytes derive
    /// their flip position from it, keeping the whole schedule replayable).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Derive a shard-local fault domain: same rates/corruption/latency/trip
    /// schedule, seed `self.seed ⊕ shard_id`, and — critically — **fresh
    /// per-site op counters**. A sharded run that shared one plan would
    /// interleave op draws across shard threads, so the schedule would
    /// depend on scheduling; one derived plan per shard makes every shard's
    /// chaos schedule a pure function of `(faults.seed, shard_id)` and the
    /// shard's own operation order, replayable bitwise regardless of
    /// cross-shard interleaving.
    pub fn derive_for_shard(&self, shard_id: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            seed: self.seed ^ shard_id,
            rates: self.rates,
            corrupt: self.corrupt,
            latency_us: self.latency_us,
            trip: self.trip,
            ops: Default::default(),
            injected: Default::default(),
        })
    }

    /// Operations checked at `site` so far.
    pub fn ops_at(&self, site: FaultSite) -> u64 {
        self.ops[site.index()].load(Ordering::Relaxed)
    }

    /// Faults injected at `site` so far.
    pub fn injected_at(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::Relaxed)
    }

    /// Faults injected across all sites.
    pub fn injected_total(&self) -> u64 {
        ALL_SITES.iter().map(|&s| self.injected_at(s)).sum()
    }
}

/// Flip one payload byte — the canonical "torn bytes" simulation for
/// [`Injected::Corrupt`]: the real checksum machinery at the site must
/// detect it, which is exactly what the quarantine paths exercise.
pub fn corrupt_image(img: &mut [u8], seed: u64) {
    if img.is_empty() {
        return;
    }
    let at = (mix(seed) as usize) % img.len();
    img[at] ^= 0x40;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schedule(plan: &FaultPlan, site: FaultSite, n: usize) -> Vec<Option<Injected>> {
        (0..n).map(|_| plan.check(site)).collect()
    }

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::for_site(42, FaultSite::BlockRead, 0.3, 0.5);
        let b = FaultPlan::for_site(42, FaultSite::BlockRead, 0.3, 0.5);
        assert_eq!(
            schedule(&a, FaultSite::BlockRead, 500),
            schedule(&b, FaultSite::BlockRead, 500)
        );
        assert!(a.injected_at(FaultSite::BlockRead) > 0, "rate 0.3 over 500 ops must fire");
        let c = FaultPlan::for_site(43, FaultSite::BlockRead, 0.3, 0.5);
        assert_ne!(
            schedule(&a, FaultSite::BlockRead, 500),
            schedule(&c, FaultSite::BlockRead, 500),
            "different seed must shift the schedule"
        );
    }

    #[test]
    fn rate_matches_frequency_roughly() {
        let plan = FaultPlan::for_site(7, FaultSite::SpillRead, 0.2, 0.0);
        let hits = schedule(&plan, FaultSite::SpillRead, 5000)
            .iter()
            .filter(|f| f.is_some())
            .count();
        let freq = hits as f64 / 5000.0;
        assert!((freq - 0.2).abs() < 0.03, "observed rate {freq}");
    }

    #[test]
    fn zero_rate_never_fires_and_other_sites_stay_silent() {
        let plan = FaultPlan::for_site(1, FaultSite::BlockRead, 1.0, 0.0);
        for _ in 0..100 {
            assert_eq!(plan.check(FaultSite::SpillRead), None);
            assert_eq!(plan.check(FaultSite::MapTask), None);
        }
        assert_eq!(plan.injected_total(), 0);
    }

    #[test]
    fn trip_fires_exactly_once_at_nth_op() {
        let plan = FaultPlan::tripping(9, FaultSite::BundleLoad, 2);
        assert_eq!(plan.check(FaultSite::BundleLoad), None);
        assert_eq!(plan.check(FaultSite::BundleLoad), None);
        assert!(plan.check(FaultSite::BundleLoad).is_some(), "op 2 must trip");
        for _ in 0..50 {
            assert_eq!(plan.check(FaultSite::BundleLoad), None);
        }
        assert_eq!(plan.injected_at(FaultSite::BundleLoad), 1);
    }

    #[test]
    fn kinds_follow_site_and_corrupt_rate() {
        let plan = FaultPlan::for_site(3, FaultSite::BlockRead, 1.0, 1.0);
        assert_eq!(plan.check(FaultSite::BlockRead), Some(Injected::Corrupt));
        let plan = FaultPlan::for_site(3, FaultSite::BlockRead, 1.0, 0.0);
        assert_eq!(plan.check(FaultSite::BlockRead), Some(Injected::TransientIo));
        let plan = FaultPlan::for_site(3, FaultSite::MapTask, 1.0, 0.0);
        assert_eq!(plan.check(FaultSite::MapTask), Some(Injected::TaskFail));
        let plan = FaultPlan::for_site(3, FaultSite::Connection, 1.0, 0.0);
        assert_eq!(plan.check(FaultSite::Connection), Some(Injected::ConnDrop));
    }

    #[test]
    fn backoff_schedule_is_exponential_and_summable() {
        assert!((backoff_s(1) - 0.1).abs() < 1e-12);
        assert!((backoff_s(2) - 0.2).abs() < 1e-12);
        assert!((backoff_s(3) - 0.4).abs() < 1e-12);
        assert!((backoff_total_s(3) - 0.7).abs() < 1e-12);
        assert_eq!(backoff_total_s(0), 0.0);
    }

    #[test]
    fn corrupt_image_flips_one_byte_deterministically() {
        let orig = vec![0u8; 64];
        let mut a = orig.clone();
        let mut b = orig.clone();
        corrupt_image(&mut a, 5);
        corrupt_image(&mut b, 5);
        assert_eq!(a, b);
        let flipped = a.iter().zip(&orig).filter(|(x, y)| x != y).count();
        assert_eq!(flipped, 1);
        corrupt_image(&mut [], 5); // empty image must not panic
    }

    #[test]
    fn derived_shard_plans_are_independent_and_replayable() {
        let base = FaultPlan::for_site(42, FaultSite::BlockRead, 0.3, 0.5);
        // Shard 1/2 derive distinct seeds; re-deriving replays bitwise.
        let s1a = base.derive_for_shard(1);
        let s2 = base.derive_for_shard(2);
        let sched1a = schedule(&s1a, FaultSite::BlockRead, 300);
        let sched2 = schedule(&s2, FaultSite::BlockRead, 300);
        assert_ne!(sched1a, sched2, "shards must get distinct schedules");
        let s1b = base.derive_for_shard(1);
        assert_eq!(
            sched1a,
            schedule(&s1b, FaultSite::BlockRead, 300),
            "same (seed, shard) must replay the same schedule"
        );
        // Counters are shard-local: the base plan's op counter was never
        // advanced by the derived plans' draws.
        assert_eq!(base.ops_at(FaultSite::BlockRead), 0);
        // Shard 0 degenerates to the base schedule (seed ^ 0 == seed).
        let s0 = base.derive_for_shard(0);
        assert_eq!(
            schedule(&base, FaultSite::BlockRead, 300),
            schedule(&s0, FaultSite::BlockRead, 300)
        );
        // Trip schedules ride along per shard.
        let trip = FaultPlan::tripping(9, FaultSite::SpillRead, 1).derive_for_shard(3);
        assert_eq!(trip.check(FaultSite::SpillRead), None);
        assert!(trip.check(FaultSite::SpillRead).is_some());
    }

    #[test]
    fn config_roundtrip_builds_expected_plan() {
        let mut cfg = FaultsConfig::default();
        assert!(FaultPlan::from_config(&cfg).unwrap().is_none(), "inert section => no plan");
        cfg.seed = 11;
        cfg.block_read = 0.5;
        let plan = FaultPlan::from_config(&cfg).unwrap().expect("rates > 0 => plan");
        let mut saw = false;
        for _ in 0..50 {
            saw |= plan.check(FaultSite::BlockRead).is_some();
        }
        assert!(saw);
        cfg.block_read = 0.0;
        cfg.trip_site = "spill_read".into();
        cfg.trip_at = 0;
        let plan = FaultPlan::from_config(&cfg).unwrap().expect("trip schedule => plan");
        assert!(plan.check(FaultSite::SpillRead).is_some());
        cfg.trip_site = "bogus".into();
        assert!(FaultPlan::from_config(&cfg).is_err(), "unknown trip site must be loud");
    }
}
