//! Table/figure regeneration harness.
//!
//! Every table and figure of the paper's evaluation (§4) has a function
//! here that reruns the experiment on this machine and prints the same
//! rows the paper reports. `rust/benches/table*.rs` and the CLI
//! (`bigfcm bench --exp tableN`) both call into this module.
//!
//! Times are reported as **modelled cluster seconds** (SimClock; DESIGN.md
//! §3) next to the real wall seconds of this process — we claim shape
//! fidelity (who wins, by what factor, how it scales), not absolute equality
//! with the paper's 2016 testbed.

pub mod tables;

use std::fmt;

/// A rendered experiment table.
#[derive(Clone, Debug)]
pub struct TableReport {
    pub id: &'static str,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl TableReport {
    pub fn new(id: &'static str, title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            id,
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }
}

impl fmt::Display for TableReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "\n== {} — {} ==", self.id, self.title)?;
        // Column widths.
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |f: &mut fmt::Formatter<'_>, cells: &[String]| -> fmt::Result {
            write!(f, "|")?;
            for (i, c) in cells.iter().enumerate() {
                write!(f, " {:<width$} |", c, width = widths[i])?;
            }
            writeln!(f)
        };
        line(f, &self.header)?;
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        writeln!(f, "{}", "-".repeat(total))?;
        for row in &self.rows {
            line(f, row)?;
        }
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        Ok(())
    }
}

/// Experiment scale: quick (CI/bench default) vs full (closer to paper).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Records for SUSY-like runs.
    pub susy_n: usize,
    /// Records for HIGGS-like runs.
    pub higgs_n: usize,
    /// Records for KDD-like runs.
    pub kdd_n: usize,
    /// Iteration cap for the job-per-iteration baselines (they converge or
    /// hit this; the paper used 1000).
    pub baseline_max_iter: usize,
    /// Sizes for the Table 4 sweep.
    pub sweep: &'static [usize],
}

impl Scale {
    /// Fast preset used by `cargo bench` (finishes in minutes).
    pub fn quick() -> Self {
        Self {
            susy_n: 20_000,
            higgs_n: 20_000,
            kdd_n: 20_000,
            baseline_max_iter: 60,
            sweep: &[2_000, 4_000, 8_000, 16_000, 32_000, 64_000],
        }
    }

    /// Heavier preset (CLI `--full`): same shapes at ~10× the records.
    pub fn full() -> Self {
        Self {
            susy_n: 200_000,
            higgs_n: 200_000,
            kdd_n: 100_000,
            baseline_max_iter: 200,
            sweep: &[20_000, 40_000, 80_000, 160_000, 320_000, 640_000, 1_000_000],
        }
    }
}

/// Format modelled seconds the way the paper prints them.
pub fn fmt_s(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.0} ({:.1}h)", s, s / 3600.0)
    } else if s >= 100.0 {
        format!("{s:.0}")
    } else {
        format!("{s:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = TableReport::new("T0", "demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("hello");
        let s = format!("{t}");
        assert!(s.contains("T0"));
        assert!(s.contains("| 1"));
        assert!(s.contains("note: hello"));
    }

    #[test]
    fn fmt_s_bands() {
        assert_eq!(fmt_s(42.123), "42.1");
        assert_eq!(fmt_s(432.0), "432");
        assert!(fmt_s(7200.0).contains("2.0h"));
    }
}
