//! One function per paper table/figure. See module docs in [`crate::bench`].

use std::sync::Arc;

use crate::baselines::{run_baseline, BaselineAlgo, BaselineRun};
use crate::bench::{fmt_s, Scale, TableReport};
use crate::config::Config;
use crate::coordinator::{BigFcm, BigFcmRun};
use crate::data::{builtin, Dataset};
use crate::error::Result;
use crate::fcm::{assign_hard, KernelBackend, NativeBackend};
use crate::hdfs::BlockStore;
use crate::mapreduce::{Engine, EngineOptions};
use crate::metrics::{confusion_accuracy, silhouette_width_sampled, speedup};
use crate::prng::Pcg;

/// Shared experiment context.
pub struct Ctx {
    pub cfg: Config,
    pub scale: Scale,
    pub backend: Arc<dyn KernelBackend>,
}

impl Ctx {
    pub fn new(cfg: Config, scale: Scale, backend: Arc<dyn KernelBackend>) -> Self {
        Self { cfg, scale, backend }
    }

    /// Quick-scale context on the native backend (bench default).
    pub fn quick() -> Self {
        Self::new(Config::default(), Scale::quick(), Arc::new(NativeBackend))
    }

    fn store(&self, d: &Dataset) -> Result<Arc<BlockStore>> {
        Ok(Arc::new(BlockStore::in_memory(
            d.name.clone(),
            &d.features,
            self.cfg.cluster.block_records.min((d.rows() / 4).max(1024)),
            self.cfg.cluster.workers,
        )?))
    }

    fn engine(&self) -> Engine {
        Engine::new(EngineOptions::from_cluster(&self.cfg.cluster), self.cfg.overhead.clone())
    }

    fn bigfcm(&self, store: &Arc<BlockStore>, c: usize, m: f64, eps: f64) -> Result<BigFcmRun> {
        let mut engine = self.engine();
        BigFcm::new(self.cfg.clone())
            .backend(Arc::clone(&self.backend))
            .clusters(c)
            .fuzzifier(m)
            .epsilon(eps)
            .run_with_engine(store, &mut engine)
    }

    fn baseline(
        &self,
        algo: BaselineAlgo,
        store: &Arc<BlockStore>,
        c: usize,
        m: f64,
        eps: f64,
    ) -> Result<BaselineRun> {
        let mut cfg = self.cfg.clone();
        cfg.fcm.clusters = c;
        cfg.fcm.fuzzifier = m;
        cfg.fcm.epsilon = eps;
        cfg.fcm.max_iterations = self.scale.baseline_max_iter;
        let mut engine = self.engine();
        run_baseline(algo, &cfg, store, Arc::clone(&self.backend), &mut engine)
    }
}

// ---------------------------------------------------------------------------
// Table 2 — driver epsilon vs total time (SUSY, C=10, m=2)
// ---------------------------------------------------------------------------

pub fn table2(ctx: &Ctx) -> Result<TableReport> {
    let data = builtin::susy(ctx.scale.susy_n, ctx.cfg.seed);
    let store = ctx.store(&data)?;
    let mut t = TableReport::new(
        "Table 2",
        format!(
            "driver-epsilon sweep on {} (n={}, C=10, m=2) — modelled seconds",
            data.name,
            data.rows()
        ),
        &["Driver", "Total modelled (s)", "Wall (s)", "Combiner iters (job)", "Flag"],
    );

    // Column 1: no driver pre-clustering (random seeds).
    let mut engine = ctx.engine();
    let run = BigFcm::new(ctx.cfg.clone())
        .backend(Arc::clone(&ctx.backend))
        .clusters(10)
        .fuzzifier(2.0)
        .epsilon(5.0e-11)
        .without_driver()
        .run_with_engine(&store, &mut engine)?;
    t.row(vec![
        "random seed".into(),
        fmt_s(run.modelled_s()),
        format!("{:.2}", run.wall.as_secs_f64()),
        run.reduce_iterations.to_string(),
        "-".into(),
    ]);

    for eps in [5.0e-6, 5.0e-8, 5.0e-10, 5.0e-11] {
        let mut engine = ctx.engine();
        let run = BigFcm::new(ctx.cfg.clone())
            .backend(Arc::clone(&ctx.backend))
            .clusters(10)
            .fuzzifier(2.0)
            .epsilon(5.0e-11)
            .driver_epsilon(eps)
            .run_with_engine(&store, &mut engine)?;
        t.row(vec![
            format!("eps={eps:.0e}"),
            fmt_s(run.modelled_s()),
            format!("{:.2}", run.wall.as_secs_f64()),
            run.reduce_iterations.to_string(),
            if run.driver.flag_fcm { "FCM" } else { "WFCMPB" }.into(),
        ]);
    }
    t.note("paper: 5432s (random) -> 882s (eps=5e-11): tighter driver eps must not increase total time");
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 3 + Figure 2 — methods × epsilon on SUSY and HIGGS (C=2, m=2)
// ---------------------------------------------------------------------------

pub const TABLE3_EPSILONS: [f64; 4] = [5.0e-7, 5.0e-5, 5.0e-3, 5.0e-2];

pub fn table3(ctx: &Ctx) -> Result<TableReport> {
    let mut t = TableReport::new(
        "Table 3",
        "method x epsilon, C=2, m=2 — modelled seconds",
        &["Dataset", "Method", "eps=5e-7", "eps=5e-5", "eps=5e-3", "eps=5e-2"],
    );
    for (name, data) in [
        ("SUSY", builtin::susy(ctx.scale.susy_n, ctx.cfg.seed)),
        ("HIGGS", builtin::higgs(ctx.scale.higgs_n, ctx.cfg.seed)),
    ] {
        let store = ctx.store(&data)?;
        for method in ["Mahout FKM", "Mahout KM", "BigFCM"] {
            let mut cells = vec![name.to_string(), method.to_string()];
            for eps in TABLE3_EPSILONS {
                let s = match method {
                    "Mahout FKM" => ctx
                        .baseline(BaselineAlgo::FuzzyKMeans, &store, 2, 2.0, eps)?
                        .modelled_s(),
                    "Mahout KM" => ctx
                        .baseline(BaselineAlgo::KMeans, &store, 2, 2.0, eps)?
                        .modelled_s(),
                    _ => ctx.bigfcm(&store, 2, 2.0, eps)?.modelled_s(),
                };
                cells.push(fmt_s(s));
            }
            t.row(cells);
        }
    }
    t.note("paper shape: BigFCM flat in eps; Mahout FKM blows up as eps tightens (141887s at 5e-7 on SUSY)");
    Ok(t)
}

/// Figure 2 series: (epsilon, BigFCM modelled s, Mahout FKM modelled s) on SUSY.
pub fn fig2(ctx: &Ctx) -> Result<Vec<(f64, f64, f64)>> {
    let data = builtin::susy(ctx.scale.susy_n, ctx.cfg.seed);
    let store = ctx.store(&data)?;
    let mut out = Vec::new();
    for eps in TABLE3_EPSILONS {
        let big = ctx.bigfcm(&store, 2, 2.0, eps)?.modelled_s();
        let fkm = ctx
            .baseline(BaselineAlgo::FuzzyKMeans, &store, 2, 2.0, eps)?
            .modelled_s();
        out.push((eps, big, fkm));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 4 + Figure 3 — time vs data size (SUSY-like, C=6, eps=5e-11)
// ---------------------------------------------------------------------------

pub fn table4(ctx: &Ctx) -> Result<TableReport> {
    let mut t = TableReport::new(
        "Table 4",
        "time vs data size (C=6, eps=5e-11, m=2) — modelled seconds",
        &["Records", "~Bytes", "BigFCM (s)", "Mahout KM (s)", "Mahout FKM (s)", "KM/Big", "FKM/Big"],
    );
    for &n in ctx.scale.sweep {
        let data = builtin::susy(n, ctx.cfg.seed);
        let store = ctx.store(&data)?;
        let big = ctx.bigfcm(&store, 6, 2.0, 5.0e-11)?;
        let km = ctx.baseline(BaselineAlgo::KMeans, &store, 6, 2.0, 5.0e-11)?;
        let fkm = ctx.baseline(BaselineAlgo::FuzzyKMeans, &store, 6, 2.0, 5.0e-11)?;
        t.row(vec![
            n.to_string(),
            store.total_bytes().to_string(),
            fmt_s(big.modelled_s()),
            fmt_s(km.modelled_s()),
            fmt_s(fkm.modelled_s()),
            format!("{:.0}x", speedup(km.modelled_s(), big.modelled_s())),
            format!("{:.0}x", speedup(fkm.modelled_s(), big.modelled_s())),
        ]);
    }
    t.note("paper: 287x over KM, 493x over FKM at 4M records; BigFCM near-linear in N");
    Ok(t)
}

/// Figure 3 series: (records, BigFCM, KM, FKM) — same sweep as Table 4.
pub fn fig3(ctx: &Ctx) -> Result<Vec<(usize, f64, f64, f64)>> {
    let mut out = Vec::new();
    for &n in ctx.scale.sweep {
        let data = builtin::susy(n, ctx.cfg.seed);
        let store = ctx.store(&data)?;
        let big = ctx.bigfcm(&store, 6, 2.0, 5.0e-11)?.modelled_s();
        let km = ctx
            .baseline(BaselineAlgo::KMeans, &store, 6, 2.0, 5.0e-11)?
            .modelled_s();
        let fkm = ctx
            .baseline(BaselineAlgo::FuzzyKMeans, &store, 6, 2.0, 5.0e-11)?
            .modelled_s();
        out.push((n, big, km, fkm));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 5 — time vs number of clusters (HIGGS, eps=5e-11, m=2)
// ---------------------------------------------------------------------------

pub fn table5(ctx: &Ctx) -> Result<TableReport> {
    let data = builtin::higgs(ctx.scale.higgs_n, ctx.cfg.seed);
    let store = ctx.store(&data)?;
    let mut t = TableReport::new(
        "Table 5",
        format!("BigFCM time vs clusters on {} (n={})", data.name, data.rows()),
        &["Centroids", "Modelled (s)", "Wall (s)", "s per cluster"],
    );
    for c in [6usize, 10, 15, 50] {
        let run = ctx.bigfcm(&store, c, 2.0, 5.0e-11)?;
        t.row(vec![
            c.to_string(),
            fmt_s(run.modelled_s()),
            format!("{:.2}", run.wall.as_secs_f64()),
            format!("{:.2}", run.modelled_s() / c as f64),
        ]);
    }
    t.note("paper claim: cost grows ~linearly in C (fast O(n.c) update in the combiner)");
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 6 — cross-dataset FKM vs BigFCM
// ---------------------------------------------------------------------------

/// Per-dataset parameters from the paper's Table 6. Pima and KDD99 are
/// min-max normalised first (the paper normalises KDD99, §4.1; Pima's raw
/// feature scales differ by 300x, which would reduce Euclidean FCM to
/// clustering on serum insulin alone).
pub fn table6_datasets(ctx: &Ctx) -> Vec<(Dataset, usize, f64, f64)> {
    let normalise = |mut d: Dataset| {
        let s = crate::data::normalize::Scaler::min_max(&d.features);
        s.apply(&mut d.features);
        d
    };
    vec![
        (builtin::susy(ctx.scale.susy_n, ctx.cfg.seed), 2, 2.0, 5.0e-7),
        (builtin::higgs(ctx.scale.higgs_n, ctx.cfg.seed), 2, 2.0, 5.0e-7),
        (normalise(builtin::pima(ctx.cfg.seed)), 2, 1.2, 5.0e-2),
        (builtin::iris(), 3, 1.2, 5.0e-2),
        (normalise(builtin::kdd99(ctx.scale.kdd_n, ctx.cfg.seed)), 23, 1.2, 5.0e-7),
    ]
}

pub fn table6(ctx: &Ctx) -> Result<TableReport> {
    let mut t = TableReport::new(
        "Table 6",
        "cross-dataset modelled time, Mahout FKM vs BigFCM",
        &["Dataset", "C", "m", "eps", "Mahout FKM (s)", "BigFCM (s)", "Speedup"],
    );
    let mut speedups = Vec::new();
    for (data, c, m, eps) in table6_datasets(ctx) {
        let store = ctx.store(&data)?;
        let fkm = ctx.baseline(BaselineAlgo::FuzzyKMeans, &store, c, m, eps)?;
        let big = ctx.bigfcm(&store, c, m, eps)?;
        let sp = speedup(fkm.modelled_s(), big.modelled_s());
        speedups.push(sp);
        t.row(vec![
            data.name.clone(),
            c.to_string(),
            format!("{m}"),
            format!("{eps:.0e}"),
            fmt_s(fkm.modelled_s()),
            fmt_s(big.modelled_s()),
            format!("{sp:.1}x"),
        ]);
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    t.note(format!(
        "average speedup {avg:.1}x (paper: 5.35x-44x, average 18.22x)"
    ));
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 7 — confusion-matrix accuracy
// ---------------------------------------------------------------------------

pub fn table7(ctx: &Ctx) -> Result<TableReport> {
    let mut t = TableReport::new(
        "Table 7",
        "confusion-matrix accuracy (cluster-class matched)",
        &["Dataset", "Mahout FKM", "BigFCM", "paper FKM", "paper BigFCM"],
    );
    let paper: [(&str, &str); 5] = [
        ("50.0%", "50.0%"),
        ("50.0%", "50.0%"),
        ("65.7%", "66.1%"),
        ("89.1%", "92.0%"),
        ("78.0%", "82.0%"),
    ];
    for ((data, c, m, eps), (p_fkm, p_big)) in table6_datasets(ctx).into_iter().zip(paper) {
        let labels = data.labels.clone().expect("table7 datasets are labelled");
        let store = ctx.store(&data)?;
        let fkm = ctx.baseline(BaselineAlgo::FuzzyKMeans, &store, c, m, eps)?;
        let big = ctx.bigfcm(&store, c, m, eps)?;
        let acc_fkm = confusion_accuracy(&assign_hard(&data.features, &fkm.centers), &labels, c);
        let acc_big = confusion_accuracy(&assign_hard(&data.features, &big.centers), &labels, c);
        t.row(vec![
            data.name.clone(),
            format!("{:.1}%", acc_fkm * 100.0),
            format!("{:.1}%", acc_big * 100.0),
            p_fkm.into(),
            p_big.into(),
        ]);
    }
    t.note("shape claim: BigFCM accuracy >= FKM accuracy on every dataset");
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 8 — silhouette width on HIGGS at 1k-4k samples
// ---------------------------------------------------------------------------

pub fn table8(ctx: &Ctx) -> Result<TableReport> {
    let data = builtin::higgs(ctx.scale.higgs_n, ctx.cfg.seed);
    let store = ctx.store(&data)?;
    let mut t = TableReport::new(
        "Table 8",
        format!("silhouette width on {} (C=2, eps=5e-11, m=2)", data.name),
        &["Method", "1k", "2k", "3k", "4k"],
    );
    let fkm = ctx.baseline(BaselineAlgo::FuzzyKMeans, &store, 2, 2.0, 5.0e-11)?;
    let big = ctx.bigfcm(&store, 2, 2.0, 5.0e-11)?;
    // Mahout's coarse rounding degenerates its centers; we model that by
    // rounding FKM centers to one decimal, as the paper footnotes ("weak
    // values … due to the rounding made to enable a faster execution").
    let mut fkm_centers = fkm.centers.clone();
    for v in fkm_centers.as_mut_slice() {
        *v = (*v * 10.0).round() / 10.0;
    }
    for (label, centers) in [("Mahout FKM", &fkm_centers), ("BigFCM", &big.centers)] {
        let assign = assign_hard(&data.features, centers);
        let mut cells = vec![label.to_string()];
        for (i, k) in [1000usize, 2000, 3000, 4000].into_iter().enumerate() {
            let mut rng = Pcg::new(ctx.cfg.seed ^ (i as u64 + 1));
            let s = silhouette_width_sampled(&data.features, &assign, k, &mut rng);
            cells.push(format!("{s:.4}"));
        }
        t.row(cells);
    }
    t.note("paper: FKM 0.0 at every size; BigFCM ~0.063 (positive, stable across sample sizes)");
    Ok(t)
}

// ---------------------------------------------------------------------------
// Ablations (DESIGN.md §6)
// ---------------------------------------------------------------------------

pub fn ablation_driver(ctx: &Ctx) -> Result<TableReport> {
    let data = builtin::susy(ctx.scale.susy_n, ctx.cfg.seed);
    let store = ctx.store(&data)?;
    let mut t = TableReport::new(
        "Ablation A1",
        "driver pre-clustering on/off (SUSY, C=6, eps=5e-11)",
        &["Arm", "Modelled (s)", "Combiner iters"],
    );
    for (label, with_driver) in [("with driver", true), ("without driver", false)] {
        let mut engine = ctx.engine();
        let mut b = BigFcm::new(ctx.cfg.clone())
            .backend(Arc::clone(&ctx.backend))
            .clusters(6)
            .epsilon(5.0e-11);
        if !with_driver {
            b = b.without_driver();
        }
        let run = b.run_with_engine(&store, &mut engine)?;
        t.row(vec![
            label.into(),
            fmt_s(run.modelled_s()),
            run.reduce_iterations.to_string(),
        ]);
    }
    Ok(t)
}

pub fn ablation_fast_vs_classic(ctx: &Ctx) -> Result<TableReport> {
    use crate::fcm::loops::{run_fcm, FcmParams, Variant};
    use std::time::Instant;
    let data = builtin::susy(ctx.scale.susy_n.min(50_000), ctx.cfg.seed);
    let mut t = TableReport::new(
        "Ablation A2",
        "fast O(n.c) vs classic O(n.c^2) FCM update — wall seconds per pass, growing C",
        &["C", "fast (s)", "classic (s)", "classic/fast"],
    );
    let w = vec![1.0f32; data.rows()];
    for c in [2usize, 6, 15, 50] {
        let mut rng = Pcg::new(ctx.cfg.seed);
        let v0 = crate::fcm::seeding::random_records(&data.features, c, &mut rng);
        let params = |variant| FcmParams { epsilon: 0.0, max_iterations: 3, variant, ..Default::default() };
        let t0 = Instant::now();
        run_fcm(ctx.backend.as_ref(), &data.features, &w, v0.clone(), &params(Variant::Fast))?;
        let fast = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        run_fcm(ctx.backend.as_ref(), &data.features, &w, v0, &params(Variant::Classic))?;
        let classic = t0.elapsed().as_secs_f64();
        t.row(vec![
            c.to_string(),
            format!("{fast:.3}"),
            format!("{classic:.3}"),
            format!("{:.2}x", classic / fast.max(1e-9)),
        ]);
    }
    t.note("the gap must widen with C (paper's reason for Algorithm 1 in the combiner)");
    Ok(t)
}

pub fn ablation_weighted_merge(ctx: &Ctx) -> Result<TableReport> {
    // Does WFCM weighting in the reduce matter? Merge per-partition centers
    // with vs without weights on an *imbalanced* partitioning.
    use crate::fcm::loops::{run_fcm, FcmParams};
    let data = builtin::susy(ctx.scale.susy_n.min(40_000), ctx.cfg.seed);
    let labels_truth = data.labels.clone().unwrap();
    let mut t = TableReport::new(
        "Ablation A3",
        "weighted vs unweighted reduce merge (imbalanced partitions)",
        &["Merge", "Accuracy", "Objective"],
    );
    // Build imbalanced partitions: 90% / 10%.
    let cut = data.rows() * 9 / 10;
    let parts = [data.features.slice_rows(0, cut), data.features.slice_rows(cut, data.rows())];
    let mut rng = Pcg::new(ctx.cfg.seed);
    let seeds = crate::fcm::seeding::random_records(&data.features, 2, &mut rng);
    let params = FcmParams { epsilon: 5.0e-11, ..Default::default() };
    let mut pool = crate::data::Matrix::zeros(0, data.dims());
    let mut pool_w = Vec::new();
    for p in &parts {
        let w = vec![1.0f32; p.rows()];
        let r = run_fcm(ctx.backend.as_ref(), p, &w, seeds.clone(), &params)?;
        for i in 0..2 {
            pool.push_row(r.centers.row(i));
            pool_w.push(r.weights[i] as f32);
        }
    }
    for (label, weights) in [
        ("weighted (WFCM)", pool_w.clone()),
        ("unweighted", vec![1.0f32; pool_w.len()]),
    ] {
        let r = run_fcm(ctx.backend.as_ref(), &pool, &weights, seeds.clone(), &params)?;
        let assign = assign_hard(&data.features, &r.centers);
        let acc = confusion_accuracy(&assign, &labels_truth, 2);
        // Global objective of the merged centers.
        let w_all = vec![1.0f32; data.rows()];
        let p = ctx.backend.fcm_partials(&data.features, &r.centers, &w_all, 2.0)?;
        t.row(vec![label.into(), format!("{:.2}%", acc * 100.0), format!("{:.1}", p.objective)]);
    }
    t.note("weighted merge must not lose to unweighted (paper contribution 3)");
    Ok(t)
}

/// All tables by id (CLI dispatch).
pub fn run_by_id(id: &str, ctx: &Ctx) -> Result<Vec<TableReport>> {
    Ok(match id {
        "table2" => vec![table2(ctx)?],
        "table3" => vec![table3(ctx)?],
        "table4" => vec![table4(ctx)?],
        "table5" => vec![table5(ctx)?],
        "table6" => vec![table6(ctx)?],
        "table7" => vec![table7(ctx)?],
        "table8" => vec![table8(ctx)?],
        "ablations" => vec![
            ablation_driver(ctx)?,
            ablation_fast_vs_classic(ctx)?,
            ablation_weighted_merge(ctx)?,
        ],
        "all" => {
            let mut v = Vec::new();
            for t in ["table2", "table3", "table4", "table5", "table6", "table7", "table8"] {
                v.extend(run_by_id(t, ctx)?);
            }
            v
        }
        other => {
            return Err(crate::error::Error::InvalidArgument(format!(
                "unknown experiment `{other}` (use table2..table8, ablations, all)"
            )))
        }
    })
}
