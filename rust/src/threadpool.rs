//! A small work-stealing-free thread pool.
//!
//! The offline dependency set has neither tokio nor rayon, so the MapReduce
//! engine runs on this pool: fixed worker count (one per simulated cluster
//! node), FIFO queue, panic isolation per task, and a `scope`-style
//! `map_parallel` helper that preserves input ordering of results.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Task),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    sender: Sender<Message>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                std::thread::Builder::new()
                    .name(format!("bigfcm-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker thread")
            })
            .collect();
        Self { workers, sender }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a task.
    pub fn execute(&self, task: impl FnOnce() + Send + 'static) {
        self.sender
            .send(Message::Run(Box::new(task)))
            .expect("thread pool has shut down");
    }

    /// Run `f` over every item of `items` in parallel, returning results in
    /// input order. Panics in `f` are propagated as `Err(description)` for
    /// that item (the engine converts them into task failures).
    pub fn map_parallel<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, Result<R, String>)>, Receiver<_>) = channel();
        for (idx, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item))).map_err(describe_panic);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        collect_ordered(n, rx)
    }

    /// Run `f(0..n)` in parallel, returning results in index order — the
    /// streaming variant of [`Self::map_parallel`]: tasks are described by
    /// their index alone, so nothing per-task is materialized up front (the
    /// engine uses this to read HDFS blocks *inside* the map slot instead
    /// of pre-loading the dataset).
    pub fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<Result<R, String>>
    where
        R: Send + 'static,
        F: Fn(usize) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, Result<R, String>)>, Receiver<_>) = channel();
        for idx in 0..n {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(idx))).map_err(describe_panic);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        collect_ordered(n, rx)
    }
}

/// Render a caught panic payload as a task-failure message.
fn describe_panic(e: Box<dyn std::any::Any + Send>) -> String {
    e.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| e.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "task panicked".to_string())
}

/// Drain `(index, result)` pairs into an input-ordered vector.
fn collect_ordered<R>(n: usize, rx: Receiver<(usize, Result<R, String>)>) -> Vec<Result<R, String>> {
    let mut results: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        results[idx] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.unwrap_or_else(|| Err("worker dropped task".to_string())))
        .collect()
}

fn worker_loop(rx: Arc<Mutex<Receiver<Message>>>) {
    loop {
        let msg = {
            let guard = rx.lock().expect("poisoned pool queue");
            guard.recv()
        };
        match msg {
            Ok(Message::Run(task)) => {
                // Panic isolation: a panicking task must not kill the worker.
                let _ = catch_unwind(AssertUnwindSafe(task));
            }
            Ok(Message::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        let done = rx.iter().count();
        assert_eq!(done, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_parallel_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map_parallel((0..50).collect(), |x: i32| x * 2);
        let vals: Vec<i32> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_parallel_isolates_panics() {
        let pool = ThreadPool::new(2);
        let out = pool.map_parallel(vec![1, 2, 3, 4], |x: i32| {
            if x == 3 {
                panic!("boom {x}");
            }
            x
        });
        assert_eq!(out[0], Ok(1));
        assert_eq!(out[1], Ok(2));
        assert!(out[2].as_ref().unwrap_err().contains("boom"));
        assert_eq!(out[3], Ok(4));
        // Pool still usable after a panic.
        let again = pool.map_parallel(vec![10], |x: i32| x + 1);
        assert_eq!(again[0], Ok(11));
    }

    #[test]
    fn map_indexed_preserves_order_and_isolates_panics() {
        let pool = ThreadPool::new(3);
        let out = pool.map_indexed(20, |i| {
            if i == 7 {
                panic!("boom {i}");
            }
            i * 3
        });
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                assert!(r.as_ref().unwrap_err().contains("boom"));
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 3);
            }
        }
        assert!(pool.map_indexed::<usize, _>(0, |i| i).is_empty());
    }

    #[test]
    fn single_worker_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map_parallel(vec![5, 6], |x: i32| x);
        assert_eq!(out.len(), 2);
    }
}
