//! A small thread pool with locality-aware map scheduling.
//!
//! The offline dependency set has neither tokio nor rayon, so the MapReduce
//! engine runs on this pool: fixed worker count (one per simulated cluster
//! node), FIFO queue, panic isolation per task, and `scope`-style map
//! helpers that preserve input ordering of results. The hinted variant
//! ([`ThreadPool::map_indexed_hinted`]) models Hadoop's data-local task
//! assignment: each logical worker drains its own queue of hinted tasks and
//! steals from a neighbour only when its queue is dry.
//!
//! The combining drain ([`ThreadPool::map_indexed_hinted_combined`]) adds a
//! worker-side merge tree on top of the hinted drain: task outputs merge
//! pairwise on the pool as map slots free up, following a binary topology
//! fixed by task index (left sibling is always the left operand), so the
//! caller's reduce sees O(log n) pre-merged segments instead of n raw
//! outputs — deterministically, whatever order tasks actually complete in.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Task),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    sender: Sender<Message>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                std::thread::Builder::new()
                    .name(format!("bigfcm-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker thread")
            })
            .collect();
        Self { workers, sender }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a task.
    pub fn execute(&self, task: impl FnOnce() + Send + 'static) {
        self.sender
            .send(Message::Run(Box::new(task)))
            .expect("thread pool has shut down");
    }

    /// Run `f` over every item of `items` in parallel, returning results in
    /// input order. Panics in `f` are propagated as `Err(description)` for
    /// that item (the engine converts them into task failures).
    pub fn map_parallel<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, Result<R, String>)>, Receiver<_>) = channel();
        for (idx, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item))).map_err(describe_panic);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        collect_ordered(n, rx)
    }

    /// Run `f(0..n)` in parallel, returning results in index order — the
    /// streaming variant of [`Self::map_parallel`]: tasks are described by
    /// their index alone, so nothing per-task is materialized up front (the
    /// engine uses this to read HDFS blocks *inside* the map slot instead
    /// of pre-loading the dataset).
    pub fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<Result<R, String>>
    where
        R: Send + 'static,
        F: Fn(usize) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, Result<R, String>)>, Receiver<_>) = channel();
        for idx in 0..n {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(idx))).map_err(describe_panic);
                let _ = tx.send((idx, out));
            });
        }
        drop(tx);
        collect_ordered(n, rx)
    }

    /// Locality-aware variant of [`Self::map_indexed`]: task `i` is queued
    /// on the worker named by `hints[i]` (wrapping when the hint is out of
    /// range, so a store sharded for more workers than this pool still
    /// schedules every block). Each logical worker drains its own queue
    /// front-to-back — preserving per-worker block order, which is what
    /// makes the *next* task prefetchable — and steals from the back of the
    /// first non-dry neighbour only when its own queue is empty.
    ///
    /// `f` receives `(task, ahead)` where [`QueueAhead`] holds the one or
    /// two tasks that were next on the same queue when `task` was claimed
    /// (the engine's prefetch hints, depth 1 and 2).
    ///
    /// Returns results in index order plus the locality outcome of the
    /// whole map (own-queue claims vs steals).
    pub fn map_indexed_hinted<R, F>(
        &self,
        n: usize,
        hints: &[usize],
        f: F,
    ) -> (Vec<Result<R, String>>, LocalityStats)
    where
        R: Send + 'static,
        F: Fn(usize, QueueAhead) -> R + Send + Sync + 'static,
    {
        let size = self.size();
        let queues = build_queues(n, hints, size);
        let local_hits = Arc::new(AtomicUsize::new(0));
        let steals = Arc::new(AtomicUsize::new(0));
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, Result<R, String>)>, Receiver<_>) = channel();
        // One drain task per logical worker. Whichever pool thread picks a
        // drain task *becomes* that logical worker; with all workers idle at
        // map start (the engine runs jobs sequentially) this is one drain
        // task per thread.
        for w in 0..size {
            let queues = Arc::clone(&queues);
            let local_hits = Arc::clone(&local_hits);
            let steals = Arc::clone(&steals);
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || loop {
                let Some((id, ahead, local)) = claim_task(&queues, w, size) else { break };
                if local {
                    local_hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    steals.fetch_add(1, Ordering::Relaxed);
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(id, ahead))).map_err(describe_panic);
                let _ = tx.send((id, out));
            });
        }
        drop(tx);
        let results = collect_ordered(n, rx);
        (
            results,
            LocalityStats {
                local_hits: local_hits.load(Ordering::Relaxed),
                steals: steals.load(Ordering::Relaxed),
            },
        )
    }

    /// Combining drain: like [`Self::map_indexed_hinted`], but task outputs
    /// merge pairwise on the pool as map slots drain, following a binary
    /// tree fixed by task index — siblings `(2k, 2k+1)` merge into slot `k`
    /// of the next level, with the even (left) sibling always the left
    /// operand of `combine`. The topology and operand order depend only on
    /// `n`, so results are deterministic for any associative-over-adjacent-
    /// segments `combine`, even one that is order-sensitive (e.g. ordered
    /// concatenation), regardless of completion order.
    ///
    /// Returns the surviving segment values ordered by their leftmost task
    /// index — O(log n) of them (the root plus one lone tail per odd-width
    /// level) — with the locality and merge-tree outcomes. A panic in `f`
    /// or `combine` surfaces as the `Err` of the segment that contained it.
    pub fn map_indexed_hinted_combined<R, F, C>(
        &self,
        n: usize,
        hints: &[usize],
        f: F,
        combine: C,
    ) -> (Vec<Result<R, String>>, LocalityStats, CombineStats)
    where
        R: Send + 'static,
        F: Fn(usize, QueueAhead) -> R + Send + Sync + 'static,
        C: Fn(R, R) -> R + Send + Sync + 'static,
    {
        let slots: Vec<usize> = (0..n).collect();
        let (parts, locality, stats) =
            self.map_indexed_hinted_combined_at(n, hints, &slots, n, f, combine);
        (parts.into_iter().map(|(_, v)| v).collect(), locality, stats)
    }

    /// Sharded variant of [`Self::map_indexed_hinted_combined`]: the merge
    /// tree's slot widths come from `total` (the *global* task count of a
    /// larger map this drain is a slice of), and local task `i` enters the
    /// cascade at leaf slot `slots[i]` instead of `i`. Pairs whose partner
    /// slot belongs to another slice park at their `(level, slot)` and are
    /// returned tagged, so a driver-side stage can complete the identical
    /// merge DAG across slices — every DAG node is computed exactly once
    /// globally, which keeps an order-sensitive or non-associative `combine`
    /// (f32 accumulation, ordered concatenation) bitwise-independent of how
    /// the map was sliced.
    ///
    /// With `slots = 0..n` and `total = n` this is exactly the unsharded
    /// combining drain. Surviving segments are ordered by leftmost task
    /// index (`slot << level`).
    pub fn map_indexed_hinted_combined_at<R, F, C>(
        &self,
        n: usize,
        hints: &[usize],
        slots: &[usize],
        total: usize,
        f: F,
        combine: C,
    ) -> (
        Vec<((usize, usize), Result<R, String>)>,
        LocalityStats,
        CombineStats,
    )
    where
        R: Send + 'static,
        F: Fn(usize, QueueAhead) -> R + Send + Sync + 'static,
        C: Fn(R, R) -> R + Send + Sync + 'static,
    {
        if n == 0 {
            return (Vec::new(), LocalityStats::default(), CombineStats::default());
        }
        assert_eq!(slots.len(), n, "one leaf slot per task");
        let size = self.size();
        let queues = build_queues(n, hints, size);
        let local_hits = Arc::new(AtomicUsize::new(0));
        let steals = Arc::new(AtomicUsize::new(0));
        let leaf_slots = Arc::new(slots.to_vec());
        // Slot widths per level: a lone trailing slot (odd width) can never
        // merge at its level and parks there until final collection.
        let mut widths = vec![total.max(n)];
        while *widths.last().expect("non-empty widths") > 1 {
            let w = *widths.last().expect("non-empty widths");
            widths.push(w / 2);
        }
        let widths = Arc::new(widths);
        let ledger: Arc<Mutex<MergeLedger<R>>> = Arc::new(Mutex::new(MergeLedger {
            slots: HashMap::new(),
            merges: 0,
            depth: 0,
        }));
        let f = Arc::new(f);
        let combine = Arc::new(combine);
        // Completion is detected by sender-drop, so a panicking drain task
        // (the closures inside are unwind-caught, but belt and braces) can
        // never deadlock the collection below.
        let (done_tx, done_rx) = channel::<()>();
        for w in 0..size {
            let queues = Arc::clone(&queues);
            let local_hits = Arc::clone(&local_hits);
            let steals = Arc::clone(&steals);
            let widths = Arc::clone(&widths);
            let ledger = Arc::clone(&ledger);
            let leaf_slots = Arc::clone(&leaf_slots);
            let f = Arc::clone(&f);
            let combine = Arc::clone(&combine);
            let done_tx = done_tx.clone();
            self.execute(move || {
                loop {
                    let Some((id, ahead, local)) = claim_task(&queues, w, size) else { break };
                    if local {
                        local_hits.fetch_add(1, Ordering::Relaxed);
                    } else {
                        steals.fetch_add(1, Ordering::Relaxed);
                    }
                    let mut val: Result<R, String> =
                        catch_unwind(AssertUnwindSafe(|| f(id, ahead))).map_err(describe_panic);
                    // Cascade up the merge tree: park when the sibling is
                    // still running (it will pick the pair up later — or
                    // lives on another slice and never arrives here), merge
                    // and promote when it already parked. Check-and-park is
                    // one lock acquisition, so exactly one of the siblings
                    // performs each merge.
                    let mut level = 0usize;
                    let mut slot = leaf_slots[id];
                    loop {
                        let width = widths.get(level).copied().unwrap_or(1);
                        let sib = slot ^ 1;
                        let partner = {
                            let mut lg = ledger.lock().expect("combine ledger poisoned");
                            if sib >= width {
                                // Lone tail slot: parks permanently.
                                lg.slots.insert((level, slot), val);
                                break;
                            }
                            match lg.slots.remove(&(level, sib)) {
                                Some(p) => p,
                                None => {
                                    lg.slots.insert((level, slot), val);
                                    break;
                                }
                            }
                        };
                        // Even slot = left segment = left operand, always.
                        let (left, right) =
                            if slot & 1 == 0 { (val, partner) } else { (partner, val) };
                        let merged = match (left, right) {
                            (Ok(a), Ok(b)) => {
                                let c = Arc::clone(&combine);
                                catch_unwind(AssertUnwindSafe(move || c(a, b)))
                                    .map_err(describe_panic)
                            }
                            (Err(e), _) | (_, Err(e)) => Err(e),
                        };
                        {
                            let mut lg = ledger.lock().expect("combine ledger poisoned");
                            lg.merges += 1;
                            lg.depth = lg.depth.max(level + 1);
                        }
                        val = merged;
                        slot /= 2;
                        level += 1;
                    }
                }
                drop(done_tx);
            });
        }
        drop(done_tx);
        // Block until every drain task has finished (all senders dropped).
        while done_rx.recv().is_ok() {}
        let mut lg = ledger.lock().expect("combine ledger poisoned");
        let stats = CombineStats { merges: lg.merges, depth: lg.depth };
        let mut parts: Vec<((usize, usize), Result<R, String>)> = lg.slots.drain().collect();
        drop(lg);
        // Order surviving segments by their leftmost task index.
        parts.sort_by_key(|part| {
            let (level, slot) = part.0;
            slot << level
        });
        (
            parts,
            LocalityStats {
                local_hits: local_hits.load(Ordering::Relaxed),
                steals: steals.load(Ordering::Relaxed),
            },
            stats,
        )
    }
}

/// Segment ledger of one combining drain: values parked by `(level, slot)`.
struct MergeLedger<R> {
    slots: HashMap<(usize, usize), Result<R, String>>,
    merges: usize,
    depth: usize,
}

/// Per-worker hinted queues for a map of `n` tasks.
fn build_queues(n: usize, hints: &[usize], size: usize) -> Arc<Vec<Mutex<VecDeque<usize>>>> {
    let mut build: Vec<VecDeque<usize>> = (0..size).map(|_| VecDeque::new()).collect();
    for id in 0..n {
        let hint = hints.get(id).copied().unwrap_or(id);
        build[hint % size].push_back(id);
    }
    Arc::new(build.into_iter().map(Mutex::new).collect())
}

/// Claim the next task for logical worker `w`: own queue front first, then
/// the back of the first non-dry victim. Returns the claimed id, the
/// claimed queue's lookahead, and whether the claim was own-queue.
fn claim_task(
    queues: &[Mutex<VecDeque<usize>>],
    w: usize,
    size: usize,
) -> Option<(usize, QueueAhead, bool)> {
    {
        let mut q = queues[w].lock().expect("poisoned locality queue");
        if let Some(id) = q.pop_front() {
            let ahead = QueueAhead { next: q.front().copied(), next2: q.get(1).copied() };
            return Some((id, ahead, true));
        }
    }
    for off in 1..size {
        let v = (w + off) % size;
        let mut q = queues[v].lock().expect("poisoned locality queue");
        if let Some(id) = q.pop_back() {
            // A stolen task gets no deep lookahead: the victim still owns
            // its queue order, so only its current front is a useful hint.
            let ahead = QueueAhead { next: q.front().copied(), next2: None };
            return Some((id, ahead, false));
        }
    }
    None
}

/// Locality outcome of a hinted map: how tasks were claimed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LocalityStats {
    /// Tasks a logical worker took from its own hinted queue.
    pub local_hits: usize,
    /// Tasks taken from another worker's queue because one's own was dry.
    pub steals: usize,
}

/// Lookahead of the claimed queue at claim time — the engine's prefetch
/// hints (depth 1 always, depth 2 when the cache budget has slack).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueAhead {
    /// The task that was next on the same queue, if any.
    pub next: Option<usize>,
    /// The task after `next` on the same queue, if any.
    pub next2: Option<usize>,
}

/// Merge-tree outcome of a combining drain.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CombineStats {
    /// Pairwise merges executed on the pool.
    pub merges: usize,
    /// Height of the tallest merged segment (0 = nothing merged).
    pub depth: usize,
}

/// Render a caught panic payload as a task-failure message.
fn describe_panic(e: Box<dyn std::any::Any + Send>) -> String {
    e.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| e.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "task panicked".to_string())
}

/// Drain `(index, result)` pairs into an input-ordered vector.
fn collect_ordered<R>(n: usize, rx: Receiver<(usize, Result<R, String>)>) -> Vec<Result<R, String>> {
    let mut results: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        results[idx] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.unwrap_or_else(|| Err("worker dropped task".to_string())))
        .collect()
}

fn worker_loop(rx: Arc<Mutex<Receiver<Message>>>) {
    loop {
        let msg = {
            let guard = rx.lock().expect("poisoned pool queue");
            guard.recv()
        };
        match msg {
            Ok(Message::Run(task)) => {
                // Panic isolation: a panicking task must not kill the worker.
                let _ = catch_unwind(AssertUnwindSafe(task));
            }
            Ok(Message::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        let done = rx.iter().count();
        assert_eq!(done, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_parallel_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map_parallel((0..50).collect(), |x: i32| x * 2);
        let vals: Vec<i32> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_parallel_isolates_panics() {
        let pool = ThreadPool::new(2);
        let out = pool.map_parallel(vec![1, 2, 3, 4], |x: i32| {
            if x == 3 {
                panic!("boom {x}");
            }
            x
        });
        assert_eq!(out[0], Ok(1));
        assert_eq!(out[1], Ok(2));
        assert!(out[2].as_ref().unwrap_err().contains("boom"));
        assert_eq!(out[3], Ok(4));
        // Pool still usable after a panic.
        let again = pool.map_parallel(vec![10], |x: i32| x + 1);
        assert_eq!(again[0], Ok(11));
    }

    #[test]
    fn map_indexed_preserves_order_and_isolates_panics() {
        let pool = ThreadPool::new(3);
        let out = pool.map_indexed(20, |i| {
            if i == 7 {
                panic!("boom {i}");
            }
            i * 3
        });
        for (i, r) in out.iter().enumerate() {
            if i == 7 {
                assert!(r.as_ref().unwrap_err().contains("boom"));
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 3);
            }
        }
        assert!(pool.map_indexed::<usize, _>(0, |i| i).is_empty());
    }

    #[test]
    fn single_worker_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map_parallel(vec![5, 6], |x: i32| x);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn hinted_map_runs_every_task_once_and_preserves_order() {
        let pool = ThreadPool::new(4);
        let hints: Vec<usize> = (0..40).map(|i| i % 4).collect();
        let (out, stats) = pool.map_indexed_hinted(40, &hints, |i, _next| i * 2);
        let vals: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..40).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(stats.local_hits + stats.steals, 40, "every claim is counted once");
    }

    #[test]
    fn hinted_map_single_worker_is_all_local() {
        let pool = ThreadPool::new(1);
        let hints = vec![0usize; 10];
        let (out, stats) = pool.map_indexed_hinted(10, &hints, |i, _next| i);
        assert_eq!(out.len(), 10);
        assert_eq!(stats, LocalityStats { local_hits: 10, steals: 0 });
    }

    #[test]
    fn hinted_map_skewed_queues_trigger_steals() {
        // All tasks hinted onto worker 0 of a 4-worker pool: the other three
        // logical workers are dry from the start and must steal. The slow
        // tasks keep worker 0 busy long enough that at least one steal lands
        // regardless of scheduling order.
        let pool = ThreadPool::new(4);
        let hints = vec![0usize; 16];
        let (out, stats) = pool.map_indexed_hinted(16, &hints, |i, _next| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            i
        });
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(stats.local_hits + stats.steals, 16);
        assert!(stats.steals > 0, "dry workers must steal from the loaded queue");
    }

    #[test]
    fn hinted_map_out_of_range_hints_degrade_gracefully() {
        // Hints name workers 5..9 of a 2-worker pool (a store sharded for a
        // larger cluster): every task must still run exactly once, results
        // in order, with claims fully accounted.
        let pool = ThreadPool::new(2);
        let hints: Vec<usize> = (0..20).map(|i| 5 + i % 5).collect();
        let (out, stats) = pool.map_indexed_hinted(20, &hints, |i, _next| i + 100);
        let vals: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..20).map(|i| i + 100).collect::<Vec<_>>());
        assert_eq!(stats.local_hits + stats.steals, 20);
    }

    #[test]
    fn hinted_map_passes_queue_lookahead_as_hint() {
        // Single worker, all tasks on its queue: the lookahead must be the
        // one or two tasks that followed in queue order, and None at the
        // queue's end.
        let pool = ThreadPool::new(1);
        let hints = vec![0usize; 5];
        let seen: Arc<Mutex<Vec<(usize, Option<usize>, Option<usize>)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let seen_in = Arc::clone(&seen);
        let (out, _) = pool.map_indexed_hinted(5, &hints, move |i, ahead: QueueAhead| {
            seen_in.lock().unwrap().push((i, ahead.next, ahead.next2));
            i
        });
        assert!(out.iter().all(|r| r.is_ok()));
        let mut log = seen.lock().unwrap().clone();
        log.sort();
        assert_eq!(
            log,
            vec![
                (0, Some(1), Some(2)),
                (1, Some(2), Some(3)),
                (2, Some(3), Some(4)),
                (3, Some(4), None),
                (4, None, None)
            ]
        );
    }

    #[test]
    fn hinted_map_isolates_panics() {
        let pool = ThreadPool::new(3);
        let hints: Vec<usize> = (0..9).map(|i| i % 3).collect();
        let (out, stats) = pool.map_indexed_hinted(9, &hints, |i, _next| {
            if i == 4 {
                panic!("boom {i}");
            }
            i
        });
        for (i, r) in out.iter().enumerate() {
            if i == 4 {
                assert!(r.as_ref().unwrap_err().contains("boom"));
            } else {
                assert_eq!(*r.as_ref().unwrap(), i);
            }
        }
        assert_eq!(stats.local_hits + stats.steals, 9);
        // Pool still usable after a panic.
        let (again, _) = pool.map_indexed_hinted(2, &[0, 1], |i, _| i);
        assert_eq!(again.len(), 2);
    }

    #[test]
    fn hinted_map_empty_input() {
        let pool = ThreadPool::new(2);
        let (out, stats) = pool.map_indexed_hinted::<usize, _>(0, &[], |i, _| i);
        assert!(out.is_empty());
        assert_eq!(stats, LocalityStats::default());
    }

    /// Ordered concatenation is the most order-sensitive combine there is:
    /// the fixed tree topology must reproduce the sequential fold exactly,
    /// for any worker count and any (non-power-of-two) task count.
    #[test]
    fn combined_drain_preserves_segment_order() {
        for workers in [1usize, 3, 4] {
            for n in [1usize, 2, 7, 16, 20, 33] {
                let pool = ThreadPool::new(workers);
                let hints: Vec<usize> = (0..n).map(|i| i % workers.max(1)).collect();
                let (parts, locality, stats) = pool.map_indexed_hinted_combined(
                    n,
                    &hints,
                    |i, _ahead| vec![i],
                    |mut a: Vec<usize>, b: Vec<usize>| {
                        a.extend(b);
                        a
                    },
                );
                let flat: Vec<usize> = parts
                    .into_iter()
                    .flat_map(|p| p.expect("no task failed"))
                    .collect();
                assert_eq!(flat, (0..n).collect::<Vec<_>>(), "workers={workers} n={n}");
                assert_eq!(locality.local_hits + locality.steals, n);
                if n > 1 {
                    assert!(stats.merges > 0, "workers={workers} n={n}: no merges");
                    assert!(stats.merges < n, "merge count must be below task count");
                }
            }
        }
    }

    /// Running the combining drain as independent slices at global slots and
    /// completing the merge DAG driver-side must reproduce the unsharded
    /// drain's surviving segments exactly — same count, same contents, same
    /// order — for splits that do and don't align with subtree boundaries.
    #[test]
    fn combined_at_slices_complete_to_identical_segments() {
        let cat = |mut a: Vec<usize>, b: Vec<usize>| {
            a.extend(b);
            a
        };
        for (total, cut) in [(5usize, 2usize), (7, 4), (8, 3), (16, 8), (20, 7)] {
            let pool = ThreadPool::new(3);
            let hints: Vec<usize> = (0..total).map(|i| i % 3).collect();
            let (reference, _, _) =
                pool.map_indexed_hinted_combined(total, &hints, |i, _| vec![i], cat);
            let reference: Vec<Vec<usize>> =
                reference.into_iter().map(|r| r.unwrap()).collect();

            let mut parked: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
            for (lo, hi) in [(0usize, cut), (cut, total)] {
                let n = hi - lo;
                let slots: Vec<usize> = (lo..hi).collect();
                let hints: Vec<usize> = (0..n).map(|i| i % 3).collect();
                let (parts, _, _) = pool.map_indexed_hinted_combined_at(
                    n,
                    &hints,
                    &slots,
                    total,
                    move |i, _| vec![lo + i],
                    cat,
                );
                for ((level, slot), v) in parts {
                    assert!(
                        parked.insert((level, slot), v.unwrap()).is_none(),
                        "total={total} cut={cut}: duplicate DAG node ({level},{slot})"
                    );
                }
            }
            // Complete the identical DAG bottom-up: merge any even/odd slot
            // pair present at a level (even slot left), promote the result.
            let mut widths = vec![total];
            while *widths.last().unwrap() > 1 {
                widths.push(widths.last().unwrap() / 2);
            }
            for level in 0..widths.len() {
                loop {
                    let key = parked.keys().copied().find(|&(l, s)| {
                        l == level && s % 2 == 0 && parked.contains_key(&(l, s + 1))
                    });
                    let Some((l, s)) = key else { break };
                    let left = parked.remove(&(l, s)).unwrap();
                    let right = parked.remove(&(l, s + 1)).unwrap();
                    parked.insert((l + 1, s / 2), cat(left, right));
                }
            }
            let mut survivors: Vec<((usize, usize), Vec<usize>)> = parked.into_iter().collect();
            survivors.sort_by_key(|((level, slot), _)| slot << level);
            let merged: Vec<Vec<usize>> = survivors.into_iter().map(|(_, v)| v).collect();
            assert_eq!(merged, reference, "total={total} cut={cut}");
        }
    }

    #[test]
    fn combined_drain_collapses_to_log_parts() {
        let pool = ThreadPool::new(4);
        let n = 64usize; // power of two: single root survives
        let hints: Vec<usize> = (0..n).map(|i| i % 4).collect();
        let (parts, _, stats) =
            pool.map_indexed_hinted_combined(n, &hints, |i, _| i, |a: usize, b: usize| a + b);
        assert_eq!(parts.len(), 1, "power-of-two map must merge to the root");
        assert_eq!(*parts[0].as_ref().unwrap(), (0..64).sum::<usize>());
        assert_eq!(stats.merges, 63);
        assert_eq!(stats.depth, 6);
    }

    #[test]
    fn combined_drain_surfaces_panics_as_segment_errors() {
        let pool = ThreadPool::new(3);
        let hints: Vec<usize> = (0..9).map(|i| i % 3).collect();
        let (parts, _, _) = pool.map_indexed_hinted_combined(
            9,
            &hints,
            |i, _| {
                if i == 4 {
                    panic!("boom {i}");
                }
                i
            },
            |a: usize, b: usize| a + b,
        );
        let errs: Vec<&String> = parts.iter().filter_map(|p| p.as_ref().err()).collect();
        assert_eq!(errs.len(), 1, "exactly one poisoned segment: {parts:?}");
        assert!(errs[0].contains("boom"));
        // Pool still usable after the panic.
        let (again, _, _) =
            pool.map_indexed_hinted_combined(2, &[0, 1], |i, _| i, |a: usize, b: usize| a + b);
        assert_eq!(again.len(), 1);
    }

    #[test]
    fn combined_drain_empty_and_single() {
        let pool = ThreadPool::new(2);
        let (parts, _, stats) =
            pool.map_indexed_hinted_combined::<usize, _, _>(0, &[], |i, _| i, |a, b| a + b);
        assert!(parts.is_empty());
        assert_eq!(stats, CombineStats::default());
        let (parts, _, stats) =
            pool.map_indexed_hinted_combined(1, &[0], |i, _| i * 7, |a: usize, b: usize| a + b);
        assert_eq!(parts.len(), 1);
        assert_eq!(*parts[0].as_ref().unwrap(), 0);
        assert_eq!(stats.merges, 0);
    }
}
