//! Sharded multi-engine scale-out: one run spans N engine shards.
//!
//! The paper's scalability story is multi-node: each node clusters the
//! blocks it stores and only compact partials cross the network. This
//! module reproduces that shape inside one process: a [`ShardedEngine`]
//! owns N [`Engine`]s (shard = rack/node group), each with its own
//! contiguous block-id slice of the store (the [`ShardPlan`]), its own
//! byte-budgeted block cache (the cluster budget split proportionally to
//! slice bytes), its own worker pool, prefetcher and locality queues, and
//! its own derived fault domain
//! ([`crate::faults::FaultPlan::derive_for_shard`]).
//!
//! **Two-level merge.** Per-shard map outputs merge locally on each
//! shard's pool through the worker-side combine tree — but the tree runs
//! at the blocks' *global* leaf slots
//! ([`crate::threadpool::ThreadPool::map_indexed_hinted_combined_at`]), so
//! pairs split across shards park as tagged `(level, slot)` segments and a
//! driver-side stage ([`complete_global_dag`]) finishes the identical
//! merge DAG across shards. Every DAG node is computed exactly once
//! globally, which makes `shard.merge = exact` a **bitwise drop-in** for
//! the single-engine result even though `Partials` accumulate in f32
//! (non-associative addition). `shard.merge = representative` instead
//! exchanges only centers + fuzzy counts per shard (à la Bendechache et
//! al., arXiv 1710.09593); the session loop measures its objective-quality
//! delta against the exact merge every iteration.
//!
//! **Cross-shard stealing.** Work moves between shards only at plan time,
//! when a shard's queues would run dry long before its neighbours'
//! (modelled finish = slice bytes / shard workers): the rebalance greedily
//! moves donor-tail blocks to the starved shard while the makespan
//! improves. A stolen block keeps its global merge slot (bitwise-safe) and
//! its transfer bytes are charged to the `net_s` cost class at
//! `shard.steal_penalty ×` the calibrated wire rate — rack-local reads are
//! free, cross-rack reads are not.
//!
//! **Accounting.** Per-shard [`JobStats`] are surfaced individually and
//! merged: counters sum, startup is charged once per shard (each shard is
//! its own job submission), and the merged modelled time takes the
//! **critical shard** — wall = max over shards — plus the global-stage
//! charges. That max-over-shards line is the scaling headline.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::config::OverheadConfig;
use crate::error::{Error, Result};
use crate::hdfs::BlockStore;
use crate::mapreduce::engine::{Engine, EngineOptions, JobRunCfg, JobStats};
use crate::mapreduce::session::SessionOptions;
use crate::mapreduce::simclock::{SimClock, SimCost};
use crate::mapreduce::{DistributedCache, MapReduceJob, TaskCtx};
use crate::telemetry::trace;

/// How the N per-shard partials merge into the global result.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardMergeMode {
    /// Full `Partials` exchange completing the global merge DAG — bitwise
    /// drop-in for the single-engine result.
    #[default]
    Exact,
    /// Shards exchange only centers + fuzzy counts (arXiv 1710.09593);
    /// cheaper wire format, with the objective delta vs exact recorded.
    Representative,
}

impl ShardMergeMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardMergeMode::Exact => "exact",
            ShardMergeMode::Representative => "representative",
        }
    }
}

impl std::str::FromStr for ShardMergeMode {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "exact" => Ok(ShardMergeMode::Exact),
            "representative" | "rep" => Ok(ShardMergeMode::Representative),
            other => Err(Error::InvalidArgument(format!(
                "unknown shard merge mode `{other}` (exact|representative)"
            ))),
        }
    }
}

/// One shard's share of the store and the cluster budget.
#[derive(Clone, Debug)]
pub struct ShardSlice {
    /// Home slice: the contiguous block-id range this shard stores.
    pub range: std::ops::Range<usize>,
    /// Execution list: home blocks minus donations, plus stolen blocks.
    /// These are **global** block ids — cache keys, slab keys and merge
    /// slots all stay global, which is what keeps sharding bitwise-safe.
    pub block_ids: Vec<usize>,
    /// Blocks the plan-time rebalance moved here from other shards.
    pub stolen: Vec<usize>,
    /// Serialised bytes of the stolen blocks (the modelled rack traffic).
    pub stolen_bytes: u64,
    /// Serialised bytes of the execution list.
    pub bytes: u64,
    /// This shard's slice of the cluster cache budget.
    pub cache_bytes: u64,
    /// This shard's slice of the cluster worker count.
    pub workers: usize,
}

/// Contiguous block-range partition of a store over N shards, with the
/// cache budget split proportionally to slice bytes and a plan-time
/// modelled steal rebalance (see the module docs).
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub slices: Vec<ShardSlice>,
    pub total_blocks: usize,
    pub steal_penalty: f64,
}

impl ShardPlan {
    pub fn new(
        store: &BlockStore,
        shards: usize,
        workers: usize,
        cache_bytes: u64,
        steal_penalty: f64,
    ) -> Self {
        let n = store.num_blocks();
        let shards = shards.max(1).min(n.max(1));
        let workers = workers.max(shards); // ≥ 1 worker per shard
        let metas = store.blocks();

        // Contiguous home ranges balanced by block count; worker split
        // base + remainder (earlier shards absorb the remainder).
        let base = n / shards;
        let rem = n % shards;
        let wbase = workers / shards;
        let wrem = workers % shards;
        let mut slices = Vec::with_capacity(shards);
        let mut start = 0usize;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            let range = start..start + len;
            start += len;
            slices.push(ShardSlice {
                block_ids: range.clone().collect(),
                range,
                stolen: Vec::new(),
                stolen_bytes: 0,
                bytes: 0,
                cache_bytes: 0,
                workers: wbase + usize::from(s < wrem),
            });
        }

        // Plan-time modelled rebalance: while moving the most-loaded
        // shard's home-tail block to the driest shard lowers the pairwise
        // makespan (finish estimate = execution bytes / shard workers),
        // move it. Bounded by the block count, so it always terminates.
        let bytes_of = |ids: &[usize]| ids.iter().map(|&b| metas[b].bytes).sum::<u64>();
        for slice in slices.iter_mut() {
            slice.bytes = bytes_of(&slice.block_ids);
        }
        for _ in 0..n {
            let est = |s: &ShardSlice| s.bytes as f64 / s.workers as f64;
            let donor = (0..slices.len())
                .max_by(|&a, &b| est(&slices[a]).partial_cmp(&est(&slices[b])).unwrap())
                .expect("non-empty plan");
            let thief = (0..slices.len())
                .min_by(|&a, &b| est(&slices[a]).partial_cmp(&est(&slices[b])).unwrap())
                .expect("non-empty plan");
            if donor == thief {
                break;
            }
            // Donate from the home tail only — stolen blocks never re-hop,
            // and a donor always keeps at least one home block (an engine
            // with an empty slice would have nothing to map).
            let home_left =
                slices[donor].block_ids.len() - slices[donor].stolen.len();
            if home_left <= 1 {
                break;
            }
            let candidate = slices[donor]
                .block_ids
                .iter()
                .rev()
                .find(|b| !slices[donor].stolen.contains(b))
                .copied();
            let Some(block) = candidate else { break };
            let bbytes = metas[block].bytes;
            let before = est(&slices[donor]).max(est(&slices[thief]));
            let after = ((slices[donor].bytes - bbytes) as f64 / slices[donor].workers as f64)
                .max((slices[thief].bytes + bbytes) as f64 / slices[thief].workers as f64);
            if after + 1e-12 >= before {
                break;
            }
            slices[donor].block_ids.retain(|&b| b != block);
            slices[donor].bytes -= bbytes;
            slices[thief].block_ids.push(block);
            slices[thief].stolen.push(block);
            slices[thief].stolen_bytes += bbytes;
            slices[thief].bytes += bbytes;
        }

        // Cache budget proportional to final execution bytes.
        let total_bytes: u64 = slices.iter().map(|s| s.bytes).sum();
        let mut assigned = 0u64;
        let last = slices.len() - 1;
        for (i, slice) in slices.iter_mut().enumerate() {
            slice.cache_bytes = if i == last {
                cache_bytes - assigned // remainder-exact: slices sum to the budget
            } else if total_bytes > 0 {
                ((cache_bytes as u128 * slice.bytes as u128) / total_bytes as u128) as u64
            } else {
                cache_bytes / shards as u64
            };
            assigned += slice.cache_bytes;
        }

        Self { slices, total_blocks: n, steal_penalty }
    }

    /// Total blocks the rebalance moved across shards.
    pub fn steals(&self) -> usize {
        self.slices.iter().map(|s| s.stolen.len()).sum()
    }

    /// Total serialised bytes of cross-shard blocks.
    pub fn steal_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.stolen_bytes).sum()
    }
}

/// Complete the global merge DAG over every shard's tagged segments and
/// return the canonical survivor list (ordered by leftmost block) ready
/// for the job's reduce, plus the number of driver-side merges performed.
///
/// With `use_tree` off (flat reduce) the segments are all leaf-level; they
/// are sorted into block order untouched — exactly what the single
/// engine's flat path feeds its reduce. With it on, pairs merge bottom-up
/// (even slot always the left operand), reproducing precisely the merges
/// the single-engine combining drain would have performed on the pool.
pub fn complete_global_dag<J: MapReduceJob>(
    job: &J,
    segments: Vec<((usize, usize), J::MapOut)>,
    total: usize,
    use_tree: bool,
) -> Result<(Vec<J::MapOut>, usize)> {
    if !use_tree {
        let mut segs = segments;
        segs.sort_by_key(|((level, slot), _)| slot << level);
        return Ok((segs.into_iter().map(|(_, v)| v).collect(), 0));
    }
    let mut parked: HashMap<(usize, usize), J::MapOut> = HashMap::with_capacity(segments.len());
    for (key, v) in segments {
        if parked.insert(key, v).is_some() {
            return Err(Error::Job(format!(
                "duplicate merge-DAG node ({}, {}) — shard slices overlap",
                key.0, key.1
            )));
        }
    }
    let mut widths = vec![total.max(1)];
    while *widths.last().expect("non-empty widths") > 1 {
        let w = *widths.last().expect("non-empty widths");
        widths.push(w / 2);
    }
    let mut merges = 0usize;
    for level in 0..widths.len() {
        let mut evens: Vec<usize> = parked
            .keys()
            .filter(|&&(l, s)| l == level && s % 2 == 0)
            .map(|&(_, s)| s)
            .collect();
        evens.sort_unstable();
        for s in evens {
            if !parked.contains_key(&(level, s + 1)) {
                continue; // partner is a lone tail elsewhere in the DAG
            }
            let left = parked.remove(&(level, s)).expect("left node present");
            let right = parked.remove(&(level, s + 1)).expect("right node present");
            let merged = job.combine(left, right)?;
            merges += 1;
            parked.insert((level + 1, s / 2), merged);
        }
    }
    let mut survivors: Vec<((usize, usize), J::MapOut)> = parked.into_iter().collect();
    survivors.sort_by_key(|((level, slot), _)| slot << level);
    Ok((survivors.into_iter().map(|(_, v)| v).collect(), merges))
}

/// N engines, one store, one global clock. See the module docs.
pub struct ShardedEngine {
    engines: Vec<Engine>,
    plan: ShardPlan,
    overhead: OverheadConfig,
    clock: SimClock,
    /// Global-clock snapshot at the start of the in-flight job's map phase
    /// (consumed by [`Self::finalize_job`] to delta out the job's share).
    job_cost_before: SimCost,
}

impl ShardedEngine {
    /// Build N shard engines from the cluster-level options: workers and
    /// cache budget split per the [`ShardPlan`], one derived fault domain
    /// per shard, everything else inherited.
    pub fn new(
        store: &BlockStore,
        options: &EngineOptions,
        overhead: OverheadConfig,
        shards: usize,
        steal_penalty: f64,
    ) -> Self {
        let plan = ShardPlan::new(
            store,
            shards,
            options.workers,
            options.block_cache_bytes,
            steal_penalty,
        );
        let engines = plan
            .slices
            .iter()
            .enumerate()
            .map(|(i, slice)| {
                let opts = EngineOptions {
                    workers: slice.workers,
                    block_cache_bytes: slice.cache_bytes,
                    faults: options
                        .faults
                        .as_ref()
                        .map(|p| p.derive_for_shard(i as u64)),
                    ..options.clone()
                };
                Engine::new(opts, overhead.clone())
            })
            .collect();
        Self {
            engines,
            plan,
            overhead,
            clock: SimClock::new(),
            job_cost_before: SimCost::default(),
        }
    }

    pub fn shards(&self) -> usize {
        self.engines.len()
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn engine(&self, shard: usize) -> &Engine {
        &self.engines[shard]
    }

    pub fn engine_mut(&mut self, shard: usize) -> &mut Engine {
        &mut self.engines[shard]
    }

    /// The merged modelled clock: critical-shard share per job + global
    /// stage + rack traffic (per-shard clocks stay shard-local truth).
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    pub fn overhead(&self) -> &OverheadConfig {
        &self.overhead
    }

    /// Fold an externally accrued cost share (e.g. the driver phase run on
    /// shard 0's engine) into the global clock.
    pub fn absorb(&mut self, cost: &SimCost) {
        self.clock.absorb(cost, 0, 0);
    }

    /// Charge a driver-side HDFS scan to the global clock (checkpoint
    /// writes, slab spill traffic — mirrors [`Engine::charge_scan`]).
    pub fn charge_scan(&mut self, bytes: u64) {
        self.clock.charge_scan(&self.overhead, bytes);
    }

    /// Charge modelled retry-backoff to the global clock.
    pub fn charge_backoff(&mut self, s: f64) {
        if s > 0.0 {
            self.clock.charge_backoff(s);
        }
    }

    /// Run the map + local-combine phase on every shard concurrently —
    /// `jobs[i]` on shard `i` (sessions hand each shard its own job
    /// instance so slabs stay shard-resident; plain pipelines clone one
    /// Arc). Returns each shard's tagged segments and its [`JobStats`]
    /// (steal counters stamped, startup per `cfg`), and advances the
    /// global clock by the critical shard's share plus the stolen blocks'
    /// rack transfer (cold jobs only — a warm shard serves stolen blocks
    /// from its own cache, exactly like warm HDFS reads).
    pub fn run_map_segments<J: MapReduceJob + 'static>(
        &mut self,
        jobs: &[Arc<J>],
        store: &Arc<BlockStore>,
        cache: &Arc<DistributedCache>,
        cfg: JobRunCfg,
    ) -> Result<(Vec<Vec<((usize, usize), J::MapOut)>>, Vec<JobStats>)> {
        if jobs.len() != self.engines.len() {
            return Err(Error::Job(format!(
                "{} jobs for {} shards",
                jobs.len(),
                self.engines.len()
            )));
        }
        self.job_cost_before = self.clock.cost();
        let total = self.plan.total_blocks;
        let engines = &mut self.engines;
        let plan = &self.plan;
        // Shard spans parent to whatever span is ambient on the driver
        // (the iteration span during sessions); the per-shard job span
        // then nests under the shard via the runner thread's ambient stack.
        let trace_parent = trace::current_span_id();
        let results: Vec<Result<(Vec<((usize, usize), J::MapOut)>, JobStats)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(engines.len());
                for (shard_idx, ((engine, slice), job)) in
                    engines.iter_mut().zip(&plan.slices).zip(jobs).enumerate()
                {
                    let store = Arc::clone(store);
                    let cache = Arc::clone(cache);
                    let job = Arc::clone(job);
                    handles.push(scope.spawn(move || {
                        let mut shard_span =
                            trace::global().span_child("shard", "mapreduce", trace_parent);
                        shard_span.attr("shard", shard_idx.to_string());
                        engine.run_job_map_segments(
                            job,
                            &store,
                            cache,
                            cfg,
                            &slice.block_ids,
                            total,
                        )
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard driver thread panicked"))
                    .collect()
            });
        let mut segments = Vec::with_capacity(results.len());
        let mut stats = Vec::with_capacity(results.len());
        for (slice, r) in self.plan.slices.iter().zip(results) {
            let (segs, mut st) = r?;
            st.shard_steals = slice.stolen.len();
            st.shard_steal_bytes = slice.stolen_bytes;
            segments.push(segs);
            stats.push(st);
        }
        // Global clock: the shards ran concurrently, so the merged job
        // pays the critical (max modelled cost) shard's share...
        let tasks: usize = stats.iter().map(|s| s.map_tasks).sum();
        let critical = stats
            .iter()
            .map(|s| s.sim)
            .max_by(|a, b| a.total_s().partial_cmp(&b.total_s()).unwrap())
            .unwrap_or_default();
        self.clock.absorb(&critical, 1, tasks);
        // ...plus every shard's startup beyond the critical one's (each
        // shard is its own job submission — startup is once *per shard*)...
        let extra_startup: f64 = stats.iter().map(|s| s.sim.job_startup_s).sum::<f64>()
            - critical.job_startup_s;
        if extra_startup > 0.0 {
            self.clock.absorb(
                &SimCost { job_startup_s: extra_startup, ..SimCost::default() },
                0,
                0,
            );
        }
        // ...plus the cross-rack transfer of stolen blocks, at the steal
        // penalty, on cold jobs (warm shards hold them in cache already).
        if cfg.charge_startup && self.plan.steal_bytes() > 0 {
            let mut oh = self.overhead.clone();
            oh.net_s_per_mib *= self.plan.steal_penalty;
            for st in stats.iter_mut() {
                if st.shard_steal_bytes > 0 {
                    st.sim.net_s += self.clock.charge_net(&oh, st.shard_steal_bytes);
                }
            }
        }
        Ok((segments, stats))
    }

    /// Merge per-shard stats into the run's headline row: counters sum,
    /// wall = max over shards + the global stage, modelled cost = the
    /// global clock's delta since this job's map phase began (critical
    /// shard + startups + rack traffic + global-stage compute).
    pub fn finalize_job(
        &mut self,
        shard_stats: &[JobStats],
        global_wall: std::time::Duration,
        reduce_wall_s: f64,
        global_merges: usize,
        reduce_parts: usize,
    ) -> JobStats {
        let _ = global_merges; // surfaced via reduce_parts; kept for callers' symmetry
        // The global merge/reduce stage is real driver-side compute.
        if global_wall.as_secs_f64() > 0.0 {
            self.clock.charge_local(&self.overhead, global_wall);
        }
        let sim = self.clock.cost().delta(&self.job_cost_before);
        let first = shard_stats.first().expect("at least one shard");
        let max_wall = shard_stats.iter().map(|s| s.wall).max().unwrap_or_default();
        let mut merged = JobStats {
            name: first.name.clone(),
            wall: max_wall + global_wall,
            sim,
            map_tasks: 0,
            attempts: 0,
            shuffle_bytes: 0,
            locality_hits: 0,
            locality_steals: 0,
            prefetch_hits: 0,
            prefetch_wasted_bytes: 0,
            read_retries: 0,
            read_aborts: 0,
            quarantines: 0,
            prefetch_errors: 0,
            records_pruned: 0,
            records_pruned_quant: 0,
            quant_sidecar_bytes: 0,
            quant_build_s: 0.0,
            slab_bytes: 0,
            slab_evictions: 0,
            slab_spilled_bytes: 0,
            slab_reloads: 0,
            slab_spill_retries: 0,
            slab_spill_quarantines: 0,
            refresh_cap: 0,
            shard_steals: 0,
            shard_steal_bytes: 0,
            reduce_wall_s,
            combine_wall_s: 0.0,
            combine_depth: 0,
            reduce_parts,
            read_wall_s: 0.0,
            compute_wall_s: 0.0,
        };
        for s in shard_stats {
            merged.map_tasks += s.map_tasks;
            merged.attempts += s.attempts;
            merged.shuffle_bytes += s.shuffle_bytes;
            merged.locality_hits += s.locality_hits;
            merged.locality_steals += s.locality_steals;
            merged.prefetch_hits += s.prefetch_hits;
            merged.prefetch_wasted_bytes += s.prefetch_wasted_bytes;
            merged.read_retries += s.read_retries;
            merged.read_aborts += s.read_aborts;
            merged.quarantines += s.quarantines;
            merged.prefetch_errors += s.prefetch_errors;
            merged.records_pruned += s.records_pruned;
            merged.records_pruned_quant += s.records_pruned_quant;
            merged.quant_sidecar_bytes += s.quant_sidecar_bytes;
            merged.quant_build_s += s.quant_build_s;
            merged.slab_bytes += s.slab_bytes;
            merged.slab_evictions += s.slab_evictions;
            merged.slab_spilled_bytes += s.slab_spilled_bytes;
            merged.slab_reloads += s.slab_reloads;
            merged.slab_spill_retries += s.slab_spill_retries;
            merged.slab_spill_quarantines += s.slab_spill_quarantines;
            merged.refresh_cap = merged.refresh_cap.max(s.refresh_cap);
            merged.shard_steals += s.shard_steals;
            merged.shard_steal_bytes += s.shard_steal_bytes;
            merged.combine_wall_s += s.combine_wall_s;
            merged.combine_depth = merged.combine_depth.max(s.combine_depth);
            merged.read_wall_s += s.read_wall_s;
            merged.compute_wall_s += s.compute_wall_s;
        }
        merged
    }

    /// Execute one job across every shard with the exact two-level merge:
    /// per-shard map + local combine, driver-side global DAG completion,
    /// then the job's reduce over the canonical survivor list — a bitwise
    /// drop-in for [`Engine::run_job_cfg`] on a single engine. Returns the
    /// output, the merged stats and the per-shard stats.
    pub fn run_job_cfg<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        store: &Arc<BlockStore>,
        cache: &Arc<DistributedCache>,
        cfg: JobRunCfg,
    ) -> Result<(J::Output, JobStats, Vec<JobStats>)> {
        let jobs: Vec<Arc<J>> = (0..self.shards()).map(|_| Arc::clone(&job)).collect();
        self.run_jobs_cfg(&jobs, store, cache, cfg)
    }

    /// [`Self::run_job_cfg`] with one job instance per shard (sessions).
    pub fn run_jobs_cfg<J: MapReduceJob + 'static>(
        &mut self,
        jobs: &[Arc<J>],
        store: &Arc<BlockStore>,
        cache: &Arc<DistributedCache>,
        cfg: JobRunCfg,
    ) -> Result<(J::Output, JobStats, Vec<JobStats>)> {
        let (segments, shard_stats) = self.run_map_segments(jobs, store, cache, cfg)?;
        let use_tree = cfg.tree_combine && jobs[0].supports_combine();
        let t0 = Instant::now();
        let (parts, merges) = complete_global_dag(
            jobs[0].as_ref(),
            segments.into_iter().flatten().collect(),
            self.plan.total_blocks,
            use_tree,
        )?;
        let reduce_parts = parts.len();
        let reduce_ctx = TaskCtx { cache, task_id: usize::MAX, attempt: 0, doomed: false };
        let t_reduce = Instant::now();
        let output = jobs[0].reduce(parts, &reduce_ctx)?;
        let reduce_wall_s = t_reduce.elapsed().as_secs_f64();
        let merged =
            self.finalize_job(&shard_stats, t0.elapsed(), reduce_wall_s, merges, reduce_parts);
        Ok((output, merged, shard_stats))
    }

    /// Open an iteration-resident session over `store` spanning all shards.
    pub fn session<'e>(
        &'e mut self,
        store: &Arc<BlockStore>,
        options: SessionOptions,
    ) -> ShardedSession<'e> {
        ShardedSession { engine: self, store: Arc::clone(store), options, iterations: 0 }
    }
}

/// The sharded twin of [`crate::mapreduce::IterativeSession`]: slabs,
/// bounds state, quant sidecars and block caches stay **shard-resident**
/// across iterations, startup is charged once per shard on the first
/// iteration only (when resident), and per-job cache meters reset between
/// iterations without dropping warm blocks.
pub struct ShardedSession<'e> {
    engine: &'e mut ShardedEngine,
    store: Arc<BlockStore>,
    options: SessionOptions,
    iterations: usize,
}

impl ShardedSession<'_> {
    /// The [`JobRunCfg`] the next iteration runs under.
    pub fn next_cfg(&self) -> JobRunCfg {
        JobRunCfg {
            charge_startup: !self.options.resident || self.iterations == 0,
            tree_combine: self
                .options
                .tree_combine
                .unwrap_or(self.engine.engines[0].options().tree_combine),
        }
    }

    /// One iteration's map + local-combine phase on every shard; the
    /// caller completes the global merge (exact or representative) and
    /// calls [`ShardedEngine::finalize_job`] through
    /// [`Self::finalize_iteration`].
    pub fn run_iteration_segments<J: MapReduceJob + 'static>(
        &mut self,
        jobs: &[Arc<J>],
        cache: &Arc<DistributedCache>,
    ) -> Result<(Vec<Vec<((usize, usize), J::MapOut)>>, Vec<JobStats>, JobRunCfg)> {
        let cfg = self.next_cfg();
        if self.iterations > 0 {
            for e in &self.engine.engines {
                e.block_cache().reset_job_meters();
            }
        }
        let store = Arc::clone(&self.store);
        let out = self.engine.run_map_segments(jobs, &store, cache, cfg)?;
        self.iterations += 1;
        Ok((out.0, out.1, cfg))
    }

    /// Finish one iteration's accounting (see
    /// [`ShardedEngine::finalize_job`]).
    pub fn finalize_iteration(
        &mut self,
        shard_stats: &[JobStats],
        global_wall: std::time::Duration,
        reduce_wall_s: f64,
        global_merges: usize,
        reduce_parts: usize,
    ) -> JobStats {
        self.engine
            .finalize_job(shard_stats, global_wall, reduce_wall_s, global_merges, reduce_parts)
    }

    /// Charge a driver-side HDFS scan to the run's global clock.
    pub fn charge_scan(&mut self, bytes: u64) {
        self.engine.charge_scan(bytes);
    }

    /// Charge modelled retry-backoff to the run's global clock.
    pub fn charge_backoff(&mut self, s: f64) {
        self.engine.charge_backoff(s);
    }

    pub fn iterations(&self) -> usize {
        self.iterations
    }

    pub fn store(&self) -> &Arc<BlockStore> {
        &self.store
    }

    pub fn engine(&self) -> &ShardedEngine {
        self.engine
    }

    pub fn engine_mut(&mut self) -> &mut ShardedEngine {
        self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::data::Matrix;
    use crate::mapreduce::MIB;

    /// Combiner-capable sum job (mirrors the engine tests' CombSum).
    struct CombSum;

    impl MapReduceJob for CombSum {
        type MapOut = (f64, usize);
        type Output = (f64, usize);

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
            let s: f64 = block.as_slice().iter().map(|&v| v as f64).sum();
            Ok((s, block.rows()))
        }

        fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
            Ok(parts
                .into_iter()
                .fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
        }

        fn supports_combine(&self) -> bool {
            true
        }

        fn combine(&self, left: Self::MapOut, right: Self::MapOut) -> Result<Self::MapOut> {
            Ok((left.0 + right.0, left.1 + right.1))
        }

        fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
            16
        }

        fn name(&self) -> &str {
            "comb_sum"
        }
    }

    fn store(blocks: usize) -> Arc<BlockStore> {
        let rows = blocks * 125;
        let d = blobs(rows, 3, 2, 0.5, 7);
        Arc::new(BlockStore::in_memory("t", &d.features, 125, 4).unwrap())
    }

    #[test]
    fn plan_covers_every_block_exactly_once() {
        let s = store(10);
        for shards in [1usize, 2, 3, 4] {
            let plan = ShardPlan::new(&s, shards, 8, 64 * MIB, 4.0);
            let mut seen: Vec<usize> = plan
                .slices
                .iter()
                .flat_map(|sl| sl.block_ids.iter().copied())
                .collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..10).collect::<Vec<_>>(), "shards={shards}");
            let cache: u64 = plan.slices.iter().map(|sl| sl.cache_bytes).sum();
            assert_eq!(cache, 64 * MIB, "cache budget must split exactly");
            let workers: usize = plan.slices.iter().map(|sl| sl.workers).sum();
            assert_eq!(workers, 8, "workers must split exactly");
            assert!(plan.slices.iter().all(|sl| sl.workers >= 1));
        }
    }

    #[test]
    fn balanced_plan_steals_nothing_and_skew_steals_something() {
        let s = store(12);
        // 4 workers over 2 shards: even split, even bytes → no steals.
        let even = ShardPlan::new(&s, 2, 4, 64 * MIB, 4.0);
        assert_eq!(even.steals(), 0, "balanced shards must not steal");
        // 3 workers over 2 shards: 2/1 split → shard 1 is the straggler;
        // the rebalance must move some of its tail to shard 0.
        let skew = ShardPlan::new(&s, 2, 3, 64 * MIB, 4.0);
        assert!(skew.steals() > 0, "induced imbalance must trigger steals");
        assert!(skew.steal_bytes() > 0);
        assert!(skew.slices[0].stolen.len() > 0, "the wide shard is the thief");
        assert_eq!(skew.slices[1].stolen.len(), 0);
        // Stolen blocks still cover the store exactly once.
        let mut seen: Vec<usize> = skew
            .slices
            .iter()
            .flat_map(|sl| sl.block_ids.iter().copied())
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn sharded_sum_matches_single_engine_for_any_shard_count() {
        let s = store(10);
        let mut single = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let ((expect_sum, expect_rows), _) = single
            .run_job(Arc::new(CombSum), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        for shards in [1usize, 2, 3] {
            let mut sharded = ShardedEngine::new(
                &s,
                &EngineOptions::default(),
                OverheadConfig::default(),
                shards,
                4.0,
            );
            let cache = Arc::new(DistributedCache::new());
            let cfg = JobRunCfg { charge_startup: true, tree_combine: true };
            let ((sum, rows), merged, per_shard) = sharded
                .run_job_cfg(Arc::new(CombSum), &s, &cache, cfg)
                .unwrap();
            assert_eq!(rows, expect_rows, "shards={shards}");
            assert_eq!(sum.to_bits(), expect_sum.to_bits(), "shards={shards}: not bitwise");
            assert_eq!(per_shard.len(), shards);
            assert_eq!(merged.map_tasks, 10);
            let task_sum: usize = per_shard.iter().map(|s| s.map_tasks).sum();
            assert_eq!(task_sum, 10);
            // Startup once per shard.
            let startups = merged.sim.job_startup_s / sharded.overhead().job_startup_s;
            assert!((startups - shards as f64).abs() < 1e-9, "shards={shards}: {startups}");
            // Merged modelled time = critical shard + extra startups (+ globals):
            // it must be at least every single shard's share.
            for st in &per_shard {
                assert!(merged.sim.total_s() + 1e-12 >= st.sim.total_s(), "shards={shards}");
            }
        }
    }

    #[test]
    fn sharded_flat_reduce_matches_single_engine() {
        let s = store(9);
        let cfg = JobRunCfg { charge_startup: true, tree_combine: false };
        let mut single = Engine::new(
            EngineOptions { tree_combine: false, ..Default::default() },
            OverheadConfig::default(),
        );
        let ((expect, _), _) = single
            .run_job(Arc::new(CombSum), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        let mut sharded =
            ShardedEngine::new(&s, &EngineOptions::default(), OverheadConfig::default(), 2, 4.0);
        let ((sum, rows), merged, _) = sharded
            .run_job_cfg(Arc::new(CombSum), &s, &Arc::new(DistributedCache::new()), cfg)
            .unwrap();
        assert_eq!(rows, 9 * 125);
        assert_eq!(sum.to_bits(), expect.to_bits(), "flat sharded reduce must be bitwise");
        assert_eq!(merged.reduce_parts, 9, "flat path funnels every map output");
    }

    #[test]
    fn steals_are_charged_to_net_on_cold_jobs_only() {
        let s = store(12);
        // 3 workers / 2 shards: induced imbalance → steals exist.
        let opts = EngineOptions { workers: 3, ..Default::default() };
        let mut sharded =
            ShardedEngine::new(&s, &opts, OverheadConfig::default(), 2, 4.0);
        assert!(sharded.plan().steals() > 0);
        let cache = Arc::new(DistributedCache::new());
        let cold = JobRunCfg { charge_startup: true, tree_combine: true };
        let (_, merged_cold, per_shard) =
            sharded.run_job_cfg(Arc::new(CombSum), &s, &cache, cold).unwrap();
        assert!(merged_cold.sim.net_s > 0.0, "cold steals must charge net_s");
        assert!(merged_cold.shard_steals > 0);
        assert!(merged_cold.shard_steal_bytes > 0);
        let thief = per_shard.iter().find(|st| st.shard_steals > 0).unwrap();
        assert!(thief.sim.net_s > 0.0, "the thief's row carries the rack charge");
        // Penalty scales the charge linearly.
        let expected = sharded.plan().steal_bytes() as f64 / (1024.0 * 1024.0)
            * sharded.overhead().net_s_per_mib
            * 4.0;
        assert!((merged_cold.sim.net_s - expected).abs() < 1e-9);
        // Warm iteration: stolen blocks are cached shard-side — no re-charge.
        let warm = JobRunCfg { charge_startup: false, tree_combine: true };
        let (_, merged_warm, _) =
            sharded.run_job_cfg(Arc::new(CombSum), &s, &cache, warm).unwrap();
        assert_eq!(merged_warm.sim.net_s, 0.0, "warm jobs must not re-pay the transfer");
        assert!(merged_warm.shard_steals > 0, "the counters still describe the plan");
    }

    #[test]
    fn sharded_session_charges_startup_once_per_shard() {
        let s = store(8);
        let mut sharded =
            ShardedEngine::new(&s, &EngineOptions::default(), OverheadConfig::default(), 2, 4.0);
        let startup = sharded.overhead().job_startup_s;
        let cache = Arc::new(DistributedCache::new());
        let mut session = sharded.session(&s, SessionOptions::default());
        for it in 0..3 {
            let jobs = vec![Arc::new(CombSum), Arc::new(CombSum)];
            let (segments, stats, cfg) =
                session.run_iteration_segments(&jobs, &cache).unwrap();
            let (parts, merges) = complete_global_dag(
                jobs[0].as_ref(),
                segments.into_iter().flatten().collect(),
                8,
                cfg.tree_combine,
            )
            .unwrap();
            let reduce_parts = parts.len();
            let merged = session.finalize_iteration(
                &stats,
                std::time::Duration::from_secs(0),
                0.0,
                merges,
                reduce_parts,
            );
            if it == 0 {
                assert!((merged.sim.job_startup_s - 2.0 * startup).abs() < 1e-9);
            } else {
                assert_eq!(merged.sim.job_startup_s, 0.0, "resident iterations re-pay nothing");
            }
        }
        assert_eq!(session.iterations(), 3);
    }

    #[test]
    fn merge_mode_parses_and_roundtrips() {
        assert_eq!("exact".parse::<ShardMergeMode>().unwrap(), ShardMergeMode::Exact);
        assert_eq!(
            "representative".parse::<ShardMergeMode>().unwrap(),
            ShardMergeMode::Representative
        );
        assert_eq!("rep".parse::<ShardMergeMode>().unwrap(), ShardMergeMode::Representative);
        assert!("fuzzy".parse::<ShardMergeMode>().is_err());
        assert_eq!(ShardMergeMode::Exact.as_str(), "exact");
        assert_eq!(ShardMergeMode::Representative.as_str(), "representative");
    }
}
