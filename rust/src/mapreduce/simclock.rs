//! The virtual-cluster cost model.
//!
//! Our single process runs the same algorithmic work as the paper's Hadoop
//! cluster but pays none of its platform costs. The SimClock restores those
//! costs from the [`crate::config::OverheadConfig`] calibration so that
//! *modelled* times are comparable across systems:
//!
//! ```text
//! modelled job time = job_startup
//!                   + map makespan over W workers of
//!                       (task_launch + hdfs_read(block) + compute·scale)
//!                   + shuffle_bytes · shuffle_rate
//!                   + task_launch + reduce_compute·scale
//! ```
//!
//! Real (wall) time is always reported alongside; nothing is hidden.

use std::time::Duration;

use crate::config::OverheadConfig;

/// Cost breakdown of a modelled run, in seconds of virtual cluster time.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimCost {
    pub job_startup_s: f64,
    pub task_launch_s: f64,
    pub hdfs_io_s: f64,
    pub shuffle_s: f64,
    pub compute_s: f64,
    /// Network transport (the serving front's wire bytes), charged the
    /// way HDFS I/O is: bytes × a calibrated per-MiB rate.
    pub net_s: f64,
    /// Modelled retry-backoff waits (transient-fault recovery). Charged in
    /// virtual time only — the process never actually sleeps.
    pub backoff_s: f64,
}

impl SimCost {
    pub fn total_s(&self) -> f64 {
        self.job_startup_s
            + self.task_launch_s
            + self.hdfs_io_s
            + self.shuffle_s
            + self.compute_s
            + self.net_s
            + self.backoff_s
    }

    pub fn add(&mut self, other: &SimCost) {
        self.job_startup_s += other.job_startup_s;
        self.task_launch_s += other.task_launch_s;
        self.hdfs_io_s += other.hdfs_io_s;
        self.shuffle_s += other.shuffle_s;
        self.compute_s += other.compute_s;
        self.net_s += other.net_s;
        self.backoff_s += other.backoff_s;
    }

    /// Field-wise `self − before`: a run's share of a shared clock's cost
    /// (callers snapshot the clock before, subtract after). One place to
    /// update when a cost class is added.
    pub fn delta(&self, before: &SimCost) -> SimCost {
        SimCost {
            job_startup_s: self.job_startup_s - before.job_startup_s,
            task_launch_s: self.task_launch_s - before.task_launch_s,
            hdfs_io_s: self.hdfs_io_s - before.hdfs_io_s,
            shuffle_s: self.shuffle_s - before.shuffle_s,
            compute_s: self.compute_s - before.compute_s,
            net_s: self.net_s - before.net_s,
            backoff_s: self.backoff_s - before.backoff_s,
        }
    }
}

/// Accumulates modelled cluster time across jobs of one pipeline run.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    cost: SimCost,
    jobs: usize,
    tasks: usize,
}

/// One map task's modelled inputs.
#[derive(Clone, Copy, Debug)]
pub struct TaskSample {
    /// Real compute seconds measured for this task.
    pub compute_wall_s: f64,
    /// Bytes read from the block store.
    pub input_bytes: u64,
    /// Attempts consumed (failures re-charge launch + work).
    pub attempts: usize,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one complete MapReduce job.
    ///
    /// `workers` is the map slot count; the makespan is computed by greedy
    /// wave scheduling (each task to the earliest-free worker, in order —
    /// what the JobTracker does with a single rack).
    pub fn charge_job(
        &mut self,
        overhead: &OverheadConfig,
        workers: usize,
        map_tasks: &[TaskSample],
        shuffle_bytes: u64,
        reduce_wall_s: f64,
    ) -> SimCost {
        let workers = workers.max(1);
        let mib = |b: u64| b as f64 / (1024.0 * 1024.0);

        // Per-task modelled duration (all attempts pay launch + IO + work).
        let mut free = vec![0.0f64; workers]; // earliest-free time per slot
        let mut launch_total = 0.0;
        let mut io_total = 0.0;
        let mut compute_total = 0.0;
        for t in map_tasks {
            let attempts = t.attempts.max(1) as f64;
            let launch = overhead.task_launch_s * attempts;
            let io = mib(t.input_bytes) * overhead.hdfs_s_per_mib * attempts;
            let work = t.compute_wall_s * overhead.compute_scale * attempts;
            launch_total += launch;
            io_total += io;
            compute_total += work;
            // Greedy: earliest-free slot gets the task.
            let slot = free
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            free[slot] += launch + io + work;
        }
        let map_makespan = free.iter().cloned().fold(0.0, f64::max);
        let shuffle = mib(shuffle_bytes) * overhead.shuffle_s_per_mib;

        // Latency accounting: startup + map makespan + shuffle + reduce.
        // The launch/io/compute split inside the makespan is attributed
        // proportionally (capacity view) so reports can show a breakdown.
        let in_makespan = launch_total + io_total + compute_total;
        let frac = |part: f64| {
            if in_makespan > 0.0 {
                map_makespan * part / in_makespan
            } else {
                0.0
            }
        };
        let exact = SimCost {
            job_startup_s: overhead.job_startup_s,
            task_launch_s: frac(launch_total) + overhead.task_launch_s,
            hdfs_io_s: frac(io_total),
            shuffle_s: shuffle,
            compute_s: frac(compute_total) + reduce_wall_s * overhead.compute_scale,
            net_s: 0.0,
            backoff_s: 0.0,
        };
        self.cost.add(&exact);
        self.jobs += 1;
        self.tasks += map_tasks.len();
        exact
    }

    /// Charge driver-side (non-MR) compute, e.g. the pre-clustering or the
    /// worker-side combine-tree merges; returns the seconds charged so
    /// callers can fold the same amount into a per-job cost breakdown.
    pub fn charge_local(&mut self, overhead: &OverheadConfig, wall: Duration) -> f64 {
        let s = wall.as_secs_f64() * overhead.compute_scale;
        self.cost.compute_s += s;
        s
    }

    /// Charge a one-off HDFS scan of `bytes` (e.g. the driver sampling, or
    /// wasted prefetch reads); returns the seconds charged so callers can
    /// fold the same amount into a per-job cost without re-deriving the
    /// formula.
    pub fn charge_scan(&mut self, overhead: &OverheadConfig, bytes: u64) -> f64 {
        let s = bytes as f64 / (1024.0 * 1024.0) * overhead.hdfs_s_per_mib;
        self.cost.hdfs_io_s += s;
        s
    }

    /// Charge wire transport of `bytes` (the serving front's frames in +
    /// frames out), modelled like HDFS I/O: bytes × `net_s_per_mib`.
    /// Returns the seconds charged.
    pub fn charge_net(&mut self, overhead: &OverheadConfig, bytes: u64) -> f64 {
        let s = bytes as f64 / (1024.0 * 1024.0) * overhead.net_s_per_mib;
        self.cost.net_s += s;
        s
    }

    /// Charge modelled retry-backoff wait (seconds of virtual time). The
    /// fault-recovery paths never sleep for real; they account the
    /// exponential-backoff schedule here so modelled times stay honest
    /// about what a cluster would have paid. Returns the seconds charged.
    pub fn charge_backoff(&mut self, s: f64) -> f64 {
        self.cost.backoff_s += s;
        s
    }

    /// Fold an externally computed cost share into this clock — the
    /// sharded engine's merge: per-shard clocks advance concurrently, and
    /// the global clock takes the critical (max-cost) shard's share per
    /// job plus the cross-shard extras. No per-class rate math happens
    /// here; the share was already charged by a shard's own clock.
    pub fn absorb(&mut self, cost: &SimCost, jobs: usize, tasks: usize) {
        self.cost.add(cost);
        self.jobs += jobs;
        self.tasks += tasks;
    }

    pub fn cost(&self) -> SimCost {
        self.cost
    }

    pub fn total_s(&self) -> f64 {
        self.cost.total_s()
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn tasks(&self) -> usize {
        self.tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn overhead() -> OverheadConfig {
        OverheadConfig {
            job_startup_s: 10.0,
            task_launch_s: 1.0,
            shuffle_s_per_mib: 0.1,
            hdfs_s_per_mib: 0.1,
            net_s_per_mib: 0.2,
            compute_scale: 2.0,
        }
    }

    fn task(compute: f64) -> TaskSample {
        TaskSample { compute_wall_s: compute, input_bytes: 10 * 1024 * 1024, attempts: 1 }
    }

    #[test]
    fn single_task_job_cost() {
        let mut clock = SimClock::new();
        let cost = clock.charge_job(&overhead(), 4, &[task(1.0)], 1024 * 1024, 0.5);
        // startup 10 + (launch 1 + io 1 + compute 2) + shuffle 0.1
        // + reduce (launch 1 + 1.0) = 16.1
        assert!((cost.total_s() - 16.1).abs() < 1e-9, "{}", cost.total_s());
        assert_eq!(clock.jobs(), 1);
        assert_eq!(clock.tasks(), 1);
    }

    #[test]
    fn waves_parallelise_makespan() {
        let mut clock = SimClock::new();
        // 8 equal tasks on 4 workers → 2 waves.
        let tasks: Vec<TaskSample> = (0..8).map(|_| task(1.0)).collect();
        let c8 = clock.charge_job(&overhead(), 4, &tasks, 0, 0.0);
        let mut clock2 = SimClock::new();
        let c4 = clock2.charge_job(&overhead(), 4, &tasks[..4], 0, 0.0);
        // Map portion doubles (2 waves vs 1): job diff = one wave of 4s.
        let map8 = c8.total_s() - 10.0 - 1.0; // minus startup & reduce launch
        let map4 = c4.total_s() - 10.0 - 1.0;
        assert!((map8 - 2.0 * map4).abs() < 1e-9, "{map8} vs {map4}");
    }

    #[test]
    fn more_workers_shrink_makespan() {
        let tasks: Vec<TaskSample> = (0..16).map(|_| task(1.0)).collect();
        let mut a = SimClock::new();
        let mut b = SimClock::new();
        let slow = a.charge_job(&overhead(), 2, &tasks, 0, 0.0);
        let fast = b.charge_job(&overhead(), 16, &tasks, 0, 0.0);
        assert!(slow.total_s() > fast.total_s());
    }

    #[test]
    fn failed_attempts_cost_more() {
        let mut a = SimClock::new();
        let mut b = SimClock::new();
        let ok = a.charge_job(&overhead(), 1, &[task(1.0)], 0, 0.0);
        let mut retried = task(1.0);
        retried.attempts = 3;
        let bad = b.charge_job(&overhead(), 1, &[retried], 0, 0.0);
        assert!(bad.total_s() > ok.total_s() + 2.0 * (1.0 + 1.0 + 2.0) - 1e-9);
    }

    #[test]
    fn accumulates_across_jobs() {
        let mut clock = SimClock::new();
        for _ in 0..5 {
            clock.charge_job(&overhead(), 4, &[task(0.1)], 0, 0.0);
        }
        assert_eq!(clock.jobs(), 5);
        // 5 × startup alone = 50s.
        assert!(clock.total_s() >= 50.0);
    }

    #[test]
    fn delta_isolates_a_runs_share() {
        let mut clock = SimClock::new();
        clock.charge_job(&overhead(), 4, &[task(1.0)], 1024 * 1024, 0.5);
        let before = clock.cost();
        clock.charge_job(&overhead(), 4, &[task(2.0)], 0, 0.0);
        let share = clock.cost().delta(&before);
        let mut fresh = SimClock::new();
        let direct = fresh.charge_job(&overhead(), 4, &[task(2.0)], 0, 0.0);
        assert!((share.total_s() - direct.total_s()).abs() < 1e-9);
        assert!((share.job_startup_s - direct.job_startup_s).abs() < 1e-9);
    }

    #[test]
    fn local_and_scan_charges() {
        let mut clock = SimClock::new();
        clock.charge_local(&overhead(), Duration::from_secs(2));
        clock.charge_scan(&overhead(), 100 * 1024 * 1024);
        // 2·2.0 compute + 100·0.1 io
        assert!((clock.total_s() - 14.0).abs() < 1e-9);
    }

    #[test]
    fn backoff_charges_accumulate_and_delta() {
        let mut clock = SimClock::new();
        let s = clock.charge_backoff(0.3);
        assert!((s - 0.3).abs() < 1e-12);
        assert!((clock.cost().backoff_s - 0.3).abs() < 1e-12);
        assert!((clock.total_s() - 0.3).abs() < 1e-12);
        let before = clock.cost();
        clock.charge_backoff(0.7);
        assert!((clock.cost().delta(&before).backoff_s - 0.7).abs() < 1e-12);
        let mut sum = SimCost::default();
        sum.add(&clock.cost());
        assert!((sum.backoff_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn net_charges_accumulate_like_io() {
        let mut clock = SimClock::new();
        let s = clock.charge_net(&overhead(), 10 * 1024 * 1024);
        assert!((s - 1.0).abs() < 1e-9, "{s}");
        assert!((clock.cost().net_s - 1.0).abs() < 1e-9);
        assert!((clock.total_s() - 1.0).abs() < 1e-9);
        let before = clock.cost();
        clock.charge_net(&overhead(), 5 * 1024 * 1024);
        assert!((clock.cost().delta(&before).net_s - 0.5).abs() < 1e-9);
    }
}
