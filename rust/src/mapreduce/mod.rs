//! Mini-Hadoop: a MapReduce engine over the [`crate::hdfs`] block store.
//!
//! What it reproduces from the paper's platform:
//!
//! * **job / task lifecycle** — a job = map tasks (one per block, combiner
//!   folded in, as the paper runs FCM inside the combiner) + one reduce;
//! * **distributed cache** — a read-only key-value store every task can
//!   read, written by the driver (the paper stores V_init there);
//! * **scheduling** — map tasks run on a fixed worker pool, each drained
//!   from a per-worker queue built from the blocks' locality hints
//!   ([`crate::hdfs::BlockMeta::preferred_worker`]), stealing only when a
//!   queue runs dry — Hadoop's data-local task assignment;
//! * **fault tolerance** — injectable task failures with Hadoop's
//!   re-execution semantics (4 attempts), exercising combiner idempotence;
//! * **cost model** — a [`simclock::SimClock`] charging job startup, task
//!   launch, HDFS I/O and shuffle the way the paper's physical cluster paid
//!   them, so job-per-iteration baselines show their true relative cost on
//!   a single machine (DESIGN.md §3);
//! * **block caching + prefetch** — map tasks stream their blocks through a
//!   shared byte-budgeted LRU [`cache::BlockCache`] (the paper's "efficient
//!   caching design"): blocks are decoded inside the map slot, dropped when
//!   the task ends, kept warm across the jobs of one engine, and pulled in
//!   ahead of demand by the engine's prefetcher so disk latency overlaps
//!   compute (depth 2 when the byte budget has slack);
//! * **worker-side tree combine** — jobs that implement
//!   [`MapReduceJob::combine`] have their map outputs merged pairwise on
//!   the pool as slots drain (the thread pool's combining drain), so
//!   shuffle bytes and the reduce funnel drop from O(blocks) to
//!   O(workers + log blocks);
//! * **iteration-resident sessions** — [`session::IterativeSession`] spans
//!   every iteration of a convergence loop: one job-startup charge, warm
//!   pool/cache/prefetcher across iterations, and a byte-accounted sticky
//!   [`session::StateSlab`] where kernels persist per-block derived state
//!   (the pruning bounds of `crate::fcm::backend`) between iterations —
//!   spilling cold state to a disk ring instead of evicting it when a
//!   [`session::SpillConfig`] is set.

pub mod cache;
pub mod engine;
pub mod session;
pub mod shard;
pub mod simclock;

pub use cache::{BlockCache, CachedBlock, DistributedCache, ReadSource, MIB};
pub use engine::{Engine, EngineOptions, JobRunCfg, JobStats};
pub use session::{IterativeSession, SessionOptions, SlabState, SpillConfig, StateSlab};
pub use shard::{ShardMergeMode, ShardPlan, ShardedEngine, ShardedSession};
pub use simclock::{SimClock, SimCost};

use crate::data::Matrix;
use crate::error::{Error, Result};

/// Context handed to every task attempt.
pub struct TaskCtx<'a> {
    /// Read-only distributed cache.
    pub cache: &'a DistributedCache,
    /// Block/task id.
    pub task_id: usize,
    /// Attempt number (0 = first attempt).
    pub attempt: usize,
    /// This attempt's output will be discarded by the engine's modelled
    /// fault injection and the task re-executed. Jobs with side-band
    /// state or counters (the session's sticky slab and `records_pruned`)
    /// use this to keep doomed attempts from polluting them; the attempt
    /// still runs and is still charged, like a real failed task.
    pub doomed: bool,
}

/// A MapReduce job. `map_combine` is the fused map+combiner the paper runs
/// (the mapper parses records, the combiner clusters them); `reduce` folds
/// all combiner outputs into the job result.
///
/// Both must be pure with respect to their inputs — the engine re-executes
/// failed attempts, exactly like Hadoop.
pub trait MapReduceJob: Send + Sync {
    /// Per-block combiner output (shipped through the shuffle).
    type MapOut: Send + 'static;
    /// Job result (written back to the "HDFS" by the caller).
    type Output: Send;

    /// Fused map+combine over one block of records.
    fn map_combine(&self, block: &Matrix, ctx: &TaskCtx) -> Result<Self::MapOut>;

    /// Reduce over all combiner outputs (input order = block order).
    fn reduce(&self, parts: Vec<Self::MapOut>, ctx: &TaskCtx) -> Result<Self::Output>;

    /// Whether [`Self::combine`] implements a real pairwise merge. When
    /// true (and the engine's tree-combine knob is on) map outputs merge
    /// pairwise on the worker pool as map slots drain, so [`Self::reduce`]
    /// sees O(workers + log blocks) pre-merged segments instead of one
    /// output per block — and the modelled shuffle ships only those.
    fn supports_combine(&self) -> bool {
        false
    }

    /// Pairwise combine of two **adjacent** map-output segments (`left`
    /// always covers the lower block ids). Must be equivalent to folding
    /// the two segments in block order; the engine's merge tree has a
    /// topology and operand order fixed by the block count, so any combine
    /// meeting that contract — including order-sensitive ones like pool
    /// concatenation — yields deterministic results.
    fn combine(&self, left: Self::MapOut, right: Self::MapOut) -> Result<Self::MapOut> {
        let _ = (left, right);
        Err(Error::Job(format!("job `{}` does not implement combine", self.name())))
    }

    /// Serialised size of one combiner output, for the shuffle cost model.
    fn shuffle_bytes(&self, part: &Self::MapOut) -> u64;

    /// Job name for telemetry.
    fn name(&self) -> &str {
        "job"
    }
}
