//! Iteration-resident sessions: one session spans every iteration of a
//! convergence loop over one block store.
//!
//! The Mahout-style one-job-per-iteration pattern pays a full job startup,
//! a cold distributed-cache push and a flat reduce funnel *per iteration* —
//! the dominant cost of iterative clustering on Hadoop (PAPER.md §3;
//! Parallel Hierarchical Affinity Propagation, arXiv:1403.7394, makes the
//! same observation). An [`IterativeSession`] keeps the engine's worker
//! pool, block cache, locality queues and prefetcher warm across the jobs
//! of one loop, charges the modelled job startup once, and gives kernels a
//! **sticky per-block state slab** ([`StateSlab`]) — keyed by block id,
//! byte-accounted against its own budget — where derived state (the
//! shift-bounded pruning bounds of `crate::fcm::backend`) persists between
//! iterations.
//!
//! The slab deliberately lives *outside* the block cache: per-job cache
//! meter resets ([`crate::mapreduce::BlockCache::reset_job_meters`]) and
//! even a full block `clear()` can never invalidate bounds the pruning
//! path still holds. Slab lifetime is the session's, ended only by its own
//! byte budget or an explicit [`StateSlab::invalidate_all`].
//!
//! ## The disk spill ring
//!
//! Under budget pressure a slab with a [`SpillConfig`] does not evict cold
//! state — it **spills** it to a disk ring (one slot file per block,
//! overwritten in place, removed when the slab drops) through the state's
//! bitwise [`SlabState::spill`]/[`SlabState::unspill`] codec, and reloads
//! it on the block's next touch. Eviction forces the next pass to
//! recompute the bounds exactly (a full kernel pass over the block);
//! rereading costs only the state's own bytes at disk rate — so the slab
//! applies a modelled recompute-vs-reread crossover
//! ([`SpillConfig::max_recompute_ratio`] × [`SlabState::recompute_bytes`])
//! and falls back to eviction for states too large to be worth the round
//! trip. Spill writes and reloads are metered
//! ([`StateSlab::spilled_bytes`], [`StateSlab::reloads`]) and charged to
//! the modelled clock by the session loop, surfacing in
//! [`crate::mapreduce::JobStats::slab_spilled_bytes`] /
//! [`crate::mapreduce::JobStats::slab_reloads`]. Because the codec is
//! bitwise, a spill/reload round trip never changes results — pinned by
//! `rust/tests/integration_streaming.rs`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::Result;
use crate::faults::{backoff_s, FaultPlan, FaultSite, Injected, MAX_READ_RETRIES};
use crate::hdfs::{spill_slot_path as slot_path, BlockStore};
use crate::mapreduce::engine::{Engine, JobRunCfg, JobStats};
use crate::mapreduce::{DistributedCache, MapReduceJob};
use crate::telemetry::trace;

/// How a session schedules its iterations.
#[derive(Clone, Copy, Debug)]
pub struct SessionOptions {
    /// Resident sessions charge the modelled job startup once (first
    /// iteration only) — the pool, cache and prefetcher stay warm. A
    /// non-resident session pays it every iteration, like a fresh Hadoop
    /// job submission.
    pub resident: bool,
    /// Worker-side tree combine for this session's jobs; `None` inherits
    /// the engine option.
    pub tree_combine: Option<bool>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        Self { resident: true, tree_combine: None }
    }
}

impl SessionOptions {
    /// The Mahout-style control arm: every iteration pays job startup and
    /// funnels every map output through the flat reduce — exactly the
    /// pre-session engine behaviour, for honest A/B rows.
    pub fn per_job() -> Self {
        Self { resident: false, tree_combine: Some(false) }
    }
}

/// State a kernel may persist in a [`StateSlab`] between iterations.
pub trait SlabState: Send {
    /// Bytes this state is accounted at against the slab budget.
    fn slab_bytes(&self) -> u64;

    /// Modelled bytes an exact recompute of this state would re-read (the
    /// block payload) — the reread-vs-recompute crossover input of the
    /// slab's spill policy. 0 (the default) means unknown: always worth
    /// spilling.
    fn recompute_bytes(&self) -> u64 {
        0
    }

    /// Bitwise serialisation for the slab's disk ring. `None` (the
    /// default) marks the state unspillable — budget pressure then evicts
    /// it, exactly the pre-spill behaviour.
    fn spill(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore from a spilled image; `None` on a corrupt or foreign image
    /// (the slab then starts the block from an empty state).
    fn unspill(bytes: &[u8]) -> Option<Self>
    where
        Self: Sized,
    {
        let _ = bytes;
        None
    }
}

impl SlabState for () {
    fn slab_bytes(&self) -> u64 {
        0
    }
}

/// Disk ring configuration of a [`StateSlab`] (see the module docs).
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Ring directory — created on first spill; one slot file per block,
    /// overwritten in place on re-spill, removed when the slab drops.
    pub dir: PathBuf,
    /// Spill while `slab_bytes ≤ ratio × recompute_bytes`; colder states
    /// (larger than a few block payloads) evict and recompute instead.
    /// Rereading also saves the recompute's kernel time, which is why the
    /// crossover sits above 1.
    pub max_recompute_ratio: f64,
    /// Chaos plan for the ring's read/write sites (`None` in production).
    pub faults: Option<Arc<FaultPlan>>,
}

impl SpillConfig {
    pub fn new(dir: PathBuf) -> Self {
        Self { dir, max_recompute_ratio: 4.0, faults: None }
    }

    /// Attach a chaos plan to the ring's read/write sites.
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> Self {
        self.faults = faults;
        self
    }
}

struct SlabEntry<S> {
    state: Arc<Mutex<S>>,
    bytes: u64,
    last_touch: u64,
}

/// One block's place in the spill ring.
enum SpillSlot<S> {
    /// Staged: the state itself is still live behind this Arc while the
    /// flusher encodes and writes it — so a reload in that window simply
    /// re-adopts the state (trivially bitwise, no I/O), and neither the
    /// encode nor the write ever runs under the slab's inner lock. `gen`
    /// lets the flusher detect that the slot was adopted or re-spilled
    /// since staging and stand down.
    InFlight { state: Arc<Mutex<S>>, gen: u64 },
    /// Image fully written to the ring slot (the write was verified
    /// still-current before the transition, so the file is exactly the
    /// latest image).
    OnDisk,
}

struct SlabInner<S> {
    entries: HashMap<usize, SlabEntry<S>>,
    bytes: u64,
    tick: u64,
    /// Monotonic spill-staging counter (the `InFlight` generation source).
    spill_gen: u64,
    /// Blocks with state in the ring (staged or written).
    spilled: HashMap<usize, SpillSlot<S>>,
    /// Every slot path ever written (removed when the slab drops).
    spill_paths: HashMap<usize, PathBuf>,
}

/// A state staged for an off-lock ring write: `(block, generation, state)`.
type StagedSpill<S> = (usize, u64, Arc<Mutex<S>>);

/// Sticky per-block state, keyed by block id and byte-accounted against a
/// budget of its own (configured via `cluster.slab_mib`). The global lock
/// covers lookup and accounting only — ring **encode and disk I/O never
/// run under it** (victims are staged as O(1) `InFlight` slots and
/// encoded + written after the lock drops, serialized by `flush_lock`;
/// reloads of written slots claim the slot under the lock and read the
/// file outside it) — so map tasks of different blocks never serialize on
/// spill-ring traffic.
///
/// Exceeding the budget moves the least-recently-touched *other* entries
/// out — to the disk spill ring when one is configured and the state is
/// worth the round trip, otherwise by eviction (the block then recomputes
/// exactly on its next pass). Entries whose state lock is held (a map
/// task mid-pass) are skipped, and an entry removed while its holder was
/// still computing is re-inserted fresh by the holder's
/// [`StateSlab::note_update`] — no update is ever lost and no stale
/// spilled image can shadow a newer state.
pub struct StateSlab<S> {
    budget_bytes: u64,
    spill: Option<SpillConfig>,
    inner: Mutex<SlabInner<S>>,
    /// Serializes ring writes across callers (never held with `inner`):
    /// at most the latest staged image per slot ever reaches its file.
    flush_lock: Mutex<()>,
    dir_ready: std::sync::atomic::AtomicBool,
    evictions: AtomicU64,
    records_pruned: AtomicU64,
    records_pruned_quant: AtomicU64,
    quant_sidecar_bytes: AtomicU64,
    quant_build_ns: AtomicU64,
    spills: AtomicU64,
    spilled_bytes: AtomicU64,
    reloads: AtomicU64,
    reload_bytes: AtomicU64,
    /// Transient-fault retries taken by ring reloads (chaos runs only).
    spill_retries: AtomicU64,
    /// Checksum-quarantine re-reads of ring slots (chaos runs only).
    spill_quarantines: AtomicU64,
    /// Ring reloads that exhausted the retry budget and fell back to the
    /// recompute path (fresh state; the block's next pass is exact).
    spill_read_aborts: AtomicU64,
    /// Modelled retry-backoff accumulated by ring reloads, in nanoseconds
    /// (the session loop drains the delta into the SimClock).
    backoff_ns: AtomicU64,
}

impl<S: SlabState + Default> StateSlab<S> {
    /// Evict-only slab (no spill ring) — the pre-spill behaviour.
    pub fn with_budget_bytes(budget_bytes: u64) -> Self {
        Self::new(budget_bytes, None)
    }

    pub fn new(budget_bytes: u64, spill: Option<SpillConfig>) -> Self {
        Self {
            budget_bytes,
            spill,
            inner: Mutex::new(SlabInner {
                entries: HashMap::new(),
                bytes: 0,
                tick: 0,
                spill_gen: 0,
                spilled: HashMap::new(),
                spill_paths: HashMap::new(),
            }),
            flush_lock: Mutex::new(()),
            dir_ready: std::sync::atomic::AtomicBool::new(false),
            evictions: AtomicU64::new(0),
            records_pruned: AtomicU64::new(0),
            records_pruned_quant: AtomicU64::new(0),
            quant_sidecar_bytes: AtomicU64::new(0),
            quant_build_ns: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            spilled_bytes: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            reload_bytes: AtomicU64::new(0),
            spill_retries: AtomicU64::new(0),
            spill_quarantines: AtomicU64::new(0),
            spill_read_aborts: AtomicU64::new(0),
            backoff_ns: AtomicU64::new(0),
        }
    }

    /// Read a ring slot with bounded fault recovery: injected transient
    /// errors retry (modelled backoff accrued into `backoff_ns`, never
    /// slept); injected corruption quarantines the torn image and re-reads
    /// the slot once per incident. When the retry budget is exhausted —
    /// or the file is genuinely unreadable — the slab degrades to the
    /// documented recompute path: a fresh state, so the block's next pass
    /// is exact. The ring can therefore *delay* results but never change
    /// them or fail a session.
    fn read_slot_recovered(&self, path: &PathBuf) -> (S, u64) {
        // Ambient: nests under the worker's open map_task span.
        let _span = trace::global().span("spill_reload", "session");
        let plan = self.spill.as_ref().and_then(|c| c.faults.as_ref());
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            match plan.and_then(|p| p.check(FaultSite::SpillRead)) {
                None => {
                    return match std::fs::read(path) {
                        Ok(img) => self.decode_reload(&img),
                        Err(_) => (S::default(), 0),
                    };
                }
                Some(Injected::Corrupt) => {
                    // Torn image on arrival: discard it unread (never adopt
                    // bytes known to be torn) and re-read the slot.
                    self.spill_quarantines.fetch_add(1, Ordering::Relaxed);
                }
                Some(_) => {
                    if attempt < MAX_READ_RETRIES {
                        self.spill_retries.fetch_add(1, Ordering::Relaxed);
                        let ns = (backoff_s(attempt) * 1e9).round() as u64;
                        self.backoff_ns.fetch_add(ns, Ordering::Relaxed);
                    }
                }
            }
            if attempt >= MAX_READ_RETRIES {
                self.spill_read_aborts.fetch_add(1, Ordering::Relaxed);
                return (S::default(), 0);
            }
        }
    }

    /// Decode a spilled image, counting the reload; a corrupt image
    /// yields a fresh state (the block recomputes exactly).
    fn decode_reload(&self, img: &[u8]) -> (S, u64) {
        match S::unspill(img) {
            Some(s) => {
                let bytes = s.slab_bytes();
                self.reloads.fetch_add(1, Ordering::Relaxed);
                self.reload_bytes.fetch_add(img.len() as u64, Ordering::Relaxed);
                (s, bytes)
            }
            None => (S::default(), 0),
        }
    }

    /// Handle to `block`'s sticky state — created empty on first touch, or
    /// reloaded from the spill ring when an image is waiting there (from
    /// the staged in-memory copy when its write is still in flight, from
    /// the slot file otherwise). Touching marks the entry
    /// most-recently-used.
    pub fn entry(&self, block: usize) -> Arc<Mutex<S>> {
        let mut staged: Vec<StagedSpill<S>> = Vec::new();
        let arc = {
            let mut inner = self.inner.lock().expect("state slab poisoned");
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.entries.get_mut(&block) {
                e.last_touch = tick;
                return Arc::clone(&e.state);
            }
            let (arc, bytes) = match inner.spilled.remove(&block) {
                Some(SpillSlot::InFlight { state, .. }) => {
                    // The flush has not landed: re-adopt the live state
                    // directly (no I/O, trivially bitwise); the flusher's
                    // generation check sees the slot gone and stands down.
                    // If it is mid-encode it holds the state lock — adopt
                    // anyway with unknown size; note_update corrects it.
                    let bytes = state.try_lock().map(|st| st.slab_bytes()).unwrap_or(0);
                    (state, bytes)
                }
                Some(SpillSlot::OnDisk) => {
                    // Claim the slot, then read outside the lock: the block
                    // is now in neither map, and only this block's own map
                    // task calls entry/note_update for it, so nothing can
                    // race the gap — and the file is complete (OnDisk is
                    // only set after a verified-current write) and cannot
                    // be overwritten before a future spill, which needs
                    // this entry() to finish first.
                    let path = inner.spill_paths.get(&block).cloned();
                    drop(inner);
                    let (state, bytes) = match &path {
                        Some(p) => self.read_slot_recovered(p),
                        None => (S::default(), 0),
                    };
                    inner = self.inner.lock().expect("state slab poisoned");
                    (Arc::new(Mutex::new(state)), bytes)
                }
                None => (Arc::new(Mutex::new(S::default())), 0),
            };
            inner.entries.insert(
                block,
                SlabEntry { state: Arc::clone(&arc), bytes, last_touch: tick },
            );
            inner.bytes += bytes;
            // Make room for the reload by moving *others* out; the entry
            // just handed out is never removed here (its task is about to
            // run — note_update resolves any remaining overage).
            self.enforce_budget(&mut inner, block, false, &mut staged);
            arc
        };
        self.flush_spills(staged);
        arc
    }

    /// Record `block`'s new byte size after a mutation (the caller measures
    /// it via [`SlabState::slab_bytes`] and drops the state lock first —
    /// the slab only ever `try_lock`s a state, so lock order can never
    /// deadlock). If the entry was spilled or evicted while the caller was
    /// computing, the caller's handle — the freshest state — is re-inserted
    /// and any stale spilled image dropped. Moves entries out beyond the
    /// budget.
    pub fn note_update(&self, block: usize, handle: &Arc<Mutex<S>>, bytes: u64) {
        let mut staged: Vec<StagedSpill<S>> = Vec::new();
        {
            let mut inner = self.inner.lock().expect("state slab poisoned");
            let st = &mut *inner;
            st.tick += 1;
            let tick = st.tick;
            if let Some(e) = st.entries.get_mut(&block) {
                st.bytes = st.bytes + bytes - e.bytes;
                e.bytes = bytes;
                e.last_touch = tick;
            } else {
                // Removed while held: the image (if any) predates this
                // update — drop it so it can never shadow the fresh state.
                st.spilled.remove(&block);
                st.entries.insert(
                    block,
                    SlabEntry { state: Arc::clone(handle), bytes, last_touch: tick },
                );
                st.bytes += bytes;
            }
            self.enforce_budget(st, block, true, &mut staged);
        }
        self.flush_spills(staged);
    }

    /// Move least-recently-touched entries out until the budget holds,
    /// skipping `exclude` and any entry whose state lock is held. With
    /// `allow_exclude_removal`, a lone over-budget `exclude` is moved out
    /// too (mirroring the old "an over-budget state does not stick" rule —
    /// with a spill ring it sticks on disk instead).
    fn enforce_budget(
        &self,
        inner: &mut SlabInner<S>,
        exclude: usize,
        allow_exclude_removal: bool,
        staged: &mut Vec<StagedSpill<S>>,
    ) {
        if inner.bytes <= self.budget_bytes {
            return;
        }
        let mut victims: Vec<(u64, usize)> = inner
            .entries
            .iter()
            .filter(|(id, _)| **id != exclude)
            .map(|(id, e)| (e.last_touch, *id))
            .collect();
        victims.sort_unstable();
        for (_, id) in victims {
            if inner.bytes <= self.budget_bytes {
                return;
            }
            self.spill_or_evict(inner, id, staged);
        }
        if allow_exclude_removal
            && inner.bytes > self.budget_bytes
            && inner.entries.len() == 1
            && inner.entries.contains_key(&exclude)
        {
            self.spill_or_evict(inner, exclude, staged);
        }
    }

    /// Stage `id` for the spill ring when configured and worth it, else
    /// evict it. Staging is O(1) under the inner lock — the encode and the
    /// disk write both happen in the caller's off-lock flush. Returns
    /// false (and leaves the entry alone) when the state lock is held — an
    /// in-flight task's entry is never torn down under it.
    fn spill_or_evict(
        &self,
        inner: &mut SlabInner<S>,
        id: usize,
        staged: &mut Vec<StagedSpill<S>>,
    ) -> bool {
        let (arc, ebytes) = match inner.entries.get(&id) {
            Some(e) => (Arc::clone(&e.state), e.bytes),
            None => return false,
        };
        let mut stage = false;
        if let Some(cfg) = &self.spill {
            match arc.try_lock() {
                Ok(st) => {
                    stage = match st.recompute_bytes() {
                        0 => true,
                        rb => st.slab_bytes() as f64 <= cfg.max_recompute_ratio * rb as f64,
                    };
                }
                Err(std::sync::TryLockError::WouldBlock) => return false, // in use: skip
                Err(std::sync::TryLockError::Poisoned(_)) => {} // torn state: evict
            }
        }
        if stage {
            inner.spill_gen += 1;
            let gen = inner.spill_gen;
            inner
                .spilled
                .insert(id, SpillSlot::InFlight { state: Arc::clone(&arc), gen });
            staged.push((id, gen, arc));
        } else {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        inner.entries.remove(&id);
        inner.bytes -= ebytes;
        true
    }

    /// Encode and write staged states to the ring — serialized across
    /// callers by `flush_lock`, **never** under the slab's inner lock, so
    /// other map tasks' bookkeeping proceeds while a spill encodes and
    /// writes. Each staged slot is re-checked by generation first (adopted
    /// or re-spilled slots stand down), so the slot file only ever holds
    /// the latest still-current image — what makes `OnDisk` reads sound.
    /// `spills`/`spilled_bytes` count only completed writes; any failure
    /// (unwritable ring, unspillable state) degrades to a counted
    /// eviction with the slot dropped, keeping the byte budget honest —
    /// state is never silently retained in memory.
    fn flush_spills(&self, staged: Vec<StagedSpill<S>>) {
        if staged.is_empty() {
            return;
        }
        let Some(cfg) = &self.spill else { return };
        let _serialized = self.flush_lock.lock().expect("spill flush lock poisoned");
        if !self.dir_ready.load(Ordering::Relaxed)
            && std::fs::create_dir_all(&cfg.dir).is_ok()
        {
            self.dir_ready.store(true, Ordering::Relaxed);
        }
        let dir_ready = self.dir_ready.load(Ordering::Relaxed);
        for (id, gen, arc) in staged {
            let ours = |inner: &SlabInner<S>| {
                matches!(
                    inner.spilled.get(&id),
                    Some(SpillSlot::InFlight { gen: g, .. }) if *g == gen
                )
            };
            if !ours(&self.inner.lock().expect("state slab poisoned")) {
                continue; // adopted back or re-spilled: stand down
            }
            // Encode off the inner lock. A concurrent adopter takes the
            // Arc from the slot map, not this lock — if it beat us to the
            // state lock its task is already computing and the generation
            // check below discards our work.
            let img = match arc.try_lock() {
                Ok(st) => st.spill(),
                Err(std::sync::TryLockError::WouldBlock) => continue, // adopted mid-flight
                Err(std::sync::TryLockError::Poisoned(_)) => None,
            };
            let write_faulted = cfg
                .faults
                .as_ref()
                .map(|p| p.check(FaultSite::SpillWrite).is_some())
                .unwrap_or(false);
            let written = match (&img, dir_ready) {
                // An injected write fault takes the same degraded path as
                // an unwritable ring: counted eviction, slot dropped, the
                // block recomputes exactly on its next pass.
                (Some(img), true) if !write_faulted => {
                    let mut span = trace::global().span("spill", "session");
                    span.attr("block", id.to_string());
                    let path = slot_path(&cfg.dir, id);
                    if std::fs::write(&path, img).is_ok() {
                        Some(path)
                    } else {
                        None
                    }
                }
                _ => None,
            };
            let mut inner = self.inner.lock().expect("state slab poisoned");
            if !ours(&inner) {
                continue;
            }
            match written {
                Some(path) => {
                    inner.spilled.insert(id, SpillSlot::OnDisk);
                    inner.spill_paths.insert(id, path);
                    self.spills.fetch_add(1, Ordering::Relaxed);
                    let bytes = img.expect("written implies img").len() as u64;
                    self.spilled_bytes.fetch_add(bytes, Ordering::Relaxed);
                }
                None => {
                    // Unwritable ring or unspillable state: degrade to the
                    // documented no-spill behaviour — drop the slot (and
                    // with it the state's memory) and count an eviction;
                    // the block recomputes exactly on its next pass.
                    inner.spilled.remove(&id);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Drop every sticky state — resident and spilled (e.g. to force the
    /// next pass exact). Not counted as evictions — this is a deliberate
    /// refresh, not budget pressure.
    pub fn invalidate_all(&self) {
        let mut inner = self.inner.lock().expect("state slab poisoned");
        inner.entries.clear();
        inner.bytes = 0;
        inner.spilled.clear();
    }

    /// Bytes currently resident in the slab (spilled state not counted).
    pub fn bytes(&self) -> u64 {
        self.inner.lock().expect("state slab poisoned").bytes
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("state slab poisoned").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Budget (bytes) this slab holds resident state against.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Entries dropped (not spilled) by budget pressure since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Completed spill-ring writes since construction.
    pub fn spills(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    /// Bytes written to the spill ring since construction.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes.load(Ordering::Relaxed)
    }

    /// Spill-ring reloads (slot-file reads; in-memory re-adoption of a
    /// still-in-flight spill is not an I/O event) since construction.
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Bytes read back from the spill ring since construction.
    pub fn reload_bytes(&self) -> u64 {
        self.reload_bytes.load(Ordering::Relaxed)
    }

    /// Transient-fault retries taken by ring reloads since construction.
    pub fn spill_retries(&self) -> u64 {
        self.spill_retries.load(Ordering::Relaxed)
    }

    /// Checksum-quarantine re-reads of ring slots since construction.
    pub fn spill_quarantines(&self) -> u64 {
        self.spill_quarantines.load(Ordering::Relaxed)
    }

    /// Ring reloads that exhausted retries and recomputed instead.
    pub fn spill_read_aborts(&self) -> u64 {
        self.spill_read_aborts.load(Ordering::Relaxed)
    }

    /// Modelled retry-backoff accumulated by ring reloads, in seconds.
    pub fn backoff_seconds(&self) -> f64 {
        self.backoff_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Add to the shared pruned-records counter (kernels report how many
    /// records reused their cached contribution).
    pub fn add_records_pruned(&self, n: u64) {
        self.records_pruned.fetch_add(n, Ordering::Relaxed);
    }

    /// Drain the pruned-records counter (the session loop reads one
    /// iteration's worth and stamps it into that iteration's [`JobStats`]).
    pub fn take_records_pruned(&self) -> u64 {
        self.records_pruned.swap(0, Ordering::Relaxed)
    }

    /// Add to the quant-rescued subset of the pruned counter (records the
    /// primary bound test abandoned and the certified i8 interval replayed).
    pub fn add_records_pruned_quant(&self, n: u64) {
        self.records_pruned_quant.fetch_add(n, Ordering::Relaxed);
    }

    /// Drain the quant-rescued counter (per-iteration, like
    /// [`Self::take_records_pruned`]).
    pub fn take_records_pruned_quant(&self) -> u64 {
        self.records_pruned_quant.swap(0, Ordering::Relaxed)
    }

    /// Add one pass's resident quant-sidecar footprint. Summed across the
    /// blocks of one iteration this is the iteration's sidecar gauge; the
    /// session loop drains it every iteration, so it never double-counts
    /// across iterations.
    pub fn add_quant_sidecar_bytes(&self, n: u64) {
        self.quant_sidecar_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Drain the per-iteration sidecar-bytes gauge.
    pub fn take_quant_sidecar_bytes(&self) -> u64 {
        self.quant_sidecar_bytes.swap(0, Ordering::Relaxed)
    }

    /// Add time spent building quant sidecars (one-time per block).
    pub fn add_quant_build_ns(&self, n: u64) {
        self.quant_build_ns.fetch_add(n, Ordering::Relaxed);
    }

    /// Drain the sidecar build-time counter (per-iteration).
    pub fn take_quant_build_ns(&self) -> u64 {
        self.quant_build_ns.swap(0, Ordering::Relaxed)
    }
}

impl<S> Drop for StateSlab<S> {
    fn drop(&mut self) {
        // Remove every ring slot this slab ever wrote; the directory
        // itself may be shared (user-supplied) and is left alone.
        if let Ok(inner) = self.inner.lock() {
            for path in inner.spill_paths.values() {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

/// One convergence loop's view of the engine: iterations run as engine
/// jobs, but startup is charged per [`SessionOptions::resident`] and the
/// per-job cache peak meters reset between iterations without dropping
/// warm blocks.
pub struct IterativeSession<'e> {
    engine: &'e mut Engine,
    store: Arc<BlockStore>,
    options: SessionOptions,
    iterations: usize,
}

impl Engine {
    /// Open an iteration-resident session over `store`. The session
    /// borrows the engine exclusively: one convergence loop at a time,
    /// which is also what keeps its warm-state reasoning sound.
    pub fn session<'e>(
        &'e mut self,
        store: &Arc<BlockStore>,
        options: SessionOptions,
    ) -> IterativeSession<'e> {
        IterativeSession { engine: self, store: Arc::clone(store), options, iterations: 0 }
    }
}

impl IterativeSession<'_> {
    /// Run one iteration of the loop as an engine job.
    pub fn run_iteration<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        cache: Arc<DistributedCache>,
    ) -> Result<(J::Output, JobStats)> {
        let cfg = JobRunCfg {
            charge_startup: !self.options.resident || self.iterations == 0,
            tree_combine: self
                .options
                .tree_combine
                .unwrap_or(self.engine.options().tree_combine),
        };
        if self.iterations > 0 {
            // Job-scoped peak metering without evicting warm blocks (the
            // regression the old clear()-between-jobs pattern invited).
            self.engine.block_cache().reset_job_meters();
        }
        let store = Arc::clone(&self.store);
        let out = self.engine.run_job_cfg(job, &store, cache, cfg)?;
        self.iterations += 1;
        Ok(out)
    }

    /// Iterations run so far.
    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// The store this session iterates over.
    pub fn store(&self) -> &Arc<BlockStore> {
        &self.store
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        self.engine
    }

    /// Charge driver-side local compute to the session's modelled clock.
    pub fn charge_local(&mut self, wall: Duration) {
        self.engine.charge_local(wall);
    }

    /// Charge a driver-side HDFS scan to the session's modelled clock.
    pub fn charge_scan(&mut self, bytes: u64) {
        self.engine.charge_scan(bytes);
    }

    /// Charge modelled retry-backoff (slab ring recovery) to the session's
    /// clock — the session loop drains the slab's accrued backoff here.
    pub fn charge_backoff(&mut self, s: f64) {
        if s > 0.0 {
            self.engine.charge_backoff(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverheadConfig;
    use crate::data::synth::blobs;
    use crate::data::Matrix;
    use crate::error::Result;
    use crate::mapreduce::{EngineOptions, TaskCtx};

    #[derive(Default)]
    struct CounterState {
        passes: usize,
        payload: Vec<u8>,
        recompute: u64,
    }

    impl SlabState for CounterState {
        fn slab_bytes(&self) -> u64 {
            self.payload.len() as u64
        }

        fn recompute_bytes(&self) -> u64 {
            self.recompute
        }

        fn spill(&self) -> Option<Vec<u8>> {
            let mut b = vec![self.passes as u8];
            b.extend_from_slice(&self.recompute.to_le_bytes());
            b.extend_from_slice(&self.payload);
            Some(b)
        }

        fn unspill(bytes: &[u8]) -> Option<Self> {
            let (&passes, rest) = bytes.split_first()?;
            if rest.len() < 8 {
                return None;
            }
            let recompute = u64::from_le_bytes(rest[..8].try_into().ok()?);
            Some(Self { passes: passes as usize, payload: rest[8..].to_vec(), recompute })
        }
    }

    fn touch(slab: &StateSlab<CounterState>, block: usize, payload: usize) {
        let h = slab.entry(block);
        let mut st = h.lock().unwrap();
        st.passes += 1;
        st.payload = vec![0; payload];
        let bytes = st.slab_bytes();
        drop(st);
        slab.note_update(block, &h, bytes);
    }

    fn spill_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("bigfcm_slab_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn slab_persists_state_across_touches() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(1024);
        for _ in 0..3 {
            touch(&slab, 7, 100);
        }
        let h = slab.entry(7);
        assert_eq!(h.lock().unwrap().passes, 3);
        assert_eq!(slab.bytes(), 100);
        assert_eq!(slab.evictions(), 0);
    }

    #[test]
    fn slab_evicts_lru_beyond_budget_but_not_the_updater() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(250);
        for block in 0..4 {
            touch(&slab, block, 100);
        }
        // Budget holds 2 entries; the two oldest (0, 1) were evicted.
        assert_eq!(slab.len(), 2);
        assert!(slab.bytes() <= 250);
        assert_eq!(slab.evictions(), 2);
        // No spill ring: nothing was written anywhere.
        assert_eq!(slab.spills(), 0);
        // Block 3 (just updated) must have survived.
        assert_eq!(slab.entry(3).lock().unwrap().payload.len(), 100);
        // Block 0 restarts empty.
        assert_eq!(slab.entry(0).lock().unwrap().passes, 0);
    }

    #[test]
    fn slab_rejects_single_state_above_budget() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(50);
        touch(&slab, 0, 100);
        assert!(slab.is_empty(), "an over-budget state must not stick");
        assert_eq!(slab.bytes(), 0);
        assert_eq!(slab.evictions(), 1);
    }

    #[test]
    fn slab_spills_instead_of_evicting_and_reloads() {
        let dir = spill_dir("ring");
        let slab: StateSlab<CounterState> =
            StateSlab::new(250, Some(SpillConfig::new(dir.clone())));
        for block in 0..4 {
            touch(&slab, block, 100);
        }
        assert_eq!(slab.len(), 2);
        assert_eq!(slab.evictions(), 0, "spill ring must replace eviction");
        assert_eq!(slab.spills(), 2);
        assert!(slab.spilled_bytes() >= 200);
        // Reload block 0: its pass counter survived the disk round trip.
        let h = slab.entry(0);
        assert_eq!(h.lock().unwrap().passes, 1);
        assert_eq!(h.lock().unwrap().payload.len(), 100);
        assert_eq!(slab.reloads(), 1);
        assert!(slab.reload_bytes() > 0);
        // The ring slot is consumed: a second miss starts empty...
        slab.invalidate_all();
        assert_eq!(slab.entry(0).lock().unwrap().passes, 0);
        drop(slab);
        // ...and dropping the slab removes its slot files.
        let leftover = std::fs::read_dir(&dir)
            .map(|d| d.count())
            .unwrap_or(0);
        assert_eq!(leftover, 0, "slab drop must remove its ring slots");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_crossover_evicts_states_cheaper_to_recompute() {
        let dir = spill_dir("crossover");
        let slab: StateSlab<CounterState> =
            StateSlab::new(250, Some(SpillConfig::new(dir.clone())));
        // State of 100 B whose recompute re-reads only 10 B: reread loses
        // at ratio 4 (100 > 4×10) → evict, not spill.
        for block in 0..4 {
            let h = slab.entry(block);
            let mut st = h.lock().unwrap();
            st.passes += 1;
            st.payload = vec![0; 100];
            st.recompute = 10;
            let bytes = st.slab_bytes();
            drop(st);
            slab.note_update(block, &h, bytes);
        }
        assert_eq!(slab.spills(), 0, "cheap-to-recompute states must not spill");
        assert_eq!(slab.evictions(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn note_update_reinserts_state_spilled_while_held() {
        let dir = spill_dir("held");
        let slab: StateSlab<CounterState> =
            StateSlab::new(250, Some(SpillConfig::new(dir.clone())));
        // Take block 0's handle as a long-running task would, then force
        // budget pressure from other blocks while it is "computing".
        let h = slab.entry(0);
        h.lock().unwrap().payload = vec![0; 100];
        slab.note_update(0, &h, 100);
        for block in 1..4 {
            touch(&slab, block, 100);
        }
        assert!(slab.spills() > 0);
        // The held task finishes its (newer) state and reports in.
        let mut st = h.lock().unwrap();
        st.passes = 42;
        drop(st);
        slab.note_update(0, &h, 100);
        // Its entry is live again with the fresh state — the stale ring
        // image (if block 0 was the one spilled) must not shadow it.
        let h2 = slab.entry(0);
        assert_eq!(h2.lock().unwrap().passes, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn busy_states_are_never_torn_down() {
        let dir = spill_dir("busy");
        let slab: StateSlab<CounterState> =
            StateSlab::new(150, Some(SpillConfig::new(dir.clone())));
        let h0 = slab.entry(0);
        let guard = h0.lock().unwrap(); // hold block 0's state lock
        for block in 1..4 {
            touch(&slab, block, 100);
        }
        // Block 0 was LRU throughout but locked: every round of budget
        // pressure must have skipped it and taken the next victim.
        drop(guard);
        assert_eq!(slab.spills(), 2);
        assert_eq!(slab.evictions(), 0);
        assert!(
            Arc::ptr_eq(&h0, &slab.entry(0)),
            "locked entry must survive budget pressure in place"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chaos_spill_read_retries_then_reloads_bitwise() {
        let dir = spill_dir("chaos_read");
        // Trip exactly one transient fault at the first ring read.
        let cfg = SpillConfig::new(dir.clone())
            .with_faults(Some(FaultPlan::tripping(13, FaultSite::SpillRead, 0)));
        let slab: StateSlab<CounterState> = StateSlab::new(250, Some(cfg));
        for block in 0..4 {
            touch(&slab, block, 100);
        }
        assert_eq!(slab.spills(), 2);
        // Reload block 0 through the faulted read: one retry, then the
        // state comes back bitwise (pass counter survived).
        let h = slab.entry(0);
        assert_eq!(h.lock().unwrap().passes, 1, "retried reload must be bitwise");
        assert_eq!(slab.spill_retries(), 1);
        assert_eq!(slab.spill_read_aborts(), 0);
        assert!((slab.backoff_seconds() - backoff_s(1)).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chaos_spill_read_exhaustion_degrades_to_recompute() {
        let dir = spill_dir("chaos_abort");
        // Rate 1.0: the ring read never clears — the slab must fall back
        // to a fresh state (the recompute path), never hang or panic.
        let cfg = SpillConfig::new(dir.clone())
            .with_faults(Some(FaultPlan::for_site(13, FaultSite::SpillRead, 1.0, 0.0)));
        let slab: StateSlab<CounterState> = StateSlab::new(250, Some(cfg));
        for block in 0..4 {
            touch(&slab, block, 100);
        }
        assert_eq!(slab.spills(), 2);
        let h = slab.entry(0);
        assert_eq!(h.lock().unwrap().passes, 0, "exhausted reload must start fresh");
        assert_eq!(slab.spill_read_aborts(), 1);
        assert_eq!(slab.spill_retries(), u64::from(MAX_READ_RETRIES) - 1);
        assert_eq!(slab.reloads(), 0, "no image was ever adopted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chaos_spill_corruption_quarantines_then_rereads() {
        let dir = spill_dir("chaos_corrupt");
        let cfg = SpillConfig::new(dir.clone())
            .with_faults(Some(FaultPlan::tripping_corrupt(13, FaultSite::SpillRead, 0)));
        let slab: StateSlab<CounterState> = StateSlab::new(250, Some(cfg));
        for block in 0..4 {
            touch(&slab, block, 100);
        }
        let h = slab.entry(0);
        assert_eq!(h.lock().unwrap().passes, 1, "quarantined slot must re-read clean");
        assert_eq!(slab.spill_quarantines(), 1);
        assert_eq!(slab.spill_retries(), 0, "a quarantine re-read is not a transient retry");
        assert_eq!(slab.spill_read_aborts(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chaos_spill_write_fault_degrades_to_counted_eviction() {
        let dir = spill_dir("chaos_write");
        // Every ring write faults: the slab must degrade exactly like an
        // unwritable ring — counted evictions, recompute on next touch.
        let cfg = SpillConfig::new(dir.clone())
            .with_faults(Some(FaultPlan::for_site(13, FaultSite::SpillWrite, 1.0, 0.0)));
        let slab: StateSlab<CounterState> = StateSlab::new(250, Some(cfg));
        for block in 0..4 {
            touch(&slab, block, 100);
        }
        assert_eq!(slab.spills(), 0, "faulted writes must never count as spills");
        assert_eq!(slab.evictions(), 2);
        assert_eq!(slab.entry(0).lock().unwrap().passes, 0, "state recomputes from fresh");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn slab_pruned_counter_drains() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(10);
        slab.add_records_pruned(5);
        slab.add_records_pruned(7);
        assert_eq!(slab.take_records_pruned(), 12);
        assert_eq!(slab.take_records_pruned(), 0);
        // The quant-side counters drain independently of the primary one.
        slab.add_records_pruned_quant(3);
        slab.add_quant_sidecar_bytes(1024);
        slab.add_quant_build_ns(2_000_000);
        assert_eq!(slab.take_records_pruned(), 0);
        assert_eq!(slab.take_records_pruned_quant(), 3);
        assert_eq!(slab.take_records_pruned_quant(), 0);
        assert_eq!(slab.take_quant_sidecar_bytes(), 1024);
        assert_eq!(slab.take_quant_sidecar_bytes(), 0);
        assert_eq!(slab.take_quant_build_ns(), 2_000_000);
        assert_eq!(slab.take_quant_build_ns(), 0);
    }

    #[test]
    fn slab_invalidate_all_is_not_an_eviction() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(1024);
        touch(&slab, 0, 10);
        slab.invalidate_all();
        assert!(slab.is_empty());
        assert_eq!(slab.evictions(), 0);
    }

    struct SumJob;

    impl MapReduceJob for SumJob {
        type MapOut = f64;
        type Output = f64;

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<f64> {
            Ok(block.as_slice().iter().map(|&v| v as f64).sum())
        }

        fn reduce(&self, parts: Vec<f64>, _ctx: &TaskCtx) -> Result<f64> {
            Ok(parts.into_iter().sum())
        }

        fn shuffle_bytes(&self, _part: &f64) -> u64 {
            8
        }
    }

    fn store() -> Arc<BlockStore> {
        let d = blobs(800, 3, 2, 0.5, 21);
        Arc::new(BlockStore::in_memory("t", &d.features, 100, 4).unwrap())
    }

    #[test]
    fn resident_session_charges_startup_once() {
        let s = store();
        let overhead = OverheadConfig::default();
        let startup = overhead.job_startup_s;
        let mut e = Engine::new(EngineOptions::default(), overhead);
        let mut session = e.session(&s, SessionOptions::default());
        for it in 0..3 {
            let (_, stats) = session
                .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
                .unwrap();
            if it == 0 {
                assert!(stats.sim.job_startup_s > 0.0);
            } else {
                assert_eq!(stats.sim.job_startup_s, 0.0);
            }
        }
        assert_eq!(session.iterations(), 3);
        drop(session);
        assert_eq!(e.clock().jobs(), 3);
        let total = e.clock().cost().job_startup_s;
        assert!(
            (total - startup).abs() < 1e-9,
            "resident session must charge startup once, got {total}"
        );
    }

    #[test]
    fn per_job_session_charges_startup_each_iteration() {
        let s = store();
        let overhead = OverheadConfig::default();
        let startup = overhead.job_startup_s;
        let mut e = Engine::new(EngineOptions::default(), overhead);
        let mut session = e.session(&s, SessionOptions::per_job());
        for _ in 0..3 {
            session
                .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
                .unwrap();
        }
        drop(session);
        let total = e.clock().cost().job_startup_s;
        assert!((total - 3.0 * startup).abs() < 1e-9, "control arm must stay per-job: {total}");
    }

    #[test]
    fn session_iterations_reuse_warm_blocks() {
        let s = store();
        let opts = EngineOptions { prefetch: false, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let mut session = e.session(&s, SessionOptions::default());
        let (_, first) = session
            .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
            .unwrap();
        assert!(first.sim.hdfs_io_s > 0.0);
        let (_, second) = session
            .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(second.sim.hdfs_io_s, 0.0, "warm iteration must charge no HDFS I/O");
        drop(session);
        assert_eq!(e.block_cache().misses(), 8, "second iteration must not re-decode");
    }
}
