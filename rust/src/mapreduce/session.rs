//! Iteration-resident sessions: one session spans every iteration of a
//! convergence loop over one block store.
//!
//! The Mahout-style one-job-per-iteration pattern pays a full job startup,
//! a cold distributed-cache push and a flat reduce funnel *per iteration* —
//! the dominant cost of iterative clustering on Hadoop (PAPER.md §3;
//! Parallel Hierarchical Affinity Propagation, arXiv:1403.7394, makes the
//! same observation). An [`IterativeSession`] keeps the engine's worker
//! pool, block cache, locality queues and prefetcher warm across the jobs
//! of one loop, charges the modelled job startup once, and gives kernels a
//! **sticky per-block state slab** ([`StateSlab`]) — keyed by block id,
//! byte-accounted against its own budget — where derived state (the
//! shift-bounded pruning bounds of `crate::fcm::native`) persists between
//! iterations.
//!
//! The slab deliberately lives *outside* the block cache: per-job cache
//! meter resets ([`crate::mapreduce::BlockCache::reset_job_meters`]) and
//! even a full block `clear()` can never invalidate bounds the pruning
//! path still holds. Slab lifetime is the session's, ended only by its own byte
//! budget (LRU eviction, surfaced as `slab_evictions`) or an explicit
//! [`StateSlab::invalidate_all`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::Result;
use crate::hdfs::BlockStore;
use crate::mapreduce::engine::{Engine, JobRunCfg, JobStats};
use crate::mapreduce::{DistributedCache, MapReduceJob};

/// How a session schedules its iterations.
#[derive(Clone, Copy, Debug)]
pub struct SessionOptions {
    /// Resident sessions charge the modelled job startup once (first
    /// iteration only) — the pool, cache and prefetcher stay warm. A
    /// non-resident session pays it every iteration, like a fresh Hadoop
    /// job submission.
    pub resident: bool,
    /// Worker-side tree combine for this session's jobs; `None` inherits
    /// the engine option.
    pub tree_combine: Option<bool>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        Self { resident: true, tree_combine: None }
    }
}

impl SessionOptions {
    /// The Mahout-style control arm: every iteration pays job startup and
    /// funnels every map output through the flat reduce — exactly the
    /// pre-session engine behaviour, for honest A/B rows.
    pub fn per_job() -> Self {
        Self { resident: false, tree_combine: Some(false) }
    }
}

/// State a kernel may persist in a [`StateSlab`] between iterations.
pub trait SlabState: Send {
    /// Bytes this state is accounted at against the slab budget.
    fn slab_bytes(&self) -> u64;
}

impl SlabState for () {
    fn slab_bytes(&self) -> u64 {
        0
    }
}

struct SlabEntry<S> {
    state: Arc<Mutex<S>>,
    bytes: u64,
    last_touch: u64,
}

struct SlabInner<S> {
    entries: HashMap<usize, SlabEntry<S>>,
    bytes: u64,
    tick: u64,
}

/// Sticky per-block state, keyed by block id and byte-accounted against a
/// budget of its own (configured via `cluster.slab_mib`). The global lock
/// covers only lookup/accounting; each block's state sits behind its own
/// mutex, so map tasks of different blocks never serialize on the slab.
///
/// Exceeding the budget evicts the least-recently-touched *other* entries
/// (an evicted block simply recomputes exactly on its next pass); a single
/// state larger than the whole budget does not stick, mirroring the block
/// cache's budget semantics.
pub struct StateSlab<S> {
    budget_bytes: u64,
    inner: Mutex<SlabInner<S>>,
    evictions: AtomicU64,
    records_pruned: AtomicU64,
}

impl<S: SlabState + Default> StateSlab<S> {
    pub fn with_budget_bytes(budget_bytes: u64) -> Self {
        Self {
            budget_bytes,
            inner: Mutex::new(SlabInner { entries: HashMap::new(), bytes: 0, tick: 0 }),
            evictions: AtomicU64::new(0),
            records_pruned: AtomicU64::new(0),
        }
    }

    /// Handle to `block`'s sticky state, created empty on first touch.
    /// Touching marks the entry most-recently-used.
    pub fn entry(&self, block: usize) -> Arc<Mutex<S>> {
        let mut inner = self.inner.lock().expect("state slab poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        let e = inner.entries.entry(block).or_insert_with(|| SlabEntry {
            state: Arc::new(Mutex::new(S::default())),
            bytes: 0,
            last_touch: tick,
        });
        e.last_touch = tick;
        Arc::clone(&e.state)
    }

    /// Record `block`'s new byte size after a mutation (the caller measures
    /// it via [`SlabState::slab_bytes`] and drops the state lock first —
    /// the slab never locks a state itself, so lock order is always
    /// state-then-slab). Evicts beyond the budget.
    pub fn note_update(&self, block: usize, bytes: u64) {
        let mut inner = self.inner.lock().expect("state slab poisoned");
        let st = &mut *inner;
        if let Some(e) = st.entries.get_mut(&block) {
            st.bytes = st.bytes + bytes - e.bytes;
            e.bytes = bytes;
        }
        // Evict least-recently-touched entries (never the one just
        // updated) until the budget holds.
        while st.bytes > self.budget_bytes && st.entries.len() > 1 {
            let victim = st
                .entries
                .iter()
                .filter(|(id, _)| **id != block)
                .min_by_key(|(_, e)| e.last_touch)
                .map(|(id, _)| *id);
            let Some(v) = victim else { break };
            if let Some(e) = st.entries.remove(&v) {
                st.bytes -= e.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        if st.bytes > self.budget_bytes {
            // The updated state alone exceeds the budget: drop it too (its
            // current holder keeps the Arc alive for the rest of this
            // iteration; the next pass starts from an empty state).
            if let Some(e) = st.entries.remove(&block) {
                st.bytes -= e.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Drop every sticky state (e.g. to force the next pass exact). Not
    /// counted as evictions — this is a deliberate refresh, not budget
    /// pressure.
    pub fn invalidate_all(&self) {
        let mut inner = self.inner.lock().expect("state slab poisoned");
        inner.entries.clear();
        inner.bytes = 0;
    }

    /// Bytes currently accounted in the slab.
    pub fn bytes(&self) -> u64 {
        self.inner.lock().expect("state slab poisoned").bytes
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("state slab poisoned").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Budget (bytes) this slab evicts against.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Entries dropped by budget pressure since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Add to the shared pruned-records counter (kernels report how many
    /// records reused their cached contribution).
    pub fn add_records_pruned(&self, n: u64) {
        self.records_pruned.fetch_add(n, Ordering::Relaxed);
    }

    /// Drain the pruned-records counter (the session loop reads one
    /// iteration's worth and stamps it into that iteration's [`JobStats`]).
    pub fn take_records_pruned(&self) -> u64 {
        self.records_pruned.swap(0, Ordering::Relaxed)
    }
}

/// One convergence loop's view of the engine: iterations run as engine
/// jobs, but startup is charged per [`SessionOptions::resident`] and the
/// per-job cache peak meters reset between iterations without dropping
/// warm blocks.
pub struct IterativeSession<'e> {
    engine: &'e mut Engine,
    store: Arc<BlockStore>,
    options: SessionOptions,
    iterations: usize,
}

impl Engine {
    /// Open an iteration-resident session over `store`. The session
    /// borrows the engine exclusively: one convergence loop at a time,
    /// which is also what keeps its warm-state reasoning sound.
    pub fn session<'e>(
        &'e mut self,
        store: &Arc<BlockStore>,
        options: SessionOptions,
    ) -> IterativeSession<'e> {
        IterativeSession { engine: self, store: Arc::clone(store), options, iterations: 0 }
    }
}

impl IterativeSession<'_> {
    /// Run one iteration of the loop as an engine job.
    pub fn run_iteration<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        cache: Arc<DistributedCache>,
    ) -> Result<(J::Output, JobStats)> {
        let cfg = JobRunCfg {
            charge_startup: !self.options.resident || self.iterations == 0,
            tree_combine: self
                .options
                .tree_combine
                .unwrap_or(self.engine.options().tree_combine),
        };
        if self.iterations > 0 {
            // Job-scoped peak metering without evicting warm blocks (the
            // regression the old clear()-between-jobs pattern invited).
            self.engine.block_cache().reset_job_meters();
        }
        let store = Arc::clone(&self.store);
        let out = self.engine.run_job_cfg(job, &store, cache, cfg)?;
        self.iterations += 1;
        Ok(out)
    }

    /// Iterations run so far.
    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// The store this session iterates over.
    pub fn store(&self) -> &Arc<BlockStore> {
        &self.store
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        self.engine
    }

    /// Charge driver-side local compute to the session's modelled clock.
    pub fn charge_local(&mut self, wall: Duration) {
        self.engine.charge_local(wall);
    }

    /// Charge a driver-side HDFS scan to the session's modelled clock.
    pub fn charge_scan(&mut self, bytes: u64) {
        self.engine.charge_scan(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverheadConfig;
    use crate::data::synth::blobs;
    use crate::data::Matrix;
    use crate::error::Result;
    use crate::mapreduce::{EngineOptions, TaskCtx};

    #[derive(Default)]
    struct CounterState {
        passes: usize,
        payload: Vec<u8>,
    }

    impl SlabState for CounterState {
        fn slab_bytes(&self) -> u64 {
            self.payload.len() as u64
        }
    }

    #[test]
    fn slab_persists_state_across_touches() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(1024);
        for _ in 0..3 {
            let h = slab.entry(7);
            let mut st = h.lock().unwrap();
            st.passes += 1;
            st.payload = vec![0; 100];
            let bytes = st.slab_bytes();
            drop(st);
            slab.note_update(7, bytes);
        }
        let h = slab.entry(7);
        assert_eq!(h.lock().unwrap().passes, 3);
        assert_eq!(slab.bytes(), 100);
        assert_eq!(slab.evictions(), 0);
    }

    #[test]
    fn slab_evicts_lru_beyond_budget_but_not_the_updater() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(250);
        for block in 0..4 {
            let h = slab.entry(block);
            let mut st = h.lock().unwrap();
            st.payload = vec![0; 100];
            let bytes = st.slab_bytes();
            drop(st);
            slab.note_update(block, bytes);
        }
        // Budget holds 2 entries; the two oldest (0, 1) were evicted.
        assert_eq!(slab.len(), 2);
        assert!(slab.bytes() <= 250);
        assert_eq!(slab.evictions(), 2);
        // Block 3 (just updated) must have survived.
        assert_eq!(slab.entry(3).lock().unwrap().payload.len(), 100);
        // Block 0 restarts empty.
        assert_eq!(slab.entry(0).lock().unwrap().passes, 0);
    }

    #[test]
    fn slab_rejects_single_state_above_budget() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(50);
        let h = slab.entry(0);
        h.lock().unwrap().payload = vec![0; 100];
        slab.note_update(0, 100);
        assert!(slab.is_empty(), "an over-budget state must not stick");
        assert_eq!(slab.bytes(), 0);
        assert_eq!(slab.evictions(), 1);
    }

    #[test]
    fn slab_pruned_counter_drains() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(10);
        slab.add_records_pruned(5);
        slab.add_records_pruned(7);
        assert_eq!(slab.take_records_pruned(), 12);
        assert_eq!(slab.take_records_pruned(), 0);
    }

    #[test]
    fn slab_invalidate_all_is_not_an_eviction() {
        let slab: StateSlab<CounterState> = StateSlab::with_budget_bytes(1024);
        let h = slab.entry(0);
        h.lock().unwrap().payload = vec![0; 10];
        slab.note_update(0, 10);
        slab.invalidate_all();
        assert!(slab.is_empty());
        assert_eq!(slab.evictions(), 0);
    }

    struct SumJob;

    impl MapReduceJob for SumJob {
        type MapOut = f64;
        type Output = f64;

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<f64> {
            Ok(block.as_slice().iter().map(|&v| v as f64).sum())
        }

        fn reduce(&self, parts: Vec<f64>, _ctx: &TaskCtx) -> Result<f64> {
            Ok(parts.into_iter().sum())
        }

        fn shuffle_bytes(&self, _part: &f64) -> u64 {
            8
        }
    }

    fn store() -> Arc<BlockStore> {
        let d = blobs(800, 3, 2, 0.5, 21);
        Arc::new(BlockStore::in_memory("t", &d.features, 100, 4).unwrap())
    }

    #[test]
    fn resident_session_charges_startup_once() {
        let s = store();
        let overhead = OverheadConfig::default();
        let startup = overhead.job_startup_s;
        let mut e = Engine::new(EngineOptions::default(), overhead);
        let mut session = e.session(&s, SessionOptions::default());
        for it in 0..3 {
            let (_, stats) = session
                .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
                .unwrap();
            if it == 0 {
                assert!(stats.sim.job_startup_s > 0.0);
            } else {
                assert_eq!(stats.sim.job_startup_s, 0.0);
            }
        }
        assert_eq!(session.iterations(), 3);
        drop(session);
        assert_eq!(e.clock().jobs(), 3);
        let total = e.clock().cost().job_startup_s;
        assert!(
            (total - startup).abs() < 1e-9,
            "resident session must charge startup once, got {total}"
        );
    }

    #[test]
    fn per_job_session_charges_startup_each_iteration() {
        let s = store();
        let overhead = OverheadConfig::default();
        let startup = overhead.job_startup_s;
        let mut e = Engine::new(EngineOptions::default(), overhead);
        let mut session = e.session(&s, SessionOptions::per_job());
        for _ in 0..3 {
            session
                .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
                .unwrap();
        }
        drop(session);
        let total = e.clock().cost().job_startup_s;
        assert!((total - 3.0 * startup).abs() < 1e-9, "control arm must stay per-job: {total}");
    }

    #[test]
    fn session_iterations_reuse_warm_blocks() {
        let s = store();
        let opts = EngineOptions { prefetch: false, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let mut session = e.session(&s, SessionOptions::default());
        let (_, first) = session
            .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
            .unwrap();
        assert!(first.sim.hdfs_io_s > 0.0);
        let (_, second) = session
            .run_iteration(Arc::new(SumJob), Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(second.sim.hdfs_io_s, 0.0, "warm iteration must charge no HDFS I/O");
        drop(session);
        assert_eq!(e.block_cache().misses(), 8, "second iteration must not re-decode");
    }
}
