//! The job engine: schedules map tasks over the worker pool, re-executes
//! failed attempts, runs the reduce, and charges the SimClock.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::OverheadConfig;
use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::hdfs::BlockStore;
use crate::mapreduce::simclock::{SimClock, SimCost, TaskSample};
use crate::mapreduce::{DistributedCache, MapReduceJob, TaskCtx};
use crate::prng::Pcg;
use crate::threadpool::ThreadPool;

/// Hadoop's default max attempts per task.
const MAX_ATTEMPTS: usize = 4;

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Worker (map-slot) count.
    pub workers: usize,
    /// Injected per-attempt failure probability (fault-tolerance tests).
    pub fault_rate: f64,
    /// Seed for fault injection.
    pub fault_seed: u64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self { workers: 4, fault_rate: 0.0, fault_seed: 0 }
    }
}

/// Statistics of one executed job.
#[derive(Clone, Debug)]
pub struct JobStats {
    pub name: String,
    /// Real elapsed time of the whole job on this machine.
    pub wall: Duration,
    /// Modelled cluster cost of this job.
    pub sim: SimCost,
    pub map_tasks: usize,
    /// Total attempts (> map_tasks when faults were injected).
    pub attempts: usize,
    pub shuffle_bytes: u64,
}

/// The MapReduce engine. One engine per pipeline run; owns the worker pool
/// and the SimClock.
pub struct Engine {
    pool: ThreadPool,
    options: EngineOptions,
    overhead: OverheadConfig,
    clock: SimClock,
}

impl Engine {
    pub fn new(options: EngineOptions, overhead: OverheadConfig) -> Self {
        Self {
            pool: ThreadPool::new(options.workers),
            options,
            overhead,
            clock: SimClock::new(),
        }
    }

    pub fn workers(&self) -> usize {
        self.options.workers
    }

    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    pub fn overhead(&self) -> &OverheadConfig {
        &self.overhead
    }

    /// Charge driver-side local compute to the modelled clock.
    pub fn charge_local(&mut self, wall: Duration) {
        self.clock.charge_local(&self.overhead, wall);
    }

    /// Charge a driver-side HDFS scan.
    pub fn charge_scan(&mut self, bytes: u64) {
        self.clock.charge_scan(&self.overhead, bytes);
    }

    /// Execute one MapReduce job over every block of `store`.
    pub fn run_job<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        store: &BlockStore,
        cache: Arc<DistributedCache>,
    ) -> Result<(J::Output, JobStats)> {
        let started = Instant::now();
        let n_blocks = store.num_blocks();
        if n_blocks == 0 {
            return Err(Error::Job("no input blocks".into()));
        }

        // Pre-draw fault schedules so parallel execution stays deterministic:
        // fail_counts[t] = how many attempts of task t fail before success.
        let mut fault_rng = Pcg::new(self.options.fault_seed);
        let fail_counts: Vec<usize> = (0..n_blocks)
            .map(|_| {
                let mut fails = 0;
                while fails < MAX_ATTEMPTS - 1 && fault_rng.next_f64() < self.options.fault_rate {
                    fails += 1;
                }
                fails
            })
            .collect();

        // Map phase: read + map_combine per block on the pool.
        struct TaskResult<M> {
            out: M,
            sample: TaskSample,
        }
        let blocks: Vec<(usize, Matrix, u64, usize)> = (0..n_blocks)
            .map(|id| {
                let meta_bytes = store.blocks()[id].bytes;
                store
                    .read_block(id)
                    .map(|m| (id, m, meta_bytes, fail_counts[id]))
            })
            .collect::<Result<_>>()?;

        let job_for_map = Arc::clone(&job);
        let cache_for_map = Arc::clone(&cache);
        let results = self.pool.map_parallel(blocks, move |(id, block, bytes, fails)| {
            let mut attempt = 0usize;
            loop {
                let ctx = TaskCtx { cache: &cache_for_map, task_id: id, attempt };
                let t0 = Instant::now();
                let out = job_for_map.map_combine(&block, &ctx);
                let compute_wall_s = t0.elapsed().as_secs_f64();
                // Injected fault: discard this attempt's output and retry
                // (idempotence is the combiner contract).
                if attempt < fails {
                    attempt += 1;
                    continue;
                }
                return out.map(|o| TaskResult {
                    out: o,
                    sample: TaskSample {
                        compute_wall_s,
                        input_bytes: bytes,
                        attempts: attempt + 1,
                    },
                });
            }
        });

        let mut outs = Vec::with_capacity(n_blocks);
        let mut samples = Vec::with_capacity(n_blocks);
        let mut attempts_total = 0usize;
        for r in results {
            let task = r
                .map_err(|panic| Error::Job(format!("map task panicked: {panic}")))?
                .map_err(|e| Error::Job(format!("map task failed: {e}")))?;
            attempts_total += task.sample.attempts;
            samples.push(task.sample);
            outs.push(task.out);
        }

        let shuffle_bytes: u64 = outs.iter().map(|o| job.shuffle_bytes(o)).sum();

        // Reduce phase (single reducer, as the paper's default).
        let reduce_ctx = TaskCtx { cache: &cache, task_id: usize::MAX, attempt: 0 };
        let t0 = Instant::now();
        let output = job.reduce(outs, &reduce_ctx)?;
        let reduce_wall_s = t0.elapsed().as_secs_f64();

        let sim = self.clock.charge_job(
            &self.overhead,
            self.options.workers,
            &samples,
            shuffle_bytes,
            reduce_wall_s,
        );

        let stats = JobStats {
            name: job.name().to_string(),
            wall: started.elapsed(),
            sim,
            map_tasks: n_blocks,
            attempts: attempts_total,
            shuffle_bytes,
        };
        Ok((output, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;

    /// Toy job: per-block weighted row sums, reduce = grand total.
    struct SumJob;

    impl MapReduceJob for SumJob {
        type MapOut = (f64, usize);
        type Output = (f64, usize);

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
            let s: f64 = block.as_slice().iter().map(|&v| v as f64).sum();
            Ok((s, block.rows()))
        }

        fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
            Ok(parts
                .into_iter()
                .fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
        }

        fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
            16
        }

        fn name(&self) -> &str {
            "sum"
        }
    }

    fn store() -> BlockStore {
        let d = blobs(1000, 3, 2, 0.5, 1);
        BlockStore::in_memory("t", &d.features, 128, 4).unwrap()
    }

    #[test]
    fn job_computes_correct_global_result() {
        let s = store();
        let expected: f64 = {
            let mut acc = 0.0;
            for b in 0..s.num_blocks() {
                acc += s
                    .read_block(b)
                    .unwrap()
                    .as_slice()
                    .iter()
                    .map(|&v| v as f64)
                    .sum::<f64>();
            }
            acc
        };
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let ((total, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!((total - expected).abs() < 1e-6);
        assert_eq!(stats.map_tasks, 8);
        assert_eq!(stats.attempts, 8);
        assert_eq!(stats.shuffle_bytes, 8 * 16);
        assert!(stats.sim.total_s() > 0.0);
    }

    #[test]
    fn fault_injection_retries_and_still_correct() {
        let s = store();
        let opts = EngineOptions { workers: 4, fault_rate: 0.4, fault_seed: 9 };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!(stats.attempts > stats.map_tasks, "expected retries");
    }

    #[test]
    fn sim_clock_accumulates_per_job() {
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        for _ in 0..3 {
            e.run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
                .unwrap();
        }
        assert_eq!(e.clock().jobs(), 3);
        // 3 job startups at least.
        assert!(e.clock().total_s() >= 3.0 * e.overhead().job_startup_s);
    }

    #[test]
    fn cache_visible_to_tasks() {
        struct CacheEcho;
        impl MapReduceJob for CacheEcho {
            type MapOut = f64;
            type Output = f64;
            fn map_combine(&self, _b: &Matrix, ctx: &TaskCtx) -> Result<f64> {
                Ok(ctx.cache.get_scalar("x").unwrap_or(-1.0))
            }
            fn reduce(&self, parts: Vec<f64>, _ctx: &TaskCtx) -> Result<f64> {
                Ok(parts.into_iter().sum())
            }
            fn shuffle_bytes(&self, _p: &f64) -> u64 {
                8
            }
        }
        let s = store();
        let cache = Arc::new(DistributedCache::new());
        cache.put_scalar("x", 2.5);
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let (total, _) = e.run_job(Arc::new(CacheEcho), &s, cache).unwrap();
        assert_eq!(total, 2.5 * s.num_blocks() as f64);
    }

    #[test]
    fn failing_map_task_fails_job() {
        struct FailJob;
        impl MapReduceJob for FailJob {
            type MapOut = ();
            type Output = ();
            fn map_combine(&self, _b: &Matrix, ctx: &TaskCtx) -> Result<()> {
                if ctx.task_id == 2 {
                    Err(Error::Job("synthetic failure".into()))
                } else {
                    Ok(())
                }
            }
            fn reduce(&self, _p: Vec<()>, _ctx: &TaskCtx) -> Result<()> {
                Ok(())
            }
            fn shuffle_bytes(&self, _p: &()) -> u64 {
                0
            }
        }
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let r = e.run_job(Arc::new(FailJob), &s, Arc::new(DistributedCache::new()));
        assert!(r.is_err());
    }
}
