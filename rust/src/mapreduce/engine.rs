//! The job engine: schedules map tasks over the worker pool with locality
//! hints, re-executes failed attempts, prefetches upcoming blocks, runs the
//! reduce, and charges the SimClock.
//!
//! ## Streaming map pipeline
//!
//! `run_job` never materializes the dataset: map tasks are described to the
//! pool by block id alone, and each map slot reads (or cache-hits), computes
//! and *drops* its block inside the worker closure. Peak decoded-block
//! memory is therefore O(byte budget + workers × block size), not
//! O(dataset) — the property that lets one engine stream multi-gigabyte
//! stores. Three mechanisms coordinate around the engine's byte-budgeted
//! [`BlockCache`]:
//!
//! * **locality-aware ordering** — tasks are queued per worker from each
//!   block's [`crate::hdfs::BlockMeta::preferred_worker`] hint
//!   ([`ThreadPool::map_indexed_hinted`]); a worker steals only when its
//!   own queue is dry. Own-queue claims vs steals surface in [`JobStats`].
//! * **prefetch** — when a worker claims block *k* it hints the engine's
//!   prefetcher thread at block *k+1* of the same queue, so the next disk
//!   read overlaps the current block's compute. Prefetch-served reads
//!   surface in [`JobStats::prefetch_hits`].
//! * **byte-budgeted caching** — warm blocks are served by the engine's
//!   [`BlockCache`], so iterative callers (the Mahout-style
//!   one-job-per-iteration baselines especially) re-read hot blocks from
//!   memory instead of re-decoding HDFS files.
//! * **worker-side tree combine** — jobs implementing
//!   [`MapReduceJob::combine`] merge their map outputs pairwise on the
//!   pool as slots drain (a fixed binary topology over block ids, so the
//!   result is deterministic); the reduce and the modelled shuffle then
//!   handle O(workers + log blocks) segments instead of O(blocks).
//!   [`JobStats::reduce_parts`] and [`JobStats::combine_depth`] surface
//!   the effect per job.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{ClusterConfig, OverheadConfig};
use crate::error::{Error, Result};
use crate::faults::{FaultPlan, FaultSite};
use crate::hdfs::BlockStore;
use crate::mapreduce::cache::{BlockCache, ReadSource, MIB};
use crate::mapreduce::simclock::{SimClock, SimCost, TaskSample};
use crate::mapreduce::{DistributedCache, MapReduceJob, TaskCtx};
use crate::prng::Pcg;
use crate::telemetry::metrics::MetricsRegistry;
use crate::telemetry::trace;
use crate::threadpool::{QueueAhead, ThreadPool};

/// Hadoop's default max attempts per task.
const MAX_ATTEMPTS: usize = 4;

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Worker (map-slot) count.
    pub workers: usize,
    /// Injected per-attempt failure probability (fault-tolerance tests).
    pub fault_rate: f64,
    /// Seed for fault injection.
    pub fault_seed: u64,
    /// Block-cache byte budget (0 disables caching; reads then stream
    /// straight from the store, one block per busy worker). Express MiB
    /// budgets via [`crate::mapreduce::cache::MIB`].
    pub block_cache_bytes: u64,
    /// Overlap the next queued block's read with the current block's
    /// compute on a dedicated prefetcher thread. The depth adapts: when
    /// the byte budget has at least two max-size blocks of unreserved
    /// slack, the block after next is warmed as well.
    pub prefetch: bool,
    /// Merge map outputs pairwise on the worker pool as slots drain, for
    /// jobs that implement [`MapReduceJob::combine`] — the reduce then
    /// funnels O(workers + log blocks) segments instead of O(blocks).
    pub tree_combine: bool,
    /// Chaos plan threaded into the block cache (demand-read / prefetch
    /// sites) and the map-task pre-draw. `None` (the default, and always
    /// when `[faults]` is absent) keeps every check a single `Option` test.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            workers: 4,
            fault_rate: 0.0,
            fault_seed: 0,
            block_cache_bytes: 256 * MIB,
            prefetch: true,
            tree_combine: true,
            faults: None,
        }
    }
}

impl EngineOptions {
    /// Engine shape from the cluster config (fault injection stays off).
    pub fn from_cluster(cluster: &ClusterConfig) -> Self {
        Self {
            workers: cluster.workers,
            block_cache_bytes: cluster.cache_mib as u64 * MIB,
            prefetch: cluster.prefetch,
            tree_combine: cluster.tree_combine,
            ..Self::default()
        }
    }
}

/// Per-invocation job knobs — the session layer drives these; plain
/// [`Engine::run_job`] uses the defaults implied by [`EngineOptions`].
#[derive(Clone, Copy, Debug)]
pub struct JobRunCfg {
    /// Charge the modelled per-job startup cost. Iteration-resident
    /// sessions charge it once for the whole convergence loop.
    pub charge_startup: bool,
    /// Use the worker-side combine tree when the job supports it.
    pub tree_combine: bool,
}

/// Statistics of one executed job.
#[derive(Clone, Debug, Default)]
pub struct JobStats {
    pub name: String,
    /// Real elapsed time of the whole job on this machine.
    pub wall: Duration,
    /// Modelled cluster cost of this job.
    pub sim: SimCost,
    pub map_tasks: usize,
    /// Total attempts (> map_tasks when faults were injected).
    pub attempts: usize,
    pub shuffle_bytes: u64,
    /// Map tasks claimed by the worker their block's locality hint named.
    pub locality_hits: usize,
    /// Map tasks stolen by a worker whose own queue was dry.
    pub locality_steals: usize,
    /// Map-task block reads served warm by the prefetcher this job.
    pub prefetch_hits: u64,
    /// Prefetcher disk reads nothing consumed (evicted before first touch
    /// or lost a duplicate race); charged to this job's modelled HDFS I/O
    /// so every real read is counted exactly once.
    pub prefetch_wasted_bytes: u64,
    /// Transient-fault retries taken by this job's demand block reads
    /// (chaos runs only; each accrued modelled backoff into `sim`).
    pub read_retries: u64,
    /// Demand reads that exhausted the retry budget this job (a nonzero
    /// value only ever accompanies a failed run's partial stats — success
    /// means every retry chain cleared).
    pub read_aborts: u64,
    /// Checksum-quarantine re-reads this job (torn bytes never served).
    pub quarantines: u64,
    /// Prefetch reads that failed and were swallowed this job; the demand
    /// path re-reads such blocks, so they cost latency, not correctness.
    pub prefetch_errors: u64,
    /// Map records whose contribution was served from the sticky pruning
    /// slab instead of a full distance pass. Filled by the session layer
    /// (`crate::fcm::loops::run_fcm_session`); 0 for ordinary jobs.
    pub records_pruned: u64,
    /// Subset of `records_pruned` the primary bound test abandoned and the
    /// certified i8 pre-pass rescued (session runs with `cluster.quant`
    /// only; 0 otherwise).
    pub records_pruned_quant: u64,
    /// Resident quant-sidecar bytes summed over the blocks this job's
    /// pruned passes touched (session runs with `cluster.quant` only).
    pub quant_sidecar_bytes: u64,
    /// Real seconds spent building quant sidecars during this job (lazy
    /// one-time cost per block; amortises to 0 on warm iterations).
    pub quant_build_s: f64,
    /// Bytes resident in the session's sticky state slab after this job
    /// (session runs only).
    pub slab_bytes: u64,
    /// Sticky-slab evictions observed so far in the session (session runs
    /// only).
    pub slab_evictions: u64,
    /// Bytes written to the slab's disk spill ring so far in the session
    /// (session runs only; 0 when spilling is off).
    pub slab_spilled_bytes: u64,
    /// State reloads served from the slab's spill ring so far in the
    /// session (session runs only).
    pub slab_reloads: u64,
    /// Transient-fault retries taken by spill-ring slot reads so far in the
    /// session (chaos runs only; stamped by the session layer).
    pub slab_spill_retries: u64,
    /// Checksum-quarantine re-reads of spill-ring slots so far in the
    /// session (chaos runs only; stamped by the session layer).
    pub slab_spill_quarantines: u64,
    /// Effective refresh cap (`refresh_every`) this job's pruned passes
    /// ran under — the session loop's adaptive-refresh policy stamps it
    /// (session runs only; 0 for ordinary jobs).
    pub refresh_cap: usize,
    /// Blocks this job mapped that the shard plan moved here from another
    /// shard's slice (sharded runs only; stamped by the sharded engine).
    pub shard_steals: usize,
    /// Serialised bytes of those cross-shard blocks — the traffic the
    /// modelled rack link carries, charged to `net_s` at the configured
    /// steal penalty (sharded runs only).
    pub shard_steal_bytes: u64,
    /// Real seconds of the reduce phase. Tree-combined jobs fold most
    /// merge work into the map slots, so this drops from O(blocks) worth
    /// of merging to O(parts).
    pub reduce_wall_s: f64,
    /// Real seconds spent in worker-side combine merges (overlapped with
    /// map compute; charged serially to the modelled clock, which is
    /// conservative).
    pub combine_wall_s: f64,
    /// Height of the worker-side combine tree (0 = flat reduce).
    pub combine_depth: usize,
    /// Combiner outputs that reached the reduce phase (= `map_tasks` for a
    /// flat reduce, O(workers + log blocks) when tree-combined).
    pub reduce_parts: usize,
    /// Real seconds map tasks spent reading their input block (demand
    /// reads through the cache), summed across workers.
    pub read_wall_s: f64,
    /// Real seconds map tasks spent inside `map_combine`, summed across
    /// workers (Σ of the per-task compute samples).
    pub compute_wall_s: f64,
}

impl JobStats {
    /// Publish every numeric field into `reg` under `{prefix}.*` names.
    /// Counters carry the exact integer (no float round-trip), so the
    /// registry view stays bit-identical with the legacy struct; walls and
    /// modelled seconds go in as gauges.
    pub fn publish_metrics(&self, reg: &MetricsRegistry, prefix: &str) {
        let c = |k: &str, v: u64| reg.set_counter(&format!("{prefix}.{k}"), v);
        let g = |k: &str, v: f64| reg.set_gauge(&format!("{prefix}.{k}"), v);
        c("map_tasks", self.map_tasks as u64);
        c("attempts", self.attempts as u64);
        c("shuffle_bytes", self.shuffle_bytes);
        c("locality_hits", self.locality_hits as u64);
        c("locality_steals", self.locality_steals as u64);
        c("prefetch_hits", self.prefetch_hits);
        c("prefetch_wasted_bytes", self.prefetch_wasted_bytes);
        c("read_retries", self.read_retries);
        c("read_aborts", self.read_aborts);
        c("quarantines", self.quarantines);
        c("prefetch_errors", self.prefetch_errors);
        c("records_pruned", self.records_pruned);
        c("records_pruned_quant", self.records_pruned_quant);
        c("quant_sidecar_bytes", self.quant_sidecar_bytes);
        c("slab_bytes", self.slab_bytes);
        c("slab_evictions", self.slab_evictions);
        c("slab_spilled_bytes", self.slab_spilled_bytes);
        c("slab_reloads", self.slab_reloads);
        c("slab_spill_retries", self.slab_spill_retries);
        c("slab_spill_quarantines", self.slab_spill_quarantines);
        c("refresh_cap", self.refresh_cap as u64);
        c("shard_steals", self.shard_steals as u64);
        c("shard_steal_bytes", self.shard_steal_bytes);
        c("combine_depth", self.combine_depth as u64);
        c("reduce_parts", self.reduce_parts as u64);
        g("wall_s", self.wall.as_secs_f64());
        g("sim_total_s", self.sim.total_s());
        g("quant_build_s", self.quant_build_s);
        g("reduce_wall_s", self.reduce_wall_s);
        g("combine_wall_s", self.combine_wall_s);
        g("read_wall_s", self.read_wall_s);
        g("compute_wall_s", self.compute_wall_s);
    }
}

/// The MapReduce engine. One engine per pipeline run; owns the worker pool,
/// the block cache, the prefetcher thread and the SimClock.
pub struct Engine {
    pool: ThreadPool,
    options: EngineOptions,
    overhead: OverheadConfig,
    clock: SimClock,
    block_cache: Arc<BlockCache>,
    prefetch_tx: Option<Sender<PrefetchMsg>>,
    prefetch_handle: Option<JoinHandle<()>>,
}

/// Messages to the engine's prefetcher thread.
enum PrefetchMsg {
    /// Pull this block into the cache ahead of demand.
    Fetch(Arc<BlockStore>, usize),
    /// Barrier: ack once every message queued before it is processed. Sent
    /// at the end of each job's map phase so late prefetch completions are
    /// metered (and charged) to the job whose map queued them, and so an
    /// engine is never dropped with a backlog of pointless reads.
    Fence(Sender<()>),
}

/// Prefetcher thread body: pull hinted blocks into the cache until the
/// engine drops its sender. Prefetch failures are deliberately swallowed —
/// the demand path will retry the read and surface the error attached to
/// the task that needed the block, with the failing block id in its
/// message — but never silently: the cache meters every one in
/// `prefetch_errors`, which [`JobStats::prefetch_errors`] reports per job
/// so a dying disk is observable long before demand reads start failing.
fn prefetch_loop(rx: Receiver<PrefetchMsg>, cache: Arc<BlockCache>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            PrefetchMsg::Fetch(store, id) => {
                let mut span = trace::global().span("prefetch", "mapreduce");
                span.attr("block", id.to_string());
                // Counted by the cache as `prefetch_errors`; see above.
                let _ = cache.prefetch(&store, id);
            }
            PrefetchMsg::Fence(ack) => {
                let _ = ack.send(());
            }
        }
    }
}

impl Engine {
    pub fn new(options: EngineOptions, overhead: OverheadConfig) -> Self {
        let block_cache = Arc::new(
            BlockCache::with_budget_bytes(options.block_cache_bytes)
                .with_faults(options.faults.clone()),
        );
        let (prefetch_tx, prefetch_handle) = if options.prefetch {
            let (tx, rx) = channel();
            let cache = Arc::clone(&block_cache);
            let handle = std::thread::Builder::new()
                .name("bigfcm-prefetch".to_string())
                .spawn(move || prefetch_loop(rx, cache))
                .expect("spawn prefetch thread");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };
        Self {
            pool: ThreadPool::new(options.workers),
            block_cache,
            options,
            overhead,
            clock: SimClock::new(),
            prefetch_tx,
            prefetch_handle,
        }
    }

    pub fn workers(&self) -> usize {
        self.options.workers
    }

    pub fn options(&self) -> &EngineOptions {
        &self.options
    }

    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    pub fn overhead(&self) -> &OverheadConfig {
        &self.overhead
    }

    /// The engine-wide block cache (warm across jobs of one pipeline run).
    pub fn block_cache(&self) -> &BlockCache {
        &self.block_cache
    }

    /// Charge driver-side local compute to the modelled clock.
    pub fn charge_local(&mut self, wall: Duration) {
        self.clock.charge_local(&self.overhead, wall);
    }

    /// Charge a driver-side HDFS scan.
    pub fn charge_scan(&mut self, bytes: u64) {
        self.clock.charge_scan(&self.overhead, bytes);
    }

    /// Charge modelled retry-backoff (fault recovery outside a job's own
    /// accounting, e.g. the session slab's ring reloads).
    pub fn charge_backoff(&mut self, s: f64) {
        self.clock.charge_backoff(s);
    }

    /// Execute one MapReduce job over every block of `store`.
    ///
    /// Blocks are read *inside* the worker tasks (see module docs); the
    /// store travels to the pool behind an `Arc`. Equivalent to
    /// [`Self::run_job_cfg`] with startup charged and the engine's
    /// tree-combine default.
    pub fn run_job<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        store: &Arc<BlockStore>,
        cache: Arc<DistributedCache>,
    ) -> Result<(J::Output, JobStats)> {
        let cfg = JobRunCfg { charge_startup: true, tree_combine: self.options.tree_combine };
        self.run_job_cfg(job, store, cache, cfg)
    }

    /// [`Self::run_job`] with per-invocation knobs — the session layer's
    /// entry point (resumed iterations skip the startup charge; the
    /// Mahout-style control disables the combine tree).
    pub fn run_job_cfg<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        store: &Arc<BlockStore>,
        cache: Arc<DistributedCache>,
        cfg: JobRunCfg,
    ) -> Result<(J::Output, JobStats)> {
        let started = Instant::now();
        let n_blocks = store.num_blocks();
        if n_blocks == 0 {
            return Err(Error::Job("no input blocks".into()));
        }
        // Job span: ambient on the driver thread (nests under an open
        // iteration span), explicit parent of the worker-side task spans.
        let tracer = trace::global();
        let mut job_span = tracer.span("job", "mapreduce");
        job_span.attr("name", job.name().to_string());
        job_span.attr("blocks", n_blocks.to_string());
        let job_span_id = job_span.id();
        // Demand-read wall accumulated by map tasks across workers.
        let read_nanos = Arc::new(AtomicU64::new(0));

        // Pre-draw fault schedules so parallel execution stays deterministic:
        // fail_counts[t] = how many attempts of task t fail before success.
        // The legacy `fault_rate` injector models per-attempt transient
        // failures and always leaves one good attempt; the chaos plan's
        // MapTask site models a dead node pinned to the task's split —
        // every attempt dies, the job surfaces [`Error::TaskFailed`] and
        // the pool stays reusable. Plan draws are taken in task order so
        // the schedule is independent of worker interleaving.
        let mut fault_rng = Pcg::new(self.options.fault_seed);
        let mut fail_counts: Vec<usize> = (0..n_blocks)
            .map(|_| {
                let mut fails = 0;
                while fails < MAX_ATTEMPTS - 1 && fault_rng.next_f64() < self.options.fault_rate {
                    fails += 1;
                }
                fails
            })
            .collect();
        if let Some(plan) = &self.options.faults {
            for fc in fail_counts.iter_mut() {
                if plan.check(FaultSite::MapTask).is_some() {
                    *fc = MAX_ATTEMPTS;
                }
            }
        }
        let fail_counts = fail_counts;

        // Locality hints: one queue entry per block on its preferred worker.
        let hints: Vec<usize> = store.blocks().iter().map(|b| b.preferred_worker).collect();
        let prefetch_hits_before = self.block_cache.prefetch_hits();
        let prefetch_wasted_before = self.block_cache.prefetch_wasted_bytes();
        let read_retries_before = self.block_cache.read_retries();
        let read_aborts_before = self.block_cache.read_aborts();
        let quarantines_before = self.block_cache.quarantines();
        let prefetch_errors_before = self.block_cache.prefetch_errors();
        let backoff_before = self.block_cache.backoff_seconds();
        let max_block = store.max_block_bytes();
        let use_tree = cfg.tree_combine && job.supports_combine();

        // Map phase: each task reads its own block on the pool (through the
        // engine's block cache), runs map_combine, and releases the block
        // when it finishes — the only materialized blocks at any instant are
        // the busy workers' plus the cache's budget plus the in-flight
        // prefetches (whose reservations count against the budget).
        struct TaskResult<M> {
            out: M,
            sample: TaskSample,
        }
        let job_for_map = Arc::clone(&job);
        let cache_for_map = Arc::clone(&cache);
        let store_for_map = Arc::clone(store);
        let blocks_for_map = Arc::clone(&self.block_cache);
        // `Sender` predates `Sync` in older std releases; the Mutex makes
        // the shared map closure unambiguously thread-safe either way.
        let prefetch_for_map = self.prefetch_tx.clone().map(Mutex::new);
        let read_for_map = Arc::clone(&read_nanos);

        let (outs, samples, locality, combine_depth, combine_wall_s) = if use_tree {
            // Worker-side tree combine: map outputs merge pairwise on the
            // pool as slots drain; the reduce sees O(log blocks) segments.
            // Samples travel on a side channel (the merge tree only carries
            // the combinable payload).
            let (sample_tx, sample_rx) = channel::<(usize, TaskSample)>();
            let sample_tx = Mutex::new(sample_tx);
            let job_for_combine = Arc::clone(&job);
            let combine_wall = Arc::new(Mutex::new(0.0f64));
            let combine_wall_in = Arc::clone(&combine_wall);
            let (parts, locality, cstats) = self.pool.map_indexed_hinted_combined(
                n_blocks,
                &hints,
                move |id, ahead| -> Result<J::MapOut> {
                    let (out, sample) = run_map_task(
                        job_for_map.as_ref(),
                        &cache_for_map,
                        &store_for_map,
                        &blocks_for_map,
                        prefetch_for_map.as_ref(),
                        max_block,
                        fail_counts[id],
                        id,
                        ahead,
                        &read_for_map,
                        job_span_id,
                    )?;
                    let _ = sample_tx
                        .lock()
                        .expect("sample sender poisoned")
                        .send((id, sample));
                    Ok(out)
                },
                move |a: Result<J::MapOut>, b: Result<J::MapOut>| -> Result<J::MapOut> {
                    match (a, b) {
                        (Ok(x), Ok(y)) => {
                            let t0 = Instant::now();
                            let merged = job_for_combine.combine(x, y);
                            let el = t0.elapsed();
                            *combine_wall_in.lock().expect("combine wall poisoned") +=
                                el.as_secs_f64();
                            trace::global()
                                .record_manual("combine", "mapreduce", job_span_id, el, Vec::new());
                            merged
                        }
                        (Err(e), _) | (_, Err(e)) => Err(e),
                    }
                },
            );
            self.fence_prefetcher();
            let mut outs = Vec::with_capacity(parts.len());
            for p in parts {
                let part = p
                    .map_err(|panic| Error::Job(format!("map/combine panicked: {panic}")))?
                    .map_err(wrap_map_error)?;
                outs.push(part);
            }
            let mut tagged: Vec<(usize, TaskSample)> = sample_rx.into_iter().collect();
            if tagged.len() != n_blocks {
                return Err(Error::Job(format!(
                    "lost map-task samples: {} of {n_blocks}",
                    tagged.len()
                )));
            }
            // Deterministic greedy-wave charging regardless of completion
            // order.
            tagged.sort_by_key(|(id, _)| *id);
            let samples: Vec<TaskSample> = tagged.into_iter().map(|(_, s)| s).collect();
            let combine_wall_s = *combine_wall.lock().expect("combine wall poisoned");
            (outs, samples, locality, cstats.depth, combine_wall_s)
        } else {
            let (results, locality) = self.pool.map_indexed_hinted(
                n_blocks,
                &hints,
                move |id, ahead| -> Result<TaskResult<J::MapOut>> {
                    run_map_task(
                        job_for_map.as_ref(),
                        &cache_for_map,
                        &store_for_map,
                        &blocks_for_map,
                        prefetch_for_map.as_ref(),
                        max_block,
                        fail_counts[id],
                        id,
                        ahead,
                        &read_for_map,
                        job_span_id,
                    )
                    .map(|(out, sample)| TaskResult { out, sample })
                },
            );
            self.fence_prefetcher();
            let mut outs = Vec::with_capacity(n_blocks);
            let mut samples = Vec::with_capacity(n_blocks);
            for r in results {
                let task = r
                    .map_err(|panic| Error::Job(format!("map task panicked: {panic}")))?
                    .map_err(wrap_map_error)?;
                samples.push(task.sample);
                outs.push(task.out);
            }
            (outs, samples, locality, 0, 0.0)
        };

        let attempts_total: usize = samples.iter().map(|s| s.attempts).sum();
        // Shuffle ships exactly what reaches the reduce: every map output
        // on the flat path, only the surviving merged segments on the tree
        // path.
        let shuffle_bytes: u64 = outs.iter().map(|o| job.shuffle_bytes(o)).sum();
        let reduce_parts = outs.len();

        // Reduce phase (single reducer, as the paper's default).
        let reduce_ctx = TaskCtx { cache: &cache, task_id: usize::MAX, attempt: 0, doomed: false };
        let t0 = Instant::now();
        let output = {
            let _reduce_span = tracer.span("reduce", "mapreduce");
            job.reduce(outs, &reduce_ctx)?
        };
        let reduce_wall_s = t0.elapsed().as_secs_f64();

        let mut oh = self.overhead.clone();
        if !cfg.charge_startup {
            // A resumed session iteration: the pool, cache and prefetcher
            // are already warm, so no per-job startup is paid.
            oh.job_startup_s = 0.0;
        }
        let mut sim = self.clock.charge_job(
            &oh,
            self.options.workers,
            &samples,
            shuffle_bytes,
            reduce_wall_s,
        );
        if combine_wall_s > 0.0 {
            // Worker-side merges are real compute. They overlap map slots
            // in practice; charging them serially is conservative.
            sim.compute_s += self
                .clock
                .charge_local(&oh, Duration::from_secs_f64(combine_wall_s));
        }

        // Prefetcher reads nothing consumed this job (evicted unconsumed or
        // duplicate races) still moved bytes off the store: charge them so
        // modelled I/O counts every real read exactly once, even in the
        // churn regime where the budget is tight against the worker count.
        let prefetch_wasted_bytes =
            self.block_cache.prefetch_wasted_bytes() - prefetch_wasted_before;
        if prefetch_wasted_bytes > 0 {
            sim.hdfs_io_s += self.clock.charge_scan(&oh, prefetch_wasted_bytes);
        }

        // Modelled backoff this job's retried reads accrued in the cache:
        // fold it into the clock (and this job's breakdown) exactly once.
        let backoff = self.block_cache.backoff_seconds() - backoff_before;
        if backoff > 0.0 {
            sim.backoff_s += self.clock.charge_backoff(backoff);
        }

        let stats = JobStats {
            name: job.name().to_string(),
            wall: started.elapsed(),
            sim,
            map_tasks: n_blocks,
            attempts: attempts_total,
            shuffle_bytes,
            locality_hits: locality.local_hits,
            locality_steals: locality.steals,
            prefetch_hits: self.block_cache.prefetch_hits() - prefetch_hits_before,
            prefetch_wasted_bytes,
            read_retries: self.block_cache.read_retries() - read_retries_before,
            read_aborts: self.block_cache.read_aborts() - read_aborts_before,
            quarantines: self.block_cache.quarantines() - quarantines_before,
            prefetch_errors: self.block_cache.prefetch_errors() - prefetch_errors_before,
            records_pruned: 0,
            records_pruned_quant: 0,
            quant_sidecar_bytes: 0,
            quant_build_s: 0.0,
            slab_bytes: 0,
            slab_evictions: 0,
            slab_spilled_bytes: 0,
            slab_reloads: 0,
            slab_spill_retries: 0,
            slab_spill_quarantines: 0,
            refresh_cap: 0,
            shard_steals: 0,
            shard_steal_bytes: 0,
            reduce_wall_s,
            combine_wall_s,
            combine_depth,
            reduce_parts,
            read_wall_s: read_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            compute_wall_s: samples.iter().map(|s| s.compute_wall_s).sum(),
        };
        // Stamp the measured wall so the trace and the report agree exactly.
        job_span.set_dur(stats.wall);
        Ok((output, stats))
    }

    /// Map phase of one job over an explicit **global block-id list** —
    /// the sharded engine's per-shard entry point. Task `i` reads global
    /// block `block_ids[i]` through this engine's cache (cache and slab
    /// keys stay global, so a shard's warm state is exactly the state the
    /// single engine would hold for those blocks), and the worker-side
    /// combine cascade runs at the blocks' *global* leaf slots against a
    /// merge tree of `total_blocks` leaves. Segments whose merge partner
    /// lives on another shard park and are returned tagged `(level, slot)`;
    /// the caller completes the identical global merge DAG and runs the
    /// reduce, so a non-associative combiner (f32 accumulation) gives a
    /// bitwise drop-in for the unsharded run no matter how blocks were
    /// sliced. No reduce happens here: the returned [`JobStats`] carry the
    /// map/combine phase only (`reduce_wall_s` 0, `shuffle_bytes` = what
    /// the surviving segments ship to the global stage).
    pub fn run_job_map_segments<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        store: &Arc<BlockStore>,
        cache: Arc<DistributedCache>,
        cfg: JobRunCfg,
        block_ids: &[usize],
        total_blocks: usize,
    ) -> Result<(Vec<((usize, usize), J::MapOut)>, JobStats)> {
        let started = Instant::now();
        let n = block_ids.len();
        if n == 0 {
            return Err(Error::Job("no input blocks".into()));
        }
        // Ambient job span: on a shard runner thread this nests under the
        // shard span the sharded engine opened around this call.
        let tracer = trace::global();
        let mut job_span = tracer.span("job", "mapreduce");
        job_span.attr("name", job.name().to_string());
        job_span.attr("blocks", n.to_string());
        let job_span_id = job_span.id();
        let read_nanos = Arc::new(AtomicU64::new(0));

        // Pre-draw fault schedules in local task order (the id list is
        // fixed at plan time, so the schedule is a pure function of this
        // shard's seed and slice — independent of cross-shard interleaving).
        let mut fault_rng = Pcg::new(self.options.fault_seed);
        let mut fail_counts: Vec<usize> = (0..n)
            .map(|_| {
                let mut fails = 0;
                while fails < MAX_ATTEMPTS - 1 && fault_rng.next_f64() < self.options.fault_rate {
                    fails += 1;
                }
                fails
            })
            .collect();
        if let Some(plan) = &self.options.faults {
            for fc in fail_counts.iter_mut() {
                if plan.check(FaultSite::MapTask).is_some() {
                    *fc = MAX_ATTEMPTS;
                }
            }
        }
        let fail_counts = fail_counts;

        let hints: Vec<usize> = block_ids
            .iter()
            .map(|&b| store.blocks()[b].preferred_worker)
            .collect();
        let prefetch_hits_before = self.block_cache.prefetch_hits();
        let prefetch_wasted_before = self.block_cache.prefetch_wasted_bytes();
        let read_retries_before = self.block_cache.read_retries();
        let read_aborts_before = self.block_cache.read_aborts();
        let quarantines_before = self.block_cache.quarantines();
        let prefetch_errors_before = self.block_cache.prefetch_errors();
        let backoff_before = self.block_cache.backoff_seconds();
        let max_block = store.max_block_bytes();
        let use_tree = cfg.tree_combine && job.supports_combine();

        let job_for_map = Arc::clone(&job);
        let cache_for_map = Arc::clone(&cache);
        let store_for_map = Arc::clone(store);
        let blocks_for_map = Arc::clone(&self.block_cache);
        let prefetch_for_map = self.prefetch_tx.clone().map(Mutex::new);
        let ids_for_map = Arc::new(block_ids.to_vec());
        let read_for_map = Arc::clone(&read_nanos);

        let map_one = {
            let ids = Arc::clone(&ids_for_map);
            move |id: usize, ahead: QueueAhead| -> Result<(J::MapOut, TaskSample)> {
                // Queue lookahead carries local task ids; the prefetcher
                // wants store block ids.
                let ahead = QueueAhead {
                    next: ahead.next.map(|t| ids[t]),
                    next2: ahead.next2.map(|t| ids[t]),
                };
                run_map_task(
                    job_for_map.as_ref(),
                    &cache_for_map,
                    &store_for_map,
                    &blocks_for_map,
                    prefetch_for_map.as_ref(),
                    max_block,
                    fail_counts[id],
                    ids[id],
                    ahead,
                    &read_for_map,
                    job_span_id,
                )
            }
        };

        let (segments, samples, locality, combine_depth, combine_wall_s) = if use_tree {
            let (sample_tx, sample_rx) = channel::<(usize, TaskSample)>();
            let sample_tx = Mutex::new(sample_tx);
            let job_for_combine = Arc::clone(&job);
            let combine_wall = Arc::new(Mutex::new(0.0f64));
            let combine_wall_in = Arc::clone(&combine_wall);
            let (parts, locality, cstats) = self.pool.map_indexed_hinted_combined_at(
                n,
                &hints,
                block_ids,
                total_blocks,
                move |id, ahead| -> Result<J::MapOut> {
                    let (out, sample) = map_one(id, ahead)?;
                    let _ = sample_tx
                        .lock()
                        .expect("sample sender poisoned")
                        .send((id, sample));
                    Ok(out)
                },
                move |a: Result<J::MapOut>, b: Result<J::MapOut>| -> Result<J::MapOut> {
                    match (a, b) {
                        (Ok(x), Ok(y)) => {
                            let t0 = Instant::now();
                            let merged = job_for_combine.combine(x, y);
                            let el = t0.elapsed();
                            *combine_wall_in.lock().expect("combine wall poisoned") +=
                                el.as_secs_f64();
                            trace::global()
                                .record_manual("combine", "mapreduce", job_span_id, el, Vec::new());
                            merged
                        }
                        (Err(e), _) | (_, Err(e)) => Err(e),
                    }
                },
            );
            self.fence_prefetcher();
            let mut segments = Vec::with_capacity(parts.len());
            for (tag, p) in parts {
                let part = p
                    .map_err(|panic| Error::Job(format!("map/combine panicked: {panic}")))?
                    .map_err(wrap_map_error)?;
                segments.push((tag, part));
            }
            let mut tagged: Vec<(usize, TaskSample)> = sample_rx.into_iter().collect();
            if tagged.len() != n {
                return Err(Error::Job(format!(
                    "lost map-task samples: {} of {n}",
                    tagged.len()
                )));
            }
            tagged.sort_by_key(|(id, _)| *id);
            let samples: Vec<TaskSample> = tagged.into_iter().map(|(_, s)| s).collect();
            let combine_wall_s = *combine_wall.lock().expect("combine wall poisoned");
            (segments, samples, locality, cstats.depth, combine_wall_s)
        } else {
            let (results, locality) = self.pool.map_indexed_hinted(n, &hints, move |id, ahead| {
                map_one(id, ahead)
            });
            self.fence_prefetcher();
            let mut segments = Vec::with_capacity(n);
            let mut samples = Vec::with_capacity(n);
            for (i, r) in results.into_iter().enumerate() {
                let (out, sample) = r
                    .map_err(|panic| Error::Job(format!("map task panicked: {panic}")))?
                    .map_err(wrap_map_error)?;
                samples.push(sample);
                // Flat path: every map output is a leaf-level segment.
                segments.push(((0usize, block_ids[i]), out));
            }
            (segments, samples, locality, 0, 0.0)
        };

        let attempts_total: usize = samples.iter().map(|s| s.attempts).sum();
        let shuffle_bytes: u64 = segments.iter().map(|(_, o)| job.shuffle_bytes(o)).sum();
        let reduce_parts = segments.len();

        let mut oh = self.overhead.clone();
        if !cfg.charge_startup {
            oh.job_startup_s = 0.0;
        }
        let mut sim = self.clock.charge_job(&oh, self.options.workers, &samples, shuffle_bytes, 0.0);
        if combine_wall_s > 0.0 {
            sim.compute_s += self
                .clock
                .charge_local(&oh, Duration::from_secs_f64(combine_wall_s));
        }
        let prefetch_wasted_bytes =
            self.block_cache.prefetch_wasted_bytes() - prefetch_wasted_before;
        if prefetch_wasted_bytes > 0 {
            sim.hdfs_io_s += self.clock.charge_scan(&oh, prefetch_wasted_bytes);
        }
        let backoff = self.block_cache.backoff_seconds() - backoff_before;
        if backoff > 0.0 {
            sim.backoff_s += self.clock.charge_backoff(backoff);
        }

        let stats = JobStats {
            name: job.name().to_string(),
            wall: started.elapsed(),
            sim,
            map_tasks: n,
            attempts: attempts_total,
            shuffle_bytes,
            locality_hits: locality.local_hits,
            locality_steals: locality.steals,
            prefetch_hits: self.block_cache.prefetch_hits() - prefetch_hits_before,
            prefetch_wasted_bytes,
            read_retries: self.block_cache.read_retries() - read_retries_before,
            read_aborts: self.block_cache.read_aborts() - read_aborts_before,
            quarantines: self.block_cache.quarantines() - quarantines_before,
            prefetch_errors: self.block_cache.prefetch_errors() - prefetch_errors_before,
            records_pruned: 0,
            records_pruned_quant: 0,
            quant_sidecar_bytes: 0,
            quant_build_s: 0.0,
            slab_bytes: 0,
            slab_evictions: 0,
            slab_spilled_bytes: 0,
            slab_reloads: 0,
            slab_spill_retries: 0,
            slab_spill_quarantines: 0,
            refresh_cap: 0,
            shard_steals: 0,
            shard_steal_bytes: 0,
            reduce_wall_s: 0.0,
            combine_wall_s,
            combine_depth,
            reduce_parts,
            read_wall_s: read_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            compute_wall_s: samples.iter().map(|s| s.compute_wall_s).sum(),
        };
        job_span.set_dur(stats.wall);
        Ok((segments, stats))
    }

    /// Barrier the prefetcher: every map task has finished, so every Fetch
    /// this job will ever queue is already in the channel; fencing makes
    /// late completions land in this job's meters (and charges), not the
    /// next job's — and Drop never faces a stale backlog.
    fn fence_prefetcher(&self) {
        if let Some(tx) = &self.prefetch_tx {
            let (ack_tx, ack_rx) = channel();
            if tx.send(PrefetchMsg::Fence(ack_tx)).is_ok() {
                let _ = ack_rx.recv();
            }
        }
    }
}

/// Keep structured failures structured across the map barrier:
/// [`Error::TaskFailed`] (attempt exhaustion) passes through untouched so
/// callers can match on it; everything else gets the generic job wrapper.
fn wrap_map_error(e: Error) -> Error {
    match e {
        e @ Error::TaskFailed { .. } => e,
        e => Error::Job(format!("map task failed: {e}")),
    }
}

/// One map task, start to finish: hint the prefetcher at the claimed
/// queue's lookahead (depth 2 only while the cache budget has ≥ 2
/// max-blocks of unreserved slack), read the block through the cache,
/// run `map_combine` with Hadoop's re-execution semantics, and report the
/// task's modelled sample.
///
/// Modelled HDFS bytes: a demand miss paid the read on the task's critical
/// path; a prefetched block's read also happened this job (off the
/// critical path) and is charged to the task that consumes it. Only blocks
/// warm from earlier jobs — data-local in-memory re-reads, the paper's
/// caching design — cost nothing.
#[allow(clippy::too_many_arguments)]
fn run_map_task<J: MapReduceJob>(
    job: &J,
    cache: &DistributedCache,
    store: &Arc<BlockStore>,
    blocks: &BlockCache,
    prefetch: Option<&Mutex<Sender<PrefetchMsg>>>,
    max_block: u64,
    fails: usize,
    id: usize,
    ahead: QueueAhead,
    read_nanos: &AtomicU64,
    job_span: u64,
) -> Result<(J::MapOut, TaskSample)> {
    // Worker-side task span: explicit parent (the driver's job span lives
    // on another thread), ambient for the spill/reload spans the slab may
    // open while this task computes.
    let mut task_span = trace::global().span_child("map_task", "mapreduce", job_span);
    task_span.attr("block", id.to_string());
    // Hint the prefetcher *before* paying our own read, so they overlap.
    if let (Some(tx), Some(next)) = (prefetch, ahead.next) {
        let tx = tx.lock().expect("prefetch sender poisoned");
        let _ = tx.send(PrefetchMsg::Fetch(Arc::clone(store), next));
        // Adaptive depth (ROADMAP streaming follow-up): also warm the
        // block after next while the budget has two max-blocks of slack —
        // the reservation accounting in the cache keeps the residency
        // envelope `budget + workers × max_block` intact either way.
        if let Some(next2) = ahead.next2 {
            if max_block > 0 && blocks.budget_slack() >= 2 * max_block {
                let _ = tx.send(PrefetchMsg::Fetch(Arc::clone(store), next2));
            }
        }
    }
    if fails >= MAX_ATTEMPTS {
        // The chaos plan killed this task's node: every attempt would die.
        // Surface the exhaustion as a structured error — no panic, and the
        // pool (which collects per-task Results) stays fully reusable.
        return Err(Error::TaskFailed { task: id, attempts: MAX_ATTEMPTS });
    }
    let t_read = Instant::now();
    let (block, source) = blocks.get_or_read_traced(store, id)?;
    read_nanos.fetch_add(t_read.elapsed().as_nanos() as u64, Ordering::Relaxed);
    let bytes = match source {
        ReadSource::Cached => 0,
        ReadSource::Miss | ReadSource::Prefetched => store.blocks()[id].bytes,
    };
    let mut attempt = 0usize;
    loop {
        let ctx = TaskCtx { cache, task_id: id, attempt, doomed: attempt < fails };
        let t0 = Instant::now();
        let out = job.map_combine(block.data(), &ctx);
        let compute_wall_s = t0.elapsed().as_secs_f64();
        // Injected fault: discard this attempt's output and retry
        // (idempotence is the combiner contract).
        if attempt < fails {
            attempt += 1;
            continue;
        }
        task_span.attr("attempts", (attempt + 1).to_string());
        return out.map(|o| {
            (o, TaskSample { compute_wall_s, input_bytes: bytes, attempts: attempt + 1 })
        });
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Disconnect the prefetcher (its recv() errors out), then join it.
        self.prefetch_tx = None;
        if let Some(h) = self.prefetch_handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::data::Matrix;

    /// Toy job: per-block weighted row sums, reduce = grand total.
    struct SumJob;

    impl MapReduceJob for SumJob {
        type MapOut = (f64, usize);
        type Output = (f64, usize);

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
            let s: f64 = block.as_slice().iter().map(|&v| v as f64).sum();
            Ok((s, block.rows()))
        }

        fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
            Ok(parts
                .into_iter()
                .fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
        }

        fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
            16
        }

        fn name(&self) -> &str {
            "sum"
        }
    }

    fn store() -> Arc<BlockStore> {
        let d = blobs(1000, 3, 2, 0.5, 1);
        Arc::new(BlockStore::in_memory("t", &d.features, 128, 4).unwrap())
    }

    #[test]
    fn job_computes_correct_global_result() {
        let s = store();
        let expected: f64 = {
            let mut acc = 0.0;
            for b in 0..s.num_blocks() {
                acc += s
                    .read_block(b)
                    .unwrap()
                    .as_slice()
                    .iter()
                    .map(|&v| v as f64)
                    .sum::<f64>();
            }
            acc
        };
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let ((total, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!((total - expected).abs() < 1e-6);
        assert_eq!(stats.map_tasks, 8);
        assert_eq!(stats.attempts, 8);
        assert_eq!(stats.shuffle_bytes, 8 * 16);
        assert!(stats.sim.total_s() > 0.0);
        assert_eq!(stats.locality_hits + stats.locality_steals, 8);
    }

    #[test]
    fn fault_injection_retries_and_still_correct() {
        let s = store();
        let opts =
            EngineOptions { workers: 4, fault_rate: 0.4, fault_seed: 9, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!(stats.attempts > stats.map_tasks, "expected retries");
    }

    #[test]
    fn sim_clock_accumulates_per_job() {
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        for _ in 0..3 {
            e.run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
                .unwrap();
        }
        assert_eq!(e.clock().jobs(), 3);
        // 3 job startups at least.
        assert!(e.clock().total_s() >= 3.0 * e.overhead().job_startup_s);
    }

    #[test]
    fn cache_visible_to_tasks() {
        struct CacheEcho;
        impl MapReduceJob for CacheEcho {
            type MapOut = f64;
            type Output = f64;
            fn map_combine(&self, _b: &Matrix, ctx: &TaskCtx) -> Result<f64> {
                Ok(ctx.cache.get_scalar("x").unwrap_or(-1.0))
            }
            fn reduce(&self, parts: Vec<f64>, _ctx: &TaskCtx) -> Result<f64> {
                Ok(parts.into_iter().sum())
            }
            fn shuffle_bytes(&self, _p: &f64) -> u64 {
                8
            }
        }
        let s = store();
        let cache = Arc::new(DistributedCache::new());
        cache.put_scalar("x", 2.5);
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let (total, _) = e.run_job(Arc::new(CacheEcho), &s, cache).unwrap();
        assert_eq!(total, 2.5 * s.num_blocks() as f64);
    }

    #[test]
    fn failing_map_task_fails_job() {
        struct FailJob;
        impl MapReduceJob for FailJob {
            type MapOut = ();
            type Output = ();
            fn map_combine(&self, _b: &Matrix, ctx: &TaskCtx) -> Result<()> {
                if ctx.task_id == 2 {
                    Err(Error::Job("synthetic failure".into()))
                } else {
                    Ok(())
                }
            }
            fn reduce(&self, _p: Vec<()>, _ctx: &TaskCtx) -> Result<()> {
                Ok(())
            }
            fn shuffle_bytes(&self, _p: &()) -> u64 {
                0
            }
        }
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let r = e.run_job(Arc::new(FailJob), &s, Arc::new(DistributedCache::new()));
        assert!(r.is_err());
    }

    #[test]
    fn streaming_bounds_resident_bytes_on_disk_store() {
        // 20 on-disk blocks, byte budget of 3 blocks, 4 workers: the job
        // must succeed with the budget far below the store size while never
        // materializing more than budget + workers × block bytes at once —
        // the streaming-pipeline memory bound, with prefetch on.
        let d = blobs(2000, 3, 2, 0.5, 2);
        let dir = std::env::temp_dir().join(format!("bigfcm_stream_{}", std::process::id()));
        let s = Arc::new(BlockStore::on_disk("t", &d.features, 100, 4, dir.clone()).unwrap());
        assert_eq!(s.num_blocks(), 20);
        let workers = 4u64;
        let block_bytes = s.max_block_bytes();
        let budget = 3 * block_bytes;
        let opts = EngineOptions { workers: 4, block_cache_bytes: budget, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 2000);
        assert_eq!(stats.map_tasks, 20);
        let bc = e.block_cache();
        assert!(
            bc.peak_resident_bytes() <= budget + workers * block_bytes,
            "peak resident bytes {} > budget {budget} + workers × block {block_bytes}",
            bc.peak_resident_bytes()
        );
        assert!(bc.cached_bytes() <= budget);
        // Every distinct block was decoded at least once, by a demand miss
        // or by the prefetcher.
        assert!(bc.misses() + bc.prefetches() >= 20, "{} + {}", bc.misses(), bc.prefetches());
        assert_eq!(stats.locality_hits + stats.locality_steals, 20);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn repeated_jobs_hit_warm_block_cache() {
        // Prefetch off: this test pins exact demand-miss counts and the
        // warm pass's zero modelled I/O, which a racing prefetcher would
        // legitimately perturb.
        let s = store(); // 8 in-memory blocks
        let opts = EngineOptions {
            workers: 4,
            block_cache_bytes: 16 * MIB,
            prefetch: false,
            ..Default::default()
        };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let (_, stats1) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(e.block_cache().misses(), 8);
        assert!(stats1.sim.hdfs_io_s > 0.0, "cold pass must pay modelled HDFS I/O");
        // Iteration 2 over the same store: every block is warm — no
        // re-decode and no modelled HDFS read.
        let (_, stats2) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(e.block_cache().misses(), 8, "second pass must not re-decode");
        assert_eq!(e.block_cache().hits(), 8);
        assert_eq!(stats2.sim.hdfs_io_s, 0.0, "warm pass must charge no HDFS I/O");
        assert_eq!(stats2.prefetch_hits, 0);
    }

    #[test]
    fn locality_hints_beyond_pool_size_degrade_gracefully() {
        // Store sharded for 8 workers, engine pool of 2: hints 0..7 wrap
        // onto the 2 logical workers and every block still runs exactly
        // once with claims fully accounted.
        let d = blobs(1000, 3, 2, 0.5, 3);
        let s = Arc::new(BlockStore::in_memory("t", &d.features, 125, 8).unwrap());
        assert_eq!(s.num_blocks(), 8);
        let opts = EngineOptions { workers: 2, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert_eq!(stats.map_tasks, 8);
        assert_eq!(stats.locality_hits + stats.locality_steals, 8);
    }

    #[test]
    fn prefetch_disabled_engine_has_no_prefetcher_effects() {
        let s = store();
        let opts = EngineOptions { prefetch: false, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let (_, stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(stats.prefetch_hits, 0);
        assert_eq!(e.block_cache().prefetches(), 0);
    }

    /// SumJob with a real combiner: the tree path must produce the same
    /// global result while shrinking what the reduce funnels.
    struct CombSum;

    impl MapReduceJob for CombSum {
        type MapOut = (f64, usize);
        type Output = (f64, usize);

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
            let s: f64 = block.as_slice().iter().map(|&v| v as f64).sum();
            Ok((s, block.rows()))
        }

        fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
            Ok(parts
                .into_iter()
                .fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
        }

        fn supports_combine(&self) -> bool {
            true
        }

        fn combine(&self, left: Self::MapOut, right: Self::MapOut) -> Result<Self::MapOut> {
            Ok((left.0 + right.0, left.1 + right.1))
        }

        fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
            16
        }

        fn name(&self) -> &str {
            "comb_sum"
        }
    }

    #[test]
    fn tree_combine_matches_flat_and_shrinks_reduce() {
        let s = store(); // 8 blocks
        let cache = Arc::new(DistributedCache::new());
        let mut flat_engine = Engine::new(
            EngineOptions { tree_combine: false, ..Default::default() },
            OverheadConfig::default(),
        );
        let ((flat_total, flat_rows), flat_stats) = flat_engine
            .run_job(Arc::new(CombSum), &s, Arc::clone(&cache))
            .unwrap();
        let mut tree_engine = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let ((tree_total, tree_rows), tree_stats) = tree_engine
            .run_job(Arc::new(CombSum), &s, cache)
            .unwrap();
        assert_eq!(flat_rows, 1000);
        assert_eq!(tree_rows, 1000);
        assert!((flat_total - tree_total).abs() < 1e-9);
        // Flat funnels every map output; the tree funnels the merged root
        // (8 = 2^3 blocks → exactly one part, depth 3).
        assert_eq!(flat_stats.reduce_parts, 8);
        assert_eq!(flat_stats.combine_depth, 0);
        assert_eq!(flat_stats.shuffle_bytes, 8 * 16);
        assert_eq!(tree_stats.reduce_parts, 1);
        assert_eq!(tree_stats.combine_depth, 3);
        assert_eq!(tree_stats.shuffle_bytes, 16);
        assert_eq!(tree_stats.attempts, 8, "samples must cover every task");
        assert_eq!(tree_stats.locality_hits + tree_stats.locality_steals, 8);
    }

    #[test]
    fn tree_combine_survives_fault_injection() {
        let s = store();
        let opts =
            EngineOptions { workers: 4, fault_rate: 0.4, fault_seed: 9, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((total, rows), stats) = e
            .run_job(Arc::new(CombSum), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!(total.is_finite());
        assert!(stats.attempts > stats.map_tasks, "expected retries");
    }

    #[test]
    fn job_without_combiner_ignores_tree_option() {
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let (_, stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(stats.reduce_parts, 8, "flat fallback for combiner-less jobs");
        assert_eq!(stats.combine_depth, 0);
    }

    #[test]
    fn chaos_task_exhaustion_is_structured_and_pool_stays_reusable() {
        use crate::faults::FaultPlan;
        let s = store(); // 8 blocks
        let opts = EngineOptions {
            faults: Some(FaultPlan::tripping(3, FaultSite::MapTask, 2)),
            ..Default::default()
        };
        let mut e = Engine::new(opts, OverheadConfig::default());
        // Job 1: the plan kills task 2's node — every attempt dies.
        let err = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap_err();
        match err {
            Error::TaskFailed { task, attempts } => {
                assert_eq!(task, 2);
                assert_eq!(attempts, MAX_ATTEMPTS);
            }
            other => panic!("expected structured TaskFailed, got: {other}"),
        }
        // Job 2 on the same engine: the trip already fired (ops 8..), so
        // the pool must run a clean job to completion — no poisoned slots,
        // no hang.
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert_eq!(stats.attempts, 8);
    }

    #[test]
    fn chaos_transient_read_charges_clock_once_per_retry() {
        use crate::faults::{backoff_s, FaultPlan};
        let s = store();
        // Trip exactly one transient fault at the first demand block read;
        // prefetch off so the demand path owns every op at the site.
        let opts = EngineOptions {
            prefetch: false,
            faults: Some(FaultPlan::tripping(5, FaultSite::BlockRead, 0)),
            ..Default::default()
        };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((total, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!(total.is_finite());
        assert_eq!(stats.read_retries, 1, "exactly one injected fault => one retry");
        assert_eq!(stats.read_aborts, 0);
        // The clock is charged exactly the modelled backoff of attempt 1 —
        // once, in the job's own cost breakdown and in the engine total.
        assert!(
            (stats.sim.backoff_s - backoff_s(1)).abs() < 1e-9,
            "job backoff {} != modelled {}",
            stats.sim.backoff_s,
            backoff_s(1)
        );
        assert!((e.clock().cost().backoff_s - backoff_s(1)).abs() < 1e-9);
        // A second, fault-free job charges no further backoff.
        let (_, stats2) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(stats2.sim.backoff_s, 0.0);
        assert!((e.clock().cost().backoff_s - backoff_s(1)).abs() < 1e-9);
    }

    #[test]
    fn uncharged_startup_drops_job_startup_only() {
        let s = store();
        let cache = Arc::new(DistributedCache::new());
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let cfg = JobRunCfg { charge_startup: false, tree_combine: false };
        let (_, stats) = e
            .run_job_cfg(Arc::new(SumJob), &s, Arc::clone(&cache), cfg)
            .unwrap();
        assert_eq!(stats.sim.job_startup_s, 0.0);
        assert!(stats.sim.total_s() > 0.0, "other cost classes still charged");
        let (_, charged) = e.run_job(Arc::new(SumJob), &s, cache).unwrap();
        assert!(charged.sim.job_startup_s > 0.0);
    }
}
