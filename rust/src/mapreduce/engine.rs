//! The job engine: schedules map tasks over the worker pool with locality
//! hints, re-executes failed attempts, prefetches upcoming blocks, runs the
//! reduce, and charges the SimClock.
//!
//! ## Streaming map pipeline
//!
//! `run_job` never materializes the dataset: map tasks are described to the
//! pool by block id alone, and each map slot reads (or cache-hits), computes
//! and *drops* its block inside the worker closure. Peak decoded-block
//! memory is therefore O(byte budget + workers × block size), not
//! O(dataset) — the property that lets one engine stream multi-gigabyte
//! stores. Three mechanisms coordinate around the engine's byte-budgeted
//! [`BlockCache`]:
//!
//! * **locality-aware ordering** — tasks are queued per worker from each
//!   block's [`crate::hdfs::BlockMeta::preferred_worker`] hint
//!   ([`ThreadPool::map_indexed_hinted`]); a worker steals only when its
//!   own queue is dry. Own-queue claims vs steals surface in [`JobStats`].
//! * **prefetch** — when a worker claims block *k* it hints the engine's
//!   prefetcher thread at block *k+1* of the same queue, so the next disk
//!   read overlaps the current block's compute. Prefetch-served reads
//!   surface in [`JobStats::prefetch_hits`].
//! * **byte-budgeted caching** — warm blocks are served by the engine's
//!   [`BlockCache`], so iterative callers (the Mahout-style
//!   one-job-per-iteration baselines especially) re-read hot blocks from
//!   memory instead of re-decoding HDFS files.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{ClusterConfig, OverheadConfig};
use crate::error::{Error, Result};
use crate::hdfs::BlockStore;
use crate::mapreduce::cache::{BlockCache, ReadSource, MIB};
use crate::mapreduce::simclock::{SimClock, SimCost, TaskSample};
use crate::mapreduce::{DistributedCache, MapReduceJob, TaskCtx};
use crate::prng::Pcg;
use crate::threadpool::ThreadPool;

/// Hadoop's default max attempts per task.
const MAX_ATTEMPTS: usize = 4;

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Worker (map-slot) count.
    pub workers: usize,
    /// Injected per-attempt failure probability (fault-tolerance tests).
    pub fault_rate: f64,
    /// Seed for fault injection.
    pub fault_seed: u64,
    /// Block-cache byte budget (0 disables caching; reads then stream
    /// straight from the store, one block per busy worker). Express MiB
    /// budgets via [`crate::mapreduce::cache::MIB`].
    pub block_cache_bytes: u64,
    /// Overlap the next queued block's read with the current block's
    /// compute on a dedicated prefetcher thread.
    pub prefetch: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            workers: 4,
            fault_rate: 0.0,
            fault_seed: 0,
            block_cache_bytes: 256 * MIB,
            prefetch: true,
        }
    }
}

impl EngineOptions {
    /// Engine shape from the cluster config (fault injection stays off).
    pub fn from_cluster(cluster: &ClusterConfig) -> Self {
        Self {
            workers: cluster.workers,
            block_cache_bytes: cluster.cache_mib as u64 * MIB,
            prefetch: cluster.prefetch,
            ..Self::default()
        }
    }
}

/// Statistics of one executed job.
#[derive(Clone, Debug)]
pub struct JobStats {
    pub name: String,
    /// Real elapsed time of the whole job on this machine.
    pub wall: Duration,
    /// Modelled cluster cost of this job.
    pub sim: SimCost,
    pub map_tasks: usize,
    /// Total attempts (> map_tasks when faults were injected).
    pub attempts: usize,
    pub shuffle_bytes: u64,
    /// Map tasks claimed by the worker their block's locality hint named.
    pub locality_hits: usize,
    /// Map tasks stolen by a worker whose own queue was dry.
    pub locality_steals: usize,
    /// Map-task block reads served warm by the prefetcher this job.
    pub prefetch_hits: u64,
    /// Prefetcher disk reads nothing consumed (evicted before first touch
    /// or lost a duplicate race); charged to this job's modelled HDFS I/O
    /// so every real read is counted exactly once.
    pub prefetch_wasted_bytes: u64,
}

/// The MapReduce engine. One engine per pipeline run; owns the worker pool,
/// the block cache, the prefetcher thread and the SimClock.
pub struct Engine {
    pool: ThreadPool,
    options: EngineOptions,
    overhead: OverheadConfig,
    clock: SimClock,
    block_cache: Arc<BlockCache>,
    prefetch_tx: Option<Sender<PrefetchMsg>>,
    prefetch_handle: Option<JoinHandle<()>>,
}

/// Messages to the engine's prefetcher thread.
enum PrefetchMsg {
    /// Pull this block into the cache ahead of demand.
    Fetch(Arc<BlockStore>, usize),
    /// Barrier: ack once every message queued before it is processed. Sent
    /// at the end of each job's map phase so late prefetch completions are
    /// metered (and charged) to the job whose map queued them, and so an
    /// engine is never dropped with a backlog of pointless reads.
    Fence(Sender<()>),
}

/// Prefetcher thread body: pull hinted blocks into the cache until the
/// engine drops its sender. Prefetch failures are deliberately swallowed —
/// the demand path will retry the read and surface the error attached to
/// the task that needed the block.
fn prefetch_loop(rx: Receiver<PrefetchMsg>, cache: Arc<BlockCache>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            PrefetchMsg::Fetch(store, id) => {
                let _ = cache.prefetch(&store, id);
            }
            PrefetchMsg::Fence(ack) => {
                let _ = ack.send(());
            }
        }
    }
}

impl Engine {
    pub fn new(options: EngineOptions, overhead: OverheadConfig) -> Self {
        let block_cache = Arc::new(BlockCache::with_budget_bytes(options.block_cache_bytes));
        let (prefetch_tx, prefetch_handle) = if options.prefetch {
            let (tx, rx) = channel();
            let cache = Arc::clone(&block_cache);
            let handle = std::thread::Builder::new()
                .name("bigfcm-prefetch".to_string())
                .spawn(move || prefetch_loop(rx, cache))
                .expect("spawn prefetch thread");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };
        Self {
            pool: ThreadPool::new(options.workers),
            block_cache,
            options,
            overhead,
            clock: SimClock::new(),
            prefetch_tx,
            prefetch_handle,
        }
    }

    pub fn workers(&self) -> usize {
        self.options.workers
    }

    pub fn options(&self) -> &EngineOptions {
        &self.options
    }

    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    pub fn overhead(&self) -> &OverheadConfig {
        &self.overhead
    }

    /// The engine-wide block cache (warm across jobs of one pipeline run).
    pub fn block_cache(&self) -> &BlockCache {
        &self.block_cache
    }

    /// Charge driver-side local compute to the modelled clock.
    pub fn charge_local(&mut self, wall: Duration) {
        self.clock.charge_local(&self.overhead, wall);
    }

    /// Charge a driver-side HDFS scan.
    pub fn charge_scan(&mut self, bytes: u64) {
        self.clock.charge_scan(&self.overhead, bytes);
    }

    /// Execute one MapReduce job over every block of `store`.
    ///
    /// Blocks are read *inside* the worker tasks (see module docs); the
    /// store travels to the pool behind an `Arc`.
    pub fn run_job<J: MapReduceJob + 'static>(
        &mut self,
        job: Arc<J>,
        store: &Arc<BlockStore>,
        cache: Arc<DistributedCache>,
    ) -> Result<(J::Output, JobStats)> {
        let started = Instant::now();
        let n_blocks = store.num_blocks();
        if n_blocks == 0 {
            return Err(Error::Job("no input blocks".into()));
        }

        // Pre-draw fault schedules so parallel execution stays deterministic:
        // fail_counts[t] = how many attempts of task t fail before success.
        let mut fault_rng = Pcg::new(self.options.fault_seed);
        let fail_counts: Vec<usize> = (0..n_blocks)
            .map(|_| {
                let mut fails = 0;
                while fails < MAX_ATTEMPTS - 1 && fault_rng.next_f64() < self.options.fault_rate {
                    fails += 1;
                }
                fails
            })
            .collect();

        // Locality hints: one queue entry per block on its preferred worker.
        let hints: Vec<usize> = store.blocks().iter().map(|b| b.preferred_worker).collect();
        let prefetch_hits_before = self.block_cache.prefetch_hits();
        let prefetch_wasted_before = self.block_cache.prefetch_wasted_bytes();

        // Map phase: each task reads its own block on the pool (through the
        // engine's block cache), runs map_combine, and releases the block
        // when it finishes — the only materialized blocks at any instant are
        // the busy workers' plus the cache's budget plus at most one
        // in-flight prefetch.
        struct TaskResult<M> {
            out: M,
            sample: TaskSample,
        }
        let job_for_map = Arc::clone(&job);
        let cache_for_map = Arc::clone(&cache);
        let store_for_map = Arc::clone(store);
        let blocks_for_map = Arc::clone(&self.block_cache);
        // `Sender` predates `Sync` in older std releases; the Mutex makes
        // the shared map closure unambiguously thread-safe either way.
        let prefetch_for_map = self.prefetch_tx.clone().map(Mutex::new);
        let (results, locality) = self.pool.map_indexed_hinted(
            n_blocks,
            &hints,
            move |id, next| -> Result<TaskResult<J::MapOut>> {
                // Hint the prefetcher at this worker's next queued block
                // *before* paying our own read, so the two overlap.
                if let (Some(tx), Some(next)) = (prefetch_for_map.as_ref(), next) {
                    let _ = tx
                        .lock()
                        .expect("prefetch sender poisoned")
                        .send(PrefetchMsg::Fetch(Arc::clone(&store_for_map), next));
                }
                let fails = fail_counts[id];
                let (block, source) = blocks_for_map.get_or_read_traced(&store_for_map, id)?;
                // Modelled HDFS bytes: a demand miss paid the read on the
                // task's critical path; a prefetched block's read also
                // happened this job (off the critical path) and is charged
                // to the task that consumes it. Only blocks warm from
                // earlier jobs — data-local in-memory re-reads, the paper's
                // caching design — cost nothing.
                let bytes = match source {
                    ReadSource::Cached => 0,
                    ReadSource::Miss | ReadSource::Prefetched => store_for_map.blocks()[id].bytes,
                };
                let mut attempt = 0usize;
                loop {
                    let ctx = TaskCtx { cache: &cache_for_map, task_id: id, attempt };
                    let t0 = Instant::now();
                    let out = job_for_map.map_combine(block.data(), &ctx);
                    let compute_wall_s = t0.elapsed().as_secs_f64();
                    // Injected fault: discard this attempt's output and retry
                    // (idempotence is the combiner contract).
                    if attempt < fails {
                        attempt += 1;
                        continue;
                    }
                    return out.map(|o| TaskResult {
                        out: o,
                        sample: TaskSample {
                            compute_wall_s,
                            input_bytes: bytes,
                            attempts: attempt + 1,
                        },
                    });
                }
            },
        );

        // Every map task has finished, so every Fetch this job will ever
        // queue is already in the channel; fence the prefetcher so its
        // late completions land in this job's meters (and charges), not
        // the next job's — and so Drop never faces a stale backlog.
        if let Some(tx) = &self.prefetch_tx {
            let (ack_tx, ack_rx) = channel();
            if tx.send(PrefetchMsg::Fence(ack_tx)).is_ok() {
                let _ = ack_rx.recv();
            }
        }

        let mut outs = Vec::with_capacity(n_blocks);
        let mut samples = Vec::with_capacity(n_blocks);
        let mut attempts_total = 0usize;
        for r in results {
            let task = r
                .map_err(|panic| Error::Job(format!("map task panicked: {panic}")))?
                .map_err(|e| Error::Job(format!("map task failed: {e}")))?;
            attempts_total += task.sample.attempts;
            samples.push(task.sample);
            outs.push(task.out);
        }

        let shuffle_bytes: u64 = outs.iter().map(|o| job.shuffle_bytes(o)).sum();

        // Reduce phase (single reducer, as the paper's default).
        let reduce_ctx = TaskCtx { cache: &cache, task_id: usize::MAX, attempt: 0 };
        let t0 = Instant::now();
        let output = job.reduce(outs, &reduce_ctx)?;
        let reduce_wall_s = t0.elapsed().as_secs_f64();

        let mut sim = self.clock.charge_job(
            &self.overhead,
            self.options.workers,
            &samples,
            shuffle_bytes,
            reduce_wall_s,
        );

        // Prefetcher reads nothing consumed this job (evicted unconsumed or
        // duplicate races) still moved bytes off the store: charge them so
        // modelled I/O counts every real read exactly once, even in the
        // churn regime where the budget is tight against the worker count.
        let prefetch_wasted_bytes =
            self.block_cache.prefetch_wasted_bytes() - prefetch_wasted_before;
        if prefetch_wasted_bytes > 0 {
            sim.hdfs_io_s += self.clock.charge_scan(&self.overhead, prefetch_wasted_bytes);
        }

        let stats = JobStats {
            name: job.name().to_string(),
            wall: started.elapsed(),
            sim,
            map_tasks: n_blocks,
            attempts: attempts_total,
            shuffle_bytes,
            locality_hits: locality.local_hits,
            locality_steals: locality.steals,
            prefetch_hits: self.block_cache.prefetch_hits() - prefetch_hits_before,
            prefetch_wasted_bytes,
        };
        Ok((output, stats))
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // Disconnect the prefetcher (its recv() errors out), then join it.
        self.prefetch_tx = None;
        if let Some(h) = self.prefetch_handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::data::Matrix;

    /// Toy job: per-block weighted row sums, reduce = grand total.
    struct SumJob;

    impl MapReduceJob for SumJob {
        type MapOut = (f64, usize);
        type Output = (f64, usize);

        fn map_combine(&self, block: &Matrix, _ctx: &TaskCtx) -> Result<Self::MapOut> {
            let s: f64 = block.as_slice().iter().map(|&v| v as f64).sum();
            Ok((s, block.rows()))
        }

        fn reduce(&self, parts: Vec<Self::MapOut>, _ctx: &TaskCtx) -> Result<Self::Output> {
            Ok(parts
                .into_iter()
                .fold((0.0, 0), |acc, p| (acc.0 + p.0, acc.1 + p.1)))
        }

        fn shuffle_bytes(&self, _part: &Self::MapOut) -> u64 {
            16
        }

        fn name(&self) -> &str {
            "sum"
        }
    }

    fn store() -> Arc<BlockStore> {
        let d = blobs(1000, 3, 2, 0.5, 1);
        Arc::new(BlockStore::in_memory("t", &d.features, 128, 4).unwrap())
    }

    #[test]
    fn job_computes_correct_global_result() {
        let s = store();
        let expected: f64 = {
            let mut acc = 0.0;
            for b in 0..s.num_blocks() {
                acc += s
                    .read_block(b)
                    .unwrap()
                    .as_slice()
                    .iter()
                    .map(|&v| v as f64)
                    .sum::<f64>();
            }
            acc
        };
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let ((total, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!((total - expected).abs() < 1e-6);
        assert_eq!(stats.map_tasks, 8);
        assert_eq!(stats.attempts, 8);
        assert_eq!(stats.shuffle_bytes, 8 * 16);
        assert!(stats.sim.total_s() > 0.0);
        assert_eq!(stats.locality_hits + stats.locality_steals, 8);
    }

    #[test]
    fn fault_injection_retries_and_still_correct() {
        let s = store();
        let opts =
            EngineOptions { workers: 4, fault_rate: 0.4, fault_seed: 9, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert!(stats.attempts > stats.map_tasks, "expected retries");
    }

    #[test]
    fn sim_clock_accumulates_per_job() {
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        for _ in 0..3 {
            e.run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
                .unwrap();
        }
        assert_eq!(e.clock().jobs(), 3);
        // 3 job startups at least.
        assert!(e.clock().total_s() >= 3.0 * e.overhead().job_startup_s);
    }

    #[test]
    fn cache_visible_to_tasks() {
        struct CacheEcho;
        impl MapReduceJob for CacheEcho {
            type MapOut = f64;
            type Output = f64;
            fn map_combine(&self, _b: &Matrix, ctx: &TaskCtx) -> Result<f64> {
                Ok(ctx.cache.get_scalar("x").unwrap_or(-1.0))
            }
            fn reduce(&self, parts: Vec<f64>, _ctx: &TaskCtx) -> Result<f64> {
                Ok(parts.into_iter().sum())
            }
            fn shuffle_bytes(&self, _p: &f64) -> u64 {
                8
            }
        }
        let s = store();
        let cache = Arc::new(DistributedCache::new());
        cache.put_scalar("x", 2.5);
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let (total, _) = e.run_job(Arc::new(CacheEcho), &s, cache).unwrap();
        assert_eq!(total, 2.5 * s.num_blocks() as f64);
    }

    #[test]
    fn failing_map_task_fails_job() {
        struct FailJob;
        impl MapReduceJob for FailJob {
            type MapOut = ();
            type Output = ();
            fn map_combine(&self, _b: &Matrix, ctx: &TaskCtx) -> Result<()> {
                if ctx.task_id == 2 {
                    Err(Error::Job("synthetic failure".into()))
                } else {
                    Ok(())
                }
            }
            fn reduce(&self, _p: Vec<()>, _ctx: &TaskCtx) -> Result<()> {
                Ok(())
            }
            fn shuffle_bytes(&self, _p: &()) -> u64 {
                0
            }
        }
        let s = store();
        let mut e = Engine::new(EngineOptions::default(), OverheadConfig::default());
        let r = e.run_job(Arc::new(FailJob), &s, Arc::new(DistributedCache::new()));
        assert!(r.is_err());
    }

    #[test]
    fn streaming_bounds_resident_bytes_on_disk_store() {
        // 20 on-disk blocks, byte budget of 3 blocks, 4 workers: the job
        // must succeed with the budget far below the store size while never
        // materializing more than budget + workers × block bytes at once —
        // the streaming-pipeline memory bound, with prefetch on.
        let d = blobs(2000, 3, 2, 0.5, 2);
        let dir = std::env::temp_dir().join(format!("bigfcm_stream_{}", std::process::id()));
        let s = Arc::new(BlockStore::on_disk("t", &d.features, 100, 4, dir.clone()).unwrap());
        assert_eq!(s.num_blocks(), 20);
        let workers = 4u64;
        let block_bytes = s.max_block_bytes();
        let budget = 3 * block_bytes;
        let opts = EngineOptions { workers: 4, block_cache_bytes: budget, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 2000);
        assert_eq!(stats.map_tasks, 20);
        let bc = e.block_cache();
        assert!(
            bc.peak_resident_bytes() <= budget + workers * block_bytes,
            "peak resident bytes {} > budget {budget} + workers × block {block_bytes}",
            bc.peak_resident_bytes()
        );
        assert!(bc.cached_bytes() <= budget);
        // Every distinct block was decoded at least once, by a demand miss
        // or by the prefetcher.
        assert!(bc.misses() + bc.prefetches() >= 20, "{} + {}", bc.misses(), bc.prefetches());
        assert_eq!(stats.locality_hits + stats.locality_steals, 20);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn repeated_jobs_hit_warm_block_cache() {
        // Prefetch off: this test pins exact demand-miss counts and the
        // warm pass's zero modelled I/O, which a racing prefetcher would
        // legitimately perturb.
        let s = store(); // 8 in-memory blocks
        let opts = EngineOptions {
            workers: 4,
            block_cache_bytes: 16 * MIB,
            prefetch: false,
            ..Default::default()
        };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let (_, stats1) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(e.block_cache().misses(), 8);
        assert!(stats1.sim.hdfs_io_s > 0.0, "cold pass must pay modelled HDFS I/O");
        // Iteration 2 over the same store: every block is warm — no
        // re-decode and no modelled HDFS read.
        let (_, stats2) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(e.block_cache().misses(), 8, "second pass must not re-decode");
        assert_eq!(e.block_cache().hits(), 8);
        assert_eq!(stats2.sim.hdfs_io_s, 0.0, "warm pass must charge no HDFS I/O");
        assert_eq!(stats2.prefetch_hits, 0);
    }

    #[test]
    fn locality_hints_beyond_pool_size_degrade_gracefully() {
        // Store sharded for 8 workers, engine pool of 2: hints 0..7 wrap
        // onto the 2 logical workers and every block still runs exactly
        // once with claims fully accounted.
        let d = blobs(1000, 3, 2, 0.5, 3);
        let s = Arc::new(BlockStore::in_memory("t", &d.features, 125, 8).unwrap());
        assert_eq!(s.num_blocks(), 8);
        let opts = EngineOptions { workers: 2, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let ((_, rows), stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(rows, 1000);
        assert_eq!(stats.map_tasks, 8);
        assert_eq!(stats.locality_hits + stats.locality_steals, 8);
    }

    #[test]
    fn prefetch_disabled_engine_has_no_prefetcher_effects() {
        let s = store();
        let opts = EngineOptions { prefetch: false, ..Default::default() };
        let mut e = Engine::new(opts, OverheadConfig::default());
        let (_, stats) = e
            .run_job(Arc::new(SumJob), &s, Arc::new(DistributedCache::new()))
            .unwrap();
        assert_eq!(stats.prefetch_hits, 0);
        assert_eq!(e.block_cache().prefetches(), 0);
    }
}
