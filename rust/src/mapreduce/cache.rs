//! Distributed cache file — Hadoop's mechanism for shipping small read-only
//! data (the paper stores V_init / V_winit and the `Flag` there) to every
//! task. Modelled as a concurrent typed KV store; writes happen in the
//! driver before job submission, tasks only read.

use std::collections::HashMap;
use std::sync::RwLock;

use crate::data::Matrix;

/// A cached value.
#[derive(Clone, Debug)]
pub enum CacheValue {
    Matrix(Matrix),
    Scalar(f64),
    Flag(bool),
    Text(String),
}

/// The cache itself. Cheap to share via `&` across tasks.
#[derive(Default)]
pub struct DistributedCache {
    entries: RwLock<HashMap<String, CacheValue>>,
}

impl DistributedCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&self, key: &str, value: CacheValue) {
        self.entries
            .write()
            .expect("cache poisoned")
            .insert(key.to_string(), value);
    }

    pub fn put_matrix(&self, key: &str, m: Matrix) {
        self.put(key, CacheValue::Matrix(m));
    }

    pub fn put_flag(&self, key: &str, b: bool) {
        self.put(key, CacheValue::Flag(b));
    }

    pub fn put_scalar(&self, key: &str, v: f64) {
        self.put(key, CacheValue::Scalar(v));
    }

    pub fn get_matrix(&self, key: &str) -> Option<Matrix> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Matrix(m)) => Some(m.clone()),
            _ => None,
        }
    }

    pub fn get_flag(&self, key: &str) -> Option<bool> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Flag(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_scalar(&self, key: &str) -> Option<f64> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Scalar(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.read().expect("cache poisoned").contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.entries.read().expect("cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialised footprint (models the per-task cache download cost).
    pub fn bytes(&self) -> u64 {
        self.entries
            .read()
            .expect("cache poisoned")
            .values()
            .map(|v| match v {
                CacheValue::Matrix(m) => (m.rows() * m.cols() * 4) as u64,
                CacheValue::Scalar(_) => 8,
                CacheValue::Flag(_) => 1,
                CacheValue::Text(s) => s.len() as u64,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_roundtrips() {
        let c = DistributedCache::new();
        c.put_matrix("v_init", Matrix::from_rows(&[vec![1.0, 2.0]]));
        c.put_flag("flag", true);
        c.put_scalar("m", 2.0);
        assert_eq!(c.get_matrix("v_init").unwrap().row(0), &[1.0, 2.0]);
        assert_eq!(c.get_flag("flag"), Some(true));
        assert_eq!(c.get_scalar("m"), Some(2.0));
        assert_eq!(c.len(), 3);
        assert!(c.bytes() >= 8 + 8 + 1);
    }

    #[test]
    fn wrong_type_returns_none() {
        let c = DistributedCache::new();
        c.put_flag("x", false);
        assert!(c.get_matrix("x").is_none());
        assert!(c.get_scalar("x").is_none());
        assert!(c.get_flag("missing").is_none());
    }

    #[test]
    fn concurrent_reads() {
        let c = std::sync::Arc::new(DistributedCache::new());
        c.put_scalar("k", 7.0);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        assert_eq!(c.get_scalar("k"), Some(7.0));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
