//! Task-visible caches.
//!
//! * [`DistributedCache`] — Hadoop's mechanism for shipping small read-only
//!   data (the paper stores V_init / V_winit and the `Flag` there) to every
//!   task. Modelled as a concurrent typed KV store; writes happen in the
//!   driver before job submission, tasks only read.
//! * [`BlockCache`] — an LRU over decoded HDFS blocks, shared by all map
//!   slots of an engine. The streaming pipeline reads blocks *inside* the
//!   worker closure; this cache is what makes repeated iterations over the
//!   same store hit warm blocks instead of re-decoding — the paper's
//!   "efficient caching design". It also meters residency: how many decoded
//!   blocks are alive right now (cache + in-flight) and the high-water
//!   mark, which the engine tests pin to `workers + capacity`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::data::Matrix;
use crate::error::Result;
use crate::hdfs::BlockStore;

/// A cached value.
#[derive(Clone, Debug)]
pub enum CacheValue {
    Matrix(Matrix),
    Scalar(f64),
    Flag(bool),
    Text(String),
}

/// The cache itself. Cheap to share via `&` across tasks.
#[derive(Default)]
pub struct DistributedCache {
    entries: RwLock<HashMap<String, CacheValue>>,
}

impl DistributedCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&self, key: &str, value: CacheValue) {
        self.entries
            .write()
            .expect("cache poisoned")
            .insert(key.to_string(), value);
    }

    pub fn put_matrix(&self, key: &str, m: Matrix) {
        self.put(key, CacheValue::Matrix(m));
    }

    pub fn put_flag(&self, key: &str, b: bool) {
        self.put(key, CacheValue::Flag(b));
    }

    pub fn put_scalar(&self, key: &str, v: f64) {
        self.put(key, CacheValue::Scalar(v));
    }

    pub fn get_matrix(&self, key: &str) -> Option<Matrix> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Matrix(m)) => Some(m.clone()),
            _ => None,
        }
    }

    pub fn get_flag(&self, key: &str) -> Option<bool> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Flag(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_scalar(&self, key: &str) -> Option<f64> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Scalar(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.read().expect("cache poisoned").contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.entries.read().expect("cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialised footprint (models the per-task cache download cost).
    pub fn bytes(&self) -> u64 {
        self.entries
            .read()
            .expect("cache poisoned")
            .values()
            .map(|v| match v {
                CacheValue::Matrix(m) => (m.rows() * m.cols() * 4) as u64,
                CacheValue::Scalar(_) => 8,
                CacheValue::Flag(_) => 1,
                CacheValue::Text(s) => s.len() as u64,
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Block cache (LRU over decoded HDFS blocks)
// ---------------------------------------------------------------------------

/// Live-block gauge shared between the cache and every outstanding
/// [`CachedBlock`]: `resident` counts decoded blocks currently alive
/// anywhere (cache entries + blocks held by in-flight map tasks), `peak`
/// its high-water mark.
#[derive(Default)]
struct Residency {
    resident: AtomicUsize,
    peak: AtomicUsize,
}

/// One decoded block. Dropping the last `Arc<CachedBlock>` releases the
/// block's memory and decrements the residency gauge — the mechanism the
/// streaming-bound test (`engine::tests`) observes.
pub struct CachedBlock {
    data: Matrix,
    residency: Arc<Residency>,
}

impl CachedBlock {
    fn new(data: Matrix, residency: Arc<Residency>) -> Self {
        let now = residency.resident.fetch_add(1, Ordering::SeqCst) + 1;
        residency.peak.fetch_max(now, Ordering::SeqCst);
        Self { data, residency }
    }

    /// The block's records.
    pub fn data(&self) -> &Matrix {
        &self.data
    }
}

impl Drop for CachedBlock {
    fn drop(&mut self) {
        self.residency.resident.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Keys are `(store uid, block id)` so one cache can serve several stores
/// without aliasing.
type BlockKey = (u64, usize);

struct LruState {
    entries: HashMap<BlockKey, Arc<CachedBlock>>,
    /// Access order, least-recent at the front.
    order: VecDeque<BlockKey>,
}

/// Shared LRU cache of decoded blocks with hit/miss and residency metering.
/// `capacity` is in blocks; 0 disables caching (every read is a pass-through
/// miss, nothing is retained).
pub struct BlockCache {
    capacity: usize,
    state: Mutex<LruState>,
    hits: AtomicU64,
    misses: AtomicU64,
    residency: Arc<Residency>,
}

impl BlockCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            state: Mutex::new(LruState { entries: HashMap::new(), order: VecDeque::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            residency: Arc::new(Residency::default()),
        }
    }

    /// Fetch a block through the cache: warm hit returns the shared decoded
    /// block; a miss decodes from the store (outside the lock, so workers
    /// fetching different blocks decode in parallel) and inserts it,
    /// evicting the least-recently-used entry beyond `capacity`.
    ///
    /// A concurrent duplicate miss of the same block decodes twice and the
    /// later insert is dropped — benign, and still within the
    /// `workers + capacity` residency bound because the duplicate is held
    /// by exactly one in-flight task.
    pub fn get_or_read(&self, store: &BlockStore, id: usize) -> Result<Arc<CachedBlock>> {
        Ok(self.get_or_read_traced(store, id)?.0)
    }

    /// [`Self::get_or_read`] that also reports whether the block was served
    /// warm (`true` = cache hit: no store I/O happened, so the engine
    /// charges no modelled HDFS read for it).
    pub fn get_or_read_traced(
        &self,
        store: &BlockStore,
        id: usize,
    ) -> Result<(Arc<CachedBlock>, bool)> {
        let key: BlockKey = (store.uid(), id);
        if self.capacity > 0 {
            let mut st = self.state.lock().expect("block cache poisoned");
            if let Some(hit) = st.entries.get(&key).cloned() {
                if let Some(pos) = st.order.iter().position(|k| *k == key) {
                    st.order.remove(pos);
                    st.order.push_back(key);
                }
                drop(st);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok((hit, true));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let data = store.read_block(id)?;
        let block = Arc::new(CachedBlock::new(data, Arc::clone(&self.residency)));
        if self.capacity > 0 {
            let mut st = self.state.lock().expect("block cache poisoned");
            if !st.entries.contains_key(&key) {
                st.entries.insert(key, Arc::clone(&block));
                st.order.push_back(key);
                while st.order.len() > self.capacity {
                    if let Some(evicted) = st.order.pop_front() {
                        st.entries.remove(&evicted);
                    }
                }
            }
        }
        Ok((block, false))
    }

    /// Capacity in blocks (0 = caching disabled).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocks currently retained by the cache itself.
    pub fn len(&self) -> usize {
        self.state.lock().expect("block cache poisoned").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Decoded blocks alive right now (cache entries + in-flight tasks).
    pub fn resident(&self) -> usize {
        self.residency.resident.load(Ordering::SeqCst)
    }

    /// High-water mark of [`Self::resident`] since construction.
    pub fn peak_resident(&self) -> usize {
        self.residency.peak.load(Ordering::SeqCst)
    }

    /// Drop every retained block (in-flight holders keep theirs alive).
    pub fn clear(&self) {
        let mut st = self.state.lock().expect("block cache poisoned");
        st.entries.clear();
        st.order.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_roundtrips() {
        let c = DistributedCache::new();
        c.put_matrix("v_init", Matrix::from_rows(&[vec![1.0, 2.0]]));
        c.put_flag("flag", true);
        c.put_scalar("m", 2.0);
        assert_eq!(c.get_matrix("v_init").unwrap().row(0), &[1.0, 2.0]);
        assert_eq!(c.get_flag("flag"), Some(true));
        assert_eq!(c.get_scalar("m"), Some(2.0));
        assert_eq!(c.len(), 3);
        assert!(c.bytes() >= 8 + 8 + 1);
    }

    #[test]
    fn wrong_type_returns_none() {
        let c = DistributedCache::new();
        c.put_flag("x", false);
        assert!(c.get_matrix("x").is_none());
        assert!(c.get_scalar("x").is_none());
        assert!(c.get_flag("missing").is_none());
    }

    fn block_store(n: usize, block: usize) -> BlockStore {
        let d = crate::data::synth::blobs(n, 3, 2, 0.4, 7);
        BlockStore::in_memory("t", &d.features, block, 2).unwrap()
    }

    #[test]
    fn block_cache_hits_after_first_read() {
        let s = block_store(400, 100); // 4 blocks
        let c = BlockCache::new(8);
        let a = c.get_or_read(&s, 2).unwrap();
        let b = c.get_or_read(&s, 2).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "warm hit must return the shared block");
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert_eq!(c.len(), 1);
        assert_eq!(a.data().rows(), 100);
    }

    #[test]
    fn block_cache_evicts_least_recently_used() {
        let s = block_store(400, 100); // 4 blocks
        let c = BlockCache::new(2);
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        c.get_or_read(&s, 0).unwrap(); // touch 0 → 1 is now LRU
        c.get_or_read(&s, 2).unwrap(); // evicts 1
        assert_eq!(c.len(), 2);
        c.get_or_read(&s, 0).unwrap(); // still warm
        assert_eq!(c.hits(), 2);
        c.get_or_read(&s, 1).unwrap(); // was evicted → miss
        assert_eq!(c.misses(), 4);
    }

    #[test]
    fn block_cache_zero_capacity_is_passthrough() {
        let s = block_store(200, 100);
        let c = BlockCache::new(0);
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 0).unwrap();
        assert_eq!((c.hits(), c.misses()), (0, 2));
        assert!(c.is_empty());
        // Nothing retained once callers drop their blocks.
        assert_eq!(c.resident(), 0);
    }

    #[test]
    fn residency_gauge_tracks_live_blocks_and_peak() {
        let s = block_store(400, 100);
        let c = BlockCache::new(1);
        let held = c.get_or_read(&s, 0).unwrap(); // in cache + held here
        assert_eq!(c.resident(), 1);
        c.get_or_read(&s, 1).unwrap(); // evicts 0 from cache; `held` keeps it alive
        assert_eq!(c.resident(), 2, "held block + cached block");
        assert!(c.peak_resident() >= 2);
        drop(held);
        assert_eq!(c.resident(), 1, "only the cached block remains");
        c.clear();
        assert_eq!(c.resident(), 0);
    }

    #[test]
    fn block_cache_keys_by_store_uid() {
        let s1 = block_store(200, 100);
        let s2 = block_store(200, 100);
        let c = BlockCache::new(8);
        c.get_or_read(&s1, 0).unwrap();
        c.get_or_read(&s2, 0).unwrap();
        assert_eq!(c.misses(), 2, "same block id of another store is distinct");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn concurrent_reads() {
        let c = std::sync::Arc::new(DistributedCache::new());
        c.put_scalar("k", 7.0);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        assert_eq!(c.get_scalar("k"), Some(7.0));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
