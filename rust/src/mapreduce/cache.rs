//! Task-visible caches.
//!
//! * [`DistributedCache`] — Hadoop's mechanism for shipping small read-only
//!   data (the paper stores V_init / V_winit and the `Flag` there) to every
//!   task. Modelled as a concurrent typed KV store; writes happen in the
//!   driver before job submission, tasks only read.
//! * [`BlockCache`] — a byte-budgeted LRU over decoded HDFS blocks, shared
//!   by all map slots of an engine. The streaming pipeline reads blocks
//!   *inside* the worker closure; this cache is what makes repeated
//!   iterations over the same store hit warm blocks instead of re-decoding
//!   — the paper's "efficient caching design". Capacity is a **byte
//!   budget** (skewed block sizes make a block-count capacity meaningless):
//!   each entry is accounted at its serialised block size and LRU entries
//!   are evicted until the retained bytes fit the budget. The cache also
//!   meters residency in blocks *and* bytes — how much decoded data is
//!   alive right now (cache + in-flight tasks + in-flight prefetch) and the
//!   high-water marks, which the engine and scale-harness tests pin to
//!   `budget + workers × max_block_bytes`.
//!
//! The prefetch path ([`BlockCache::prefetch`]) lets the engine pull a
//! worker's *next* queued block into the cache while the current block
//! computes, overlapping disk latency with compute. Prefetch reservations
//! are counted against the same byte budget (evicting LRU entries to make
//! room), so prefetching never grows the residency envelope.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::faults::{backoff_s, FaultPlan, FaultSite, Injected, MAX_READ_RETRIES};
use crate::hdfs::BlockStore;

/// One mebibyte — the unit block-cache budgets are usually expressed in.
pub const MIB: u64 = 1024 * 1024;

/// A cached value.
#[derive(Clone, Debug)]
pub enum CacheValue {
    Matrix(Matrix),
    Scalar(f64),
    Flag(bool),
    Text(String),
}

/// The cache itself. Cheap to share via `&` across tasks.
#[derive(Default)]
pub struct DistributedCache {
    entries: RwLock<HashMap<String, CacheValue>>,
}

impl DistributedCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&self, key: &str, value: CacheValue) {
        self.entries
            .write()
            .expect("cache poisoned")
            .insert(key.to_string(), value);
    }

    pub fn put_matrix(&self, key: &str, m: Matrix) {
        self.put(key, CacheValue::Matrix(m));
    }

    pub fn put_flag(&self, key: &str, b: bool) {
        self.put(key, CacheValue::Flag(b));
    }

    pub fn put_scalar(&self, key: &str, v: f64) {
        self.put(key, CacheValue::Scalar(v));
    }

    pub fn get_matrix(&self, key: &str) -> Option<Matrix> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Matrix(m)) => Some(m.clone()),
            _ => None,
        }
    }

    pub fn get_flag(&self, key: &str) -> Option<bool> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Flag(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_scalar(&self, key: &str) -> Option<f64> {
        match self.entries.read().expect("cache poisoned").get(key) {
            Some(CacheValue::Scalar(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.read().expect("cache poisoned").contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.entries.read().expect("cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialised footprint (models the per-task cache download cost).
    pub fn bytes(&self) -> u64 {
        self.entries
            .read()
            .expect("cache poisoned")
            .values()
            .map(|v| match v {
                CacheValue::Matrix(m) => (m.rows() * m.cols() * 4) as u64,
                CacheValue::Scalar(_) => 8,
                CacheValue::Flag(_) => 1,
                CacheValue::Text(s) => s.len() as u64,
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Block cache (byte-budgeted LRU over decoded HDFS blocks)
// ---------------------------------------------------------------------------

/// Live-block gauge shared between the cache and every outstanding
/// [`CachedBlock`]: decoded blocks currently alive anywhere (cache entries
/// + blocks held by in-flight map tasks + prefetch decodes), in blocks and
/// bytes, plus their high-water marks.
#[derive(Default)]
struct Residency {
    resident_blocks: AtomicUsize,
    peak_blocks: AtomicUsize,
    resident_bytes: AtomicU64,
    peak_bytes: AtomicU64,
}

/// One decoded block. Dropping the last `Arc<CachedBlock>` releases the
/// block's memory and decrements the residency gauges — the mechanism the
/// streaming-bound tests (`engine::tests`, `integration_streaming`)
/// observe.
pub struct CachedBlock {
    data: Matrix,
    bytes: u64,
    residency: Arc<Residency>,
}

impl CachedBlock {
    fn new(data: Matrix, bytes: u64, residency: Arc<Residency>) -> Self {
        let now = residency.resident_blocks.fetch_add(1, Ordering::SeqCst) + 1;
        residency.peak_blocks.fetch_max(now, Ordering::SeqCst);
        let now_b = residency.resident_bytes.fetch_add(bytes, Ordering::SeqCst) + bytes;
        residency.peak_bytes.fetch_max(now_b, Ordering::SeqCst);
        Self { data, bytes, residency }
    }

    /// The block's records.
    pub fn data(&self) -> &Matrix {
        &self.data
    }

    /// Serialised byte size this block is accounted at.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for CachedBlock {
    fn drop(&mut self) {
        self.residency.resident_blocks.fetch_sub(1, Ordering::SeqCst);
        self.residency.resident_bytes.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

/// Where a traced block read was served from — drives the engine's modelled
/// HDFS I/O accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadSource {
    /// Demand miss: the task decoded the block from the store on its
    /// critical path.
    Miss,
    /// Warm hit on a block a previous demand read left in the cache — no
    /// store I/O happened for this access.
    Cached,
    /// First demand touch of a block the prefetcher pulled in. The disk
    /// read did happen (and is charged), just off the task's critical path.
    Prefetched,
}

/// Keys are `(store uid, block id)` so one cache can serve several stores
/// without aliasing.
type BlockKey = (u64, usize);

/// One cache slot: the block plus its latest recency stamp.
struct LruEntry {
    block: Arc<CachedBlock>,
    /// Stamp of this entry's most recent touch; `order` occurrences with an
    /// older stamp are stale and skipped by eviction.
    stamp: u64,
}

struct LruState {
    entries: HashMap<BlockKey, LruEntry>,
    /// Recency queue, least-recent candidates at the front. Touches append
    /// `(key, stamp)` without removing the key's earlier occurrence — an
    /// O(1) "lazy invalidation" LRU: eviction pops stale pairs until it
    /// finds one whose stamp matches the live entry. Compacted when stale
    /// pairs dominate, so warm hit-heavy phases stay O(1) amortized
    /// instead of the linear rescan a `remove(position)` queue costs.
    order: VecDeque<(BlockKey, u64)>,
    /// Monotonic recency stamp source.
    next_stamp: u64,
    /// Bytes retained by `entries`.
    cached_bytes: u64,
    /// Keys inserted by the prefetcher and not yet served to a task.
    prefetched: HashSet<BlockKey>,
}

impl LruState {
    /// Stamp `key` as most-recently-used (entry must exist).
    fn touch(&mut self, key: BlockKey) {
        self.next_stamp += 1;
        let stamp = self.next_stamp;
        if let Some(e) = self.entries.get_mut(&key) {
            e.stamp = stamp;
        }
        self.order.push_back((key, stamp));
        // Bound stale growth: a long warm phase appends one pair per hit
        // without evicting any; rebuild once live pairs are the minority.
        if self.order.len() > 4 * self.entries.len().max(16) {
            let entries = &self.entries;
            self.order
                .retain(|(k, s)| entries.get(k).map(|e| e.stamp == *s).unwrap_or(false));
        }
    }
}

/// Shared byte-budgeted LRU cache of decoded blocks with hit/miss,
/// prefetch and residency metering. A budget of 0 disables caching (every
/// read is a pass-through miss, nothing is retained).
pub struct BlockCache {
    budget_bytes: u64,
    state: Mutex<LruState>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Completed prefetch inserts.
    prefetches: AtomicU64,
    /// Demand hits served by a prefetched block (first touch only).
    prefetch_hits: AtomicU64,
    /// Bytes reserved by in-flight prefetch decodes; counted against the
    /// budget by the eviction loop so cache + in-flight prefetch ≤ budget.
    prefetch_pending: AtomicU64,
    /// Bytes the prefetcher read from the store that no task ever consumed
    /// (entry evicted before first touch, or the decode lost a duplicate
    /// race). These reads really happened; the engine charges them to the
    /// job so modelled HDFS I/O counts every disk read exactly once.
    prefetch_wasted: AtomicU64,
    residency: Arc<Residency>,
    /// Chaos plan for the demand-read / prefetch sites. `None` in
    /// production: every fault check is a single `Option` match.
    faults: Option<Arc<FaultPlan>>,
    /// Transient-fault retries taken by demand reads (each also accrues a
    /// modelled backoff wait in `backoff_ns`).
    read_retries: AtomicU64,
    /// Demand reads that exhausted [`MAX_READ_RETRIES`] and surfaced an
    /// error — recovery gave up, the caller saw the failure.
    read_aborts: AtomicU64,
    /// Checksum-quarantine incidents: a demand read observed torn bytes
    /// and re-read the block from the store instead of serving them.
    quarantines: AtomicU64,
    /// Modelled retry-backoff accumulated by demand reads, in nanoseconds
    /// (an atomic stand-in for f64 seconds; the engine drains it into the
    /// SimClock's `backoff_s` cost class). Never actually slept.
    backoff_ns: AtomicU64,
    /// Prefetch reads that failed, real or injected. The prefetcher
    /// deliberately swallows the error (a failed warm-up must not kill the
    /// job) — this counter is its only visibility.
    prefetch_errors: AtomicU64,
}

impl BlockCache {
    /// Cache with a byte budget (0 disables caching).
    pub fn with_budget_bytes(budget_bytes: u64) -> Self {
        Self {
            budget_bytes,
            state: Mutex::new(LruState {
                entries: HashMap::new(),
                order: VecDeque::new(),
                next_stamp: 0,
                cached_bytes: 0,
                prefetched: HashSet::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            prefetches: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            prefetch_pending: AtomicU64::new(0),
            prefetch_wasted: AtomicU64::new(0),
            residency: Arc::new(Residency::default()),
            faults: None,
            read_retries: AtomicU64::new(0),
            read_aborts: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
            backoff_ns: AtomicU64::new(0),
            prefetch_errors: AtomicU64::new(0),
        }
    }

    /// Cache with a budget expressed in MiB.
    pub fn with_budget_mib(mib: usize) -> Self {
        Self::with_budget_bytes(mib as u64 * MIB)
    }

    /// Attach a chaos plan to the demand-read and prefetch sites.
    pub fn with_faults(mut self, faults: Option<Arc<FaultPlan>>) -> Self {
        self.faults = faults;
        self
    }

    /// Evict least-recently-used entries until retained bytes plus in-flight
    /// prefetch reservations fit the budget. Runs under the state lock.
    /// Stale recency pairs (superseded by a later touch of the same key)
    /// are discarded on the way.
    fn evict_over_budget(&self, st: &mut LruState) {
        let pending = self.prefetch_pending.load(Ordering::SeqCst);
        while st.cached_bytes + pending > self.budget_bytes {
            let Some((key, stamp)) = st.order.pop_front() else { break };
            let live = st.entries.get(&key).map(|e| e.stamp) == Some(stamp);
            if !live {
                continue; // stale pair; the key was re-touched or is gone
            }
            if let Some(e) = st.entries.remove(&key) {
                st.cached_bytes -= e.block.bytes();
                if st.prefetched.remove(&key) {
                    // Read from disk by the prefetcher, never consumed.
                    self.prefetch_wasted.fetch_add(e.block.bytes(), Ordering::Relaxed);
                }
            }
        }
    }

    /// Fetch a block through the cache: warm hit returns the shared decoded
    /// block; a miss decodes from the store (outside the lock, so workers
    /// fetching different blocks decode in parallel) and inserts it,
    /// evicting least-recently-used entries beyond the byte budget.
    ///
    /// A concurrent duplicate miss of the same block decodes twice and the
    /// later insert is dropped — benign, and still within the
    /// `budget + workers × max_block_bytes` residency bound because the
    /// duplicate is held by exactly one in-flight task.
    pub fn get_or_read(&self, store: &BlockStore, id: usize) -> Result<Arc<CachedBlock>> {
        Ok(self.get_or_read_traced(store, id)?.0)
    }

    /// [`Self::get_or_read`] that also reports where the block came from
    /// (see [`ReadSource`]) so the engine can charge modelled HDFS reads
    /// only for bytes that actually moved this job.
    pub fn get_or_read_traced(
        &self,
        store: &BlockStore,
        id: usize,
    ) -> Result<(Arc<CachedBlock>, ReadSource)> {
        let key: BlockKey = (store.uid(), id);
        if self.budget_bytes > 0 {
            let mut st = self.state.lock().expect("block cache poisoned");
            if let Some(hit) = st.entries.get(&key).map(|e| Arc::clone(&e.block)) {
                st.touch(key);
                let was_prefetched = st.prefetched.remove(&key);
                drop(st);
                self.hits.fetch_add(1, Ordering::Relaxed);
                if was_prefetched {
                    self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((hit, ReadSource::Prefetched));
                }
                return Ok((hit, ReadSource::Cached));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let data = self.read_recovered(store, id)?;
        let bytes = store.blocks()[id].bytes;
        let block = Arc::new(CachedBlock::new(data, bytes, Arc::clone(&self.residency)));
        if self.budget_bytes > 0 {
            let mut st = self.state.lock().expect("block cache poisoned");
            if !st.entries.contains_key(&key) {
                st.cached_bytes += bytes;
                st.entries.insert(key, LruEntry { block: Arc::clone(&block), stamp: 0 });
                st.touch(key);
                self.evict_over_budget(&mut st);
            }
            // A concurrent prefetch insert beat our decode: leave its
            // `prefetched` flag in place. Both reads really happened and
            // both are charged exactly once — this one as a Miss now, the
            // prefetcher's when its entry is first touched (Prefetched) or
            // evicted unconsumed (wasted).
        }
        Ok((block, ReadSource::Miss))
    }

    /// Demand-read a block from the store with bounded fault recovery.
    ///
    /// Injected transient errors retry with exponential backoff — modelled,
    /// never slept: each retry accrues [`backoff_s`] into `backoff_ns` for
    /// the engine to charge to the SimClock. Injected corruption is a
    /// checksum quarantine: the torn bytes are discarded and the block is
    /// re-read from the store (never served). After [`MAX_READ_RETRIES`]
    /// consecutive failed attempts the read aborts with the failing block
    /// id in the message. Real store errors are not retried (the store is
    /// authoritative about its own failures) but are tagged with the block
    /// id so a dying disk names the block it died on.
    fn read_recovered(&self, store: &BlockStore, id: usize) -> Result<Matrix> {
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            let injected = self.faults.as_ref().and_then(|p| p.check(FaultSite::BlockRead));
            match injected {
                None => {
                    return store
                        .read_block(id)
                        .map_err(|e| Error::BlockStore(format!("block {id}: {e}")));
                }
                Some(Injected::Corrupt) => {
                    // Torn bytes detected on arrival: quarantine them and
                    // fall through to the bounded re-read below.
                    self.quarantines.fetch_add(1, Ordering::Relaxed);
                }
                Some(_) => {
                    // Transient read failure: pay a modelled backoff wait,
                    // then fall through to the bounded retry below.
                    if attempt < MAX_READ_RETRIES {
                        self.read_retries.fetch_add(1, Ordering::Relaxed);
                        let ns = (backoff_s(attempt) * 1e9).round() as u64;
                        self.backoff_ns.fetch_add(ns, Ordering::Relaxed);
                    }
                }
            }
            if attempt >= MAX_READ_RETRIES {
                self.read_aborts.fetch_add(1, Ordering::Relaxed);
                return Err(Error::BlockStore(format!(
                    "block {id}: read failed after {MAX_READ_RETRIES} attempts \
                     (fault persisted through retries)"
                )));
            }
        }
    }

    /// Pull a block into the cache ahead of demand, evicting LRU entries to
    /// make room. Returns `Ok(true)` when the block was decoded and
    /// inserted; `Ok(false)` when it was already cached, caching is
    /// disabled, or the block cannot fit the budget. The reservation keeps
    /// `cached bytes + in-flight prefetch ≤ budget` throughout, so prefetch
    /// never grows the residency envelope beyond what the budget allows.
    pub fn prefetch(&self, store: &BlockStore, id: usize) -> Result<bool> {
        if self.budget_bytes == 0 || id >= store.num_blocks() {
            return Ok(false);
        }
        let key: BlockKey = (store.uid(), id);
        let bytes = store.blocks()[id].bytes;
        {
            let mut st = self.state.lock().expect("block cache poisoned");
            if st.entries.contains_key(&key) {
                return Ok(false);
            }
            if bytes + self.prefetch_pending.load(Ordering::SeqCst) > self.budget_bytes {
                // A block this size can never fit alongside in-flight
                // reservations; let the demand path stream it instead.
                return Ok(false);
            }
            self.prefetch_pending.fetch_add(bytes, Ordering::SeqCst);
            // Make room now, while we still hold the lock: the decode below
            // runs unlocked and demand inserts must keep seeing a budget
            // that accounts for this reservation.
            self.evict_over_budget(&mut st);
        }
        if let Some(fault) = self.faults.as_ref().and_then(|p| p.check(FaultSite::Prefetch)) {
            // A prefetch is pure warm-up: no retry, no backoff — the demand
            // path will stream the block if it's really needed. Roll back
            // the reservation and surface a counted error.
            self.prefetch_pending.fetch_sub(bytes, Ordering::SeqCst);
            self.prefetch_errors.fetch_add(1, Ordering::Relaxed);
            return Err(Error::BlockStore(format!("block {id}: injected prefetch fault {fault:?}")));
        }
        let data = match store.read_block(id) {
            Ok(d) => d,
            Err(e) => {
                self.prefetch_pending.fetch_sub(bytes, Ordering::SeqCst);
                self.prefetch_errors.fetch_add(1, Ordering::Relaxed);
                return Err(Error::BlockStore(format!("block {id}: {e}")));
            }
        };
        let block = Arc::new(CachedBlock::new(data, bytes, Arc::clone(&self.residency)));
        let mut st = self.state.lock().expect("block cache poisoned");
        self.prefetch_pending.fetch_sub(bytes, Ordering::SeqCst);
        if st.entries.contains_key(&key) {
            // A demand miss beat us to it; drop our duplicate decode. The
            // read still happened — account it so the engine charges it.
            self.prefetch_wasted.fetch_add(bytes, Ordering::Relaxed);
            return Ok(false);
        }
        st.cached_bytes += bytes;
        st.entries.insert(key, LruEntry { block, stamp: 0 });
        st.touch(key);
        st.prefetched.insert(key);
        self.evict_over_budget(&mut st);
        drop(st);
        self.prefetches.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Byte budget (0 = caching disabled).
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Unreserved budget headroom right now: budget − retained bytes −
    /// in-flight prefetch reservations. Drives the engine's adaptive
    /// prefetch depth (a second-block prefetch is only hinted when at
    /// least two max-size blocks of slack remain).
    pub fn budget_slack(&self) -> u64 {
        let cached = self.state.lock().expect("block cache poisoned").cached_bytes;
        self.budget_bytes
            .saturating_sub(cached + self.prefetch_pending.load(Ordering::SeqCst))
    }

    /// Bytes currently retained by the cache itself.
    pub fn cached_bytes(&self) -> u64 {
        self.state.lock().expect("block cache poisoned").cached_bytes
    }

    /// Blocks currently retained by the cache itself.
    pub fn len(&self) -> usize {
        self.state.lock().expect("block cache poisoned").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Completed prefetch inserts since construction.
    pub fn prefetches(&self) -> u64 {
        self.prefetches.load(Ordering::Relaxed)
    }

    /// Demand hits served by a prefetched block (first touch only).
    pub fn prefetch_hits(&self) -> u64 {
        self.prefetch_hits.load(Ordering::Relaxed)
    }

    /// Bytes the prefetcher read that no task ever consumed (evicted before
    /// first touch, duplicate race, or dropped by `clear()`); the engine
    /// charges these so modelled I/O counts every real read exactly once.
    pub fn prefetch_wasted_bytes(&self) -> u64 {
        self.prefetch_wasted.load(Ordering::Relaxed)
    }

    /// Transient-fault retries taken by demand reads.
    pub fn read_retries(&self) -> u64 {
        self.read_retries.load(Ordering::Relaxed)
    }

    /// Demand reads that exhausted the retry budget and surfaced an error.
    pub fn read_aborts(&self) -> u64 {
        self.read_aborts.load(Ordering::Relaxed)
    }

    /// Checksum-quarantine incidents (torn bytes discarded and re-read).
    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Modelled retry-backoff accumulated by demand reads, in seconds.
    pub fn backoff_seconds(&self) -> f64 {
        self.backoff_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Prefetch reads that failed (real or injected) and were swallowed.
    pub fn prefetch_errors(&self) -> u64 {
        self.prefetch_errors.load(Ordering::Relaxed)
    }

    /// Decoded blocks alive right now (cache + in-flight tasks + prefetch).
    pub fn resident(&self) -> usize {
        self.residency.resident_blocks.load(Ordering::SeqCst)
    }

    /// High-water mark of [`Self::resident`].
    pub fn peak_resident(&self) -> usize {
        self.residency.peak_blocks.load(Ordering::SeqCst)
    }

    /// Decoded bytes alive right now (cache + in-flight tasks + prefetch).
    pub fn resident_bytes(&self) -> u64 {
        self.residency.resident_bytes.load(Ordering::SeqCst)
    }

    /// High-water mark of [`Self::resident_bytes`].
    pub fn peak_resident_bytes(&self) -> u64 {
        self.residency.peak_bytes.load(Ordering::SeqCst)
    }

    /// Drop every retained block (in-flight holders keep theirs alive) and
    /// reset the peak meters to the current residency, so a long-lived
    /// cache reports per-job peaks when cleared between jobs rather than
    /// the all-time high-water mark.
    ///
    /// This drops **blocks only**. Iteration-resident sessions that just
    /// want per-iteration peak metering must call
    /// [`Self::reset_job_meters`] instead — clearing decoded blocks
    /// between iterations of one convergence loop would throw away exactly
    /// the warm data the session exists to keep. Sticky per-block *state*
    /// (the pruning slabs) lives outside this cache entirely
    /// (`crate::mapreduce::session::StateSlab`), so neither call can ever
    /// invalidate bounds the pruning path still holds.
    pub fn clear(&self) {
        let mut st = self.state.lock().expect("block cache poisoned");
        // Flagged-but-unconsumed prefetch reads die here; account them.
        let dropped_prefetched: u64 = st
            .prefetched
            .iter()
            .filter_map(|k| st.entries.get(k).map(|e| e.block.bytes()))
            .sum();
        if dropped_prefetched > 0 {
            self.prefetch_wasted.fetch_add(dropped_prefetched, Ordering::Relaxed);
        }
        st.entries.clear();
        st.order.clear();
        st.prefetched.clear();
        st.cached_bytes = 0;
        drop(st); // dropping the Arcs above decremented the gauges
        self.reset_job_meters();
    }

    /// Reset the per-job peak meters to the current residency **without**
    /// dropping any cached block — the between-iterations reset of an
    /// iteration-resident session, which needs job-scoped peaks while the
    /// warm blocks (and the session's sticky slabs, which live outside
    /// this cache) stay alive. Split out of [`Self::clear`] so per-job
    /// meter lifecycle and block lifetime can never be conflated again.
    pub fn reset_job_meters(&self) {
        self.residency
            .peak_blocks
            .store(self.residency.resident_blocks.load(Ordering::SeqCst), Ordering::SeqCst);
        self.residency
            .peak_bytes
            .store(self.residency.resident_bytes.load(Ordering::SeqCst), Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_roundtrips() {
        let c = DistributedCache::new();
        c.put_matrix("v_init", Matrix::from_rows(&[vec![1.0, 2.0]]));
        c.put_flag("flag", true);
        c.put_scalar("m", 2.0);
        assert_eq!(c.get_matrix("v_init").unwrap().row(0), &[1.0, 2.0]);
        assert_eq!(c.get_flag("flag"), Some(true));
        assert_eq!(c.get_scalar("m"), Some(2.0));
        assert_eq!(c.len(), 3);
        assert!(c.bytes() >= 8 + 8 + 1);
    }

    #[test]
    fn wrong_type_returns_none() {
        let c = DistributedCache::new();
        c.put_flag("x", false);
        assert!(c.get_matrix("x").is_none());
        assert!(c.get_scalar("x").is_none());
        assert!(c.get_flag("missing").is_none());
    }

    fn block_store(n: usize, block: usize) -> BlockStore {
        let d = crate::data::synth::blobs(n, 3, 2, 0.4, 7);
        BlockStore::in_memory("t", &d.features, block, 2).unwrap()
    }

    /// Budget sized to hold exactly `blocks` equal-size blocks of `s`.
    fn budget_for(s: &BlockStore, blocks: u64) -> u64 {
        s.blocks()[0].bytes * blocks
    }

    #[test]
    fn block_cache_hits_after_first_read() {
        let s = block_store(400, 100); // 4 equal blocks
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8));
        let a = c.get_or_read(&s, 2).unwrap();
        let b = c.get_or_read(&s, 2).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "warm hit must return the shared block");
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert_eq!(c.len(), 1);
        assert_eq!(c.cached_bytes(), s.blocks()[2].bytes);
        assert_eq!(a.data().rows(), 100);
    }

    #[test]
    fn block_cache_evicts_least_recently_used_by_bytes() {
        let s = block_store(400, 100); // 4 equal blocks
        let c = BlockCache::with_budget_bytes(budget_for(&s, 2));
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        c.get_or_read(&s, 0).unwrap(); // touch 0 → 1 is now LRU
        c.get_or_read(&s, 2).unwrap(); // evicts 1
        assert_eq!(c.len(), 2);
        assert!(c.cached_bytes() <= c.budget_bytes());
        c.get_or_read(&s, 0).unwrap(); // still warm
        assert_eq!(c.hits(), 2);
        c.get_or_read(&s, 1).unwrap(); // was evicted → miss
        assert_eq!(c.misses(), 4);
    }

    #[test]
    fn budget_below_one_block_retains_nothing() {
        let s = block_store(400, 100);
        let c = BlockCache::with_budget_bytes(s.blocks()[0].bytes - 1);
        c.get_or_read(&s, 0).unwrap();
        assert!(c.is_empty(), "a block above the whole budget must not stick");
        assert_eq!(c.cached_bytes(), 0);
        c.get_or_read(&s, 0).unwrap();
        assert_eq!((c.hits(), c.misses()), (0, 2));
    }

    #[test]
    fn block_cache_zero_budget_is_passthrough() {
        let s = block_store(200, 100);
        let c = BlockCache::with_budget_bytes(0);
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 0).unwrap();
        assert_eq!((c.hits(), c.misses()), (0, 2));
        assert!(c.is_empty());
        // Nothing retained once callers drop their blocks.
        assert_eq!(c.resident(), 0);
        assert_eq!(c.resident_bytes(), 0);
        // Prefetch is a no-op without a budget.
        assert!(!c.prefetch(&s, 1).unwrap());
    }

    #[test]
    fn residency_gauge_tracks_live_blocks_and_bytes() {
        let s = block_store(400, 100);
        let bytes = s.blocks()[0].bytes;
        let c = BlockCache::with_budget_bytes(bytes); // room for one block
        let held = c.get_or_read(&s, 0).unwrap(); // in cache + held here
        assert_eq!(c.resident(), 1);
        assert_eq!(c.resident_bytes(), bytes);
        c.get_or_read(&s, 1).unwrap(); // evicts 0 from cache; `held` keeps it alive
        assert_eq!(c.resident(), 2, "held block + cached block");
        assert_eq!(c.resident_bytes(), 2 * bytes);
        assert!(c.peak_resident() >= 2);
        assert!(c.peak_resident_bytes() >= 2 * bytes);
        drop(held);
        assert_eq!(c.resident(), 1, "only the cached block remains");
        assert_eq!(c.resident_bytes(), bytes);
        c.clear();
        assert_eq!(c.resident(), 0);
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn clear_resets_peak_meters_to_current_residency() {
        let s = block_store(400, 100);
        let bytes = s.blocks()[0].bytes;
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8));
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        assert!(c.peak_resident() >= 2);
        let held = c.get_or_read(&s, 2).unwrap();
        c.clear();
        // `held` is still alive, so the per-job meters restart from it —
        // not from zero, and not from the previous job's high-water mark.
        assert_eq!(c.resident(), 1);
        assert_eq!(c.peak_resident(), 1);
        assert_eq!(c.peak_resident_bytes(), bytes);
        drop(held);
        c.clear();
        assert_eq!(c.peak_resident(), 0);
        assert_eq!(c.peak_resident_bytes(), 0);
    }

    #[test]
    fn prefetch_warms_and_first_touch_counts_as_prefetch_hit() {
        let s = block_store(400, 100);
        let c = BlockCache::with_budget_bytes(budget_for(&s, 4));
        assert!(c.prefetch(&s, 1).unwrap());
        assert_eq!(c.prefetches(), 1);
        assert_eq!(c.misses(), 0, "prefetch is not a demand miss");
        let (_, src) = c.get_or_read_traced(&s, 1).unwrap();
        assert_eq!(src, ReadSource::Prefetched);
        assert_eq!(c.prefetch_hits(), 1);
        // Second touch is an ordinary warm hit.
        let (_, src) = c.get_or_read_traced(&s, 1).unwrap();
        assert_eq!(src, ReadSource::Cached);
        assert_eq!(c.prefetch_hits(), 1);
        // Prefetching an already-cached block is a no-op.
        assert!(!c.prefetch(&s, 1).unwrap());
    }

    #[test]
    fn prefetch_evicts_lru_to_make_room_within_budget() {
        let s = block_store(400, 100); // 4 equal blocks
        let c = BlockCache::with_budget_bytes(budget_for(&s, 2));
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        // Cache is at budget; prefetch must evict block 0 (LRU), not fail.
        assert!(c.prefetch(&s, 2).unwrap());
        assert!(c.cached_bytes() <= c.budget_bytes());
        let (_, src) = c.get_or_read_traced(&s, 2).unwrap();
        assert_eq!(src, ReadSource::Prefetched);
        let (_, src) = c.get_or_read_traced(&s, 0).unwrap();
        assert_eq!(src, ReadSource::Miss, "LRU block 0 was evicted for the prefetch");
    }

    #[test]
    fn unconsumed_prefetch_reads_are_metered_as_wasted() {
        let s = block_store(400, 100); // 4 equal blocks
        let bytes = s.blocks()[0].bytes;
        let c = BlockCache::with_budget_bytes(2 * bytes);
        assert!(c.prefetch(&s, 3).unwrap());
        assert_eq!(c.prefetch_wasted_bytes(), 0);
        // Two demand reads evict the never-touched prefetched block 3.
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        assert_eq!(c.prefetch_wasted_bytes(), bytes, "evicted-unconsumed read not metered");
        // A consumed prefetch is never counted as wasted.
        assert!(c.prefetch(&s, 2).unwrap());
        let (_, src) = c.get_or_read_traced(&s, 2).unwrap();
        assert_eq!(src, ReadSource::Prefetched);
        c.clear();
        assert_eq!(c.prefetch_wasted_bytes(), bytes);
        // But one dropped by clear() while still flagged is.
        assert!(c.prefetch(&s, 0).unwrap());
        c.clear();
        assert_eq!(c.prefetch_wasted_bytes(), 2 * bytes);
    }

    #[test]
    fn reset_job_meters_keeps_blocks_warm() {
        let s = block_store(400, 100);
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8));
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        assert!(c.peak_resident() >= 2);
        c.reset_job_meters();
        // Peaks restart from current residency; nothing was dropped.
        assert_eq!(c.len(), 2, "meter reset must not drop blocks");
        assert_eq!(c.peak_resident(), 2);
        assert_eq!(c.peak_resident_bytes(), c.resident_bytes());
        let (_, src) = c.get_or_read_traced(&s, 0).unwrap();
        assert_eq!(src, ReadSource::Cached, "block evaporated across a meter reset");
    }

    #[test]
    fn budget_slack_tracks_retained_bytes() {
        let s = block_store(400, 100); // 4 equal blocks
        let bytes = s.blocks()[0].bytes;
        let c = BlockCache::with_budget_bytes(3 * bytes);
        assert_eq!(c.budget_slack(), 3 * bytes);
        c.get_or_read(&s, 0).unwrap();
        assert_eq!(c.budget_slack(), 2 * bytes);
        c.get_or_read(&s, 1).unwrap();
        c.get_or_read(&s, 2).unwrap();
        assert_eq!(c.budget_slack(), 0);
        c.clear();
        assert_eq!(c.budget_slack(), 3 * bytes);
        // Zero-budget cache has no slack by definition.
        let z = BlockCache::with_budget_bytes(0);
        assert_eq!(z.budget_slack(), 0);
    }

    #[test]
    fn lru_order_survives_heavy_touching() {
        // Hammer warm hits so the lazy recency queue compacts several
        // times, then check eviction still removes the true LRU entry.
        let s = block_store(400, 100); // 4 equal blocks
        let c = BlockCache::with_budget_bytes(budget_for(&s, 3));
        c.get_or_read(&s, 0).unwrap();
        c.get_or_read(&s, 1).unwrap();
        c.get_or_read(&s, 2).unwrap();
        for _ in 0..500 {
            c.get_or_read(&s, 1).unwrap();
            c.get_or_read(&s, 2).unwrap();
        }
        // 0 is the LRU despite 1000 stale pairs behind it.
        c.get_or_read(&s, 3).unwrap(); // evicts 0
        assert_eq!(c.len(), 3);
        let (_, src) = c.get_or_read_traced(&s, 1).unwrap();
        assert_eq!(src, ReadSource::Cached, "recently touched block was evicted");
        let (_, src) = c.get_or_read_traced(&s, 0).unwrap();
        assert_eq!(src, ReadSource::Miss, "LRU block survived eviction");
    }

    #[test]
    fn transient_read_fault_retries_with_backoff_and_serves_same_bytes() {
        use crate::faults::{backoff_s, FaultPlan, FaultSite};
        let s = block_store(400, 100);
        let clean = BlockCache::with_budget_bytes(budget_for(&s, 8));
        let want = clean.get_or_read(&s, 1).unwrap().data().clone();
        // Trip exactly one transient fault at the first BlockRead op.
        let plan = FaultPlan::tripping(7, FaultSite::BlockRead, 0);
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8)).with_faults(Some(plan));
        let got = c.get_or_read(&s, 1).unwrap();
        assert_eq!(*got.data(), want, "recovered read must be bitwise clean");
        assert_eq!(c.read_retries(), 1);
        assert_eq!(c.read_aborts(), 0);
        assert!((c.backoff_seconds() - backoff_s(1)).abs() < 1e-12);
        // Warm hit afterwards: no further ops at the fault site needed.
        c.get_or_read(&s, 1).unwrap();
        assert_eq!(c.read_retries(), 1);
    }

    #[test]
    fn persistent_read_fault_aborts_with_block_id() {
        use crate::faults::{FaultPlan, FaultSite, MAX_READ_RETRIES};
        let s = block_store(400, 100);
        let plan = FaultPlan::for_site(11, FaultSite::BlockRead, 1.0, 0.0);
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8)).with_faults(Some(plan));
        let err = c.get_or_read(&s, 3).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("block 3"), "error must name the failing block: {msg}");
        assert_eq!(c.read_aborts(), 1);
        assert_eq!(c.read_retries(), u64::from(MAX_READ_RETRIES) - 1);
        // The cache stays usable: a clean op clears (rate draws are per-op,
        // but rate 1.0 never clears — so only counters moved, no poison).
        assert!(c.is_empty());
    }

    #[test]
    fn corrupt_read_quarantines_and_refetches() {
        use crate::faults::{FaultPlan, FaultSite};
        let s = block_store(400, 100);
        let clean = BlockCache::with_budget_bytes(budget_for(&s, 8));
        let want = clean.get_or_read(&s, 0).unwrap();
        // Trip exactly one corruption at the first demand read.
        let plan = FaultPlan::tripping_corrupt(21, FaultSite::BlockRead, 0);
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8)).with_faults(Some(plan));
        let got = c.get_or_read(&s, 0).unwrap();
        assert_eq!(got.data(), want.data(), "quarantined block must re-read clean");
        assert_eq!(c.quarantines(), 1);
        assert_eq!(c.read_aborts(), 0);
        assert_eq!(c.read_retries(), 0, "a quarantine re-read is not a transient retry");
        assert_eq!(c.backoff_seconds(), 0.0, "quarantine re-reads are immediate");
    }

    #[test]
    fn prefetch_fault_is_swallowed_but_counted() {
        use crate::faults::{FaultPlan, FaultSite};
        let s = block_store(400, 100);
        let plan = FaultPlan::for_site(5, FaultSite::Prefetch, 1.0, 0.0);
        let c = BlockCache::with_budget_bytes(budget_for(&s, 8)).with_faults(Some(plan));
        let err = c.prefetch(&s, 2).unwrap_err();
        assert!(err.to_string().contains("block 2"), "{err}");
        assert_eq!(c.prefetch_errors(), 1);
        assert_eq!(c.prefetches(), 0);
        // Reservation was rolled back: demand path still works and the
        // budget is intact.
        let got = c.get_or_read(&s, 2);
        assert!(got.is_ok());
        assert_eq!(c.budget_slack(), budget_for(&s, 8) - s.blocks()[2].bytes);
    }

    #[test]
    fn block_cache_keys_by_store_uid() {
        let s1 = block_store(200, 100);
        let s2 = block_store(200, 100);
        let c = BlockCache::with_budget_bytes(budget_for(&s1, 8));
        c.get_or_read(&s1, 0).unwrap();
        c.get_or_read(&s2, 0).unwrap();
        assert_eq!(c.misses(), 2, "same block id of another store is distinct");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn concurrent_reads() {
        let c = std::sync::Arc::new(DistributedCache::new());
        c.put_scalar("k", 7.0);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        assert_eq!(c.get_scalar("k"), Some(7.0));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
