//! Subsample sizing (paper Eqs. 3–4) and reservoir sampling over record
//! streams — the driver job's machinery for picking R_x.

use crate::prng::Pcg;

/// Thompson's formula (Eq. 3): smallest sample size for estimating
/// multinomial proportions with `mu` classes and max absolute error `d`
/// at confidence level z (upper α/(2µ) normal quantile).
///
/// `Smallest n = max_µ z² (1/µ)(1 − 1/µ) / d²` — the max over µ is attained
/// at the worst-case split; we evaluate at the given µ as the paper does.
pub fn thompson_sample_size(mu: usize, d: f64, z: f64) -> usize {
    assert!(mu >= 2, "need at least two classes");
    assert!(d > 0.0 && z > 0.0);
    let p = 1.0 / mu as f64;
    let n = z * z * p * (1.0 - p) / (d * d);
    n.ceil() as usize
}

/// Parker–Hall formula (Eq. 4): λ = v(α)·c² / r², the subsample size used
/// when per-class proportions are unknown.
///
/// * `c` — number of clusters;
/// * `r` — relative difference between class proportions;
/// * `v_alpha` — Thompson's tabulated v(α) (1.27359 for α = 0.05).
///
/// Paper's example: c=5, r=0.10, α=0.05 → 3184 records.
pub fn parker_hall_sample_size(c: usize, r: f64, v_alpha: f64) -> usize {
    assert!(c >= 1 && r > 0.0 && v_alpha > 0.0);
    let lambda = v_alpha * (c * c) as f64 / (r * r);
    lambda.ceil() as usize
}

/// Reservoir sampling (Algorithm R): uniform k-subset of a stream of
/// unknown length. Returns the sampled items.
pub fn reservoir_sample<T: Clone>(
    stream: impl Iterator<Item = T>,
    k: usize,
    rng: &mut Pcg,
) -> Vec<T> {
    let mut reservoir: Vec<T> = Vec::with_capacity(k);
    for (i, item) in stream.enumerate() {
        if i < k {
            reservoir.push(item);
        } else {
            let j = rng.next_index(i + 1);
            if j < k {
                reservoir[j] = item;
            }
        }
    }
    reservoir
}

/// Reservoir sampling of row indices [0, n) without materialising them.
pub fn reservoir_indices(n: usize, k: usize, rng: &mut Pcg) -> Vec<usize> {
    reservoir_sample(0..n, k.min(n), rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parker_hall_matches_paper_example() {
        // "five clusters and the relative difference is 0.10 … 3184 records"
        let n = parker_hall_sample_size(5, 0.10, 1.27359);
        assert_eq!(n, 3184);
    }

    #[test]
    fn thompson_reasonable_magnitudes() {
        // 2 classes, d=0.05, z=1.96 → n = 1.96²·0.25/0.0025 ≈ 385.
        let n = thompson_sample_size(2, 0.05, 1.96);
        assert_eq!(n, 385);
        // Tighter d → larger sample.
        assert!(thompson_sample_size(2, 0.01, 1.96) > n);
    }

    #[test]
    fn reservoir_uniformity() {
        let mut rng = Pcg::new(3);
        let mut counts = vec![0usize; 20];
        for _ in 0..20_000 {
            for &i in &reservoir_indices(20, 5, &mut rng) {
                counts[i] += 1;
            }
        }
        // Each index expected 20_000·(5/20) = 5_000; allow ±6%.
        for &c in &counts {
            assert!((4_700..5_300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn reservoir_small_stream_returns_all() {
        let mut rng = Pcg::new(4);
        let mut s = reservoir_indices(3, 10, &mut rng);
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2]);
    }
}
