//! Dataset substrate: matrix type, codecs, normalisation, the embedded and
//! synthetic datasets of the paper's evaluation (DESIGN.md §3 documents each
//! substitution).

pub mod builtin;
pub mod csv;
pub mod matrix;
pub mod normalize;
pub mod synth;

pub use matrix::Matrix;

/// A (possibly labelled) dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human name used in reports ("SUSY-like", "Iris", ...).
    pub name: String,
    /// N × d feature matrix.
    pub features: Matrix,
    /// Ground-truth class per record, when known (for confusion accuracy).
    pub labels: Option<Vec<usize>>,
    /// Number of distinct classes in `labels`.
    pub n_classes: usize,
}

impl Dataset {
    /// Build an unlabelled dataset.
    pub fn unlabelled(name: impl Into<String>, features: Matrix) -> Self {
        Self { name: name.into(), features, labels: None, n_classes: 0 }
    }

    /// Build a labelled dataset; panics if lengths disagree.
    pub fn labelled(name: impl Into<String>, features: Matrix, labels: Vec<usize>) -> Self {
        assert_eq!(features.rows(), labels.len(), "labels must match rows");
        let n_classes = labels.iter().copied().max().map_or(0, |m| m + 1);
        Self { name: name.into(), features, labels: Some(labels), n_classes }
    }

    pub fn rows(&self) -> usize {
        self.features.rows()
    }

    pub fn dims(&self) -> usize {
        self.features.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labelled_counts_classes() {
        let m = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let d = Dataset::labelled("t", m, vec![0, 2, 1]);
        assert_eq!(d.n_classes, 3);
        assert_eq!(d.rows(), 3);
        assert_eq!(d.dims(), 1);
    }

    #[test]
    #[should_panic(expected = "labels must match rows")]
    fn labelled_length_mismatch_panics() {
        let m = Matrix::from_rows(&[vec![0.0]]);
        let _ = Dataset::labelled("t", m, vec![0, 1]);
    }
}
