//! Synthetic dataset generators standing in for the paper's multi-gigabyte
//! UCI downloads (SUSY, HIGGS, KDD99) and Pima (DESIGN.md §3).
//!
//! Each family is a Gaussian mixture whose shape matches the original:
//! feature count, class count, class balance and class overlap. FCM's cost
//! is a function of (N, d, C, iterations), and its *quality* numbers in the
//! paper (Table 7) are driven by class overlap — e.g. SUSY/HIGGS score ~50%
//! 2-class accuracy because signal/background overlap heavily, which the
//! generators reproduce with strongly overlapping components.

use crate::data::{Dataset, Matrix};
use crate::prng::Pcg;

/// A Gaussian mixture component: per-dimension mean and standard deviation.
#[derive(Clone, Debug)]
pub struct Component {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    /// Relative sampling weight (class prior).
    pub weight: f64,
    /// Class label emitted for records from this component.
    pub label: usize,
}

/// Draw `n` records from a mixture; returns features + labels.
pub fn gaussian_mixture(
    n: usize,
    components: &[Component],
    seed: u64,
    name: &str,
) -> Dataset {
    assert!(!components.is_empty());
    let d = components[0].mean.len();
    for c in components {
        assert_eq!(c.mean.len(), d, "component dims disagree");
        assert_eq!(c.std.len(), d, "component dims disagree");
    }
    let weights: Vec<f64> = components.iter().map(|c| c.weight).collect();
    let mut rng = Pcg::new(seed);
    let mut features = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let k = rng.weighted_index(&weights);
        let comp = &components[k];
        let row = features.row_mut(i);
        for j in 0..d {
            row[j] = rng.normal_with(comp.mean[j], comp.std[j]) as f32;
        }
        labels.push(comp.label);
    }
    Dataset::labelled(name, features, labels)
}

/// Deterministic per-dimension means on a ring: class centers separated by
/// `sep` in a d-dimensional space, derived from a seed.
fn spread_means(d: usize, k: usize, sep: f64, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg::new(seed ^ 0x5EED);
    (0..k)
        .map(|_| (0..d).map(|_| rng.normal() * sep).collect())
        .collect()
}

/// Physics-like generator shared by the SUSY/HIGGS stand-ins.
///
/// Two properties of the real datasets matter for Tables 7–8:
///
/// * **classes are cluster-invisible** — 2-means/2-FCM cannot separate
///   signal from background (the paper reports exactly 50.0% confusion
///   accuracy for both methods). We reproduce that by carrying the class
///   label in the *sign* of one isotropic feature (a genuine function of
///   the features, like a physics discriminant) while keeping both class
///   conditionals identical as point clouds — no centroid-based method can
///   see it.
/// * **weak but real cluster structure exists** — FCM finds a balanced
///   split along the dominant variance directions with a small positive
///   silhouette (paper Table 8: ≈0.063). We reproduce that with an
///   anisotropic cloud (two stretched features).
fn physics_like(n: usize, d: usize, seed: u64, label_flip: f64, name: &str) -> Dataset {
    let mut rng = Pcg::new(seed ^ 0x9197);
    let mut features = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let row = features.row_mut(i);
        for j in 0..d {
            let std = match j {
                0 => 2.0, // stretched: where 2-clustering splits
                1 => 1.4,
                _ => 1.0,
            };
            row[j] = rng.normal_with(0.0, std) as f32;
        }
        // Class = sign of an isotropic feature, with label noise — strong
        // class signal, orthogonal to the cluster structure.
        let mut label = usize::from(row[2] > 0.0);
        if rng.next_f64() < label_flip {
            label = 1 - label;
        }
        labels.push(label);
    }
    Dataset::labelled(name, features, labels)
}

/// SUSY-like: 18 features, 2 classes; clusters carry no class signal, as in
/// the real data (paper Table 7: 50.0%).
pub fn susy_like(n: usize, seed: u64) -> Dataset {
    physics_like(n, 18, seed, 0.10, "SUSY-like")
}

/// HIGGS-like: 28 features, 2 classes, same class/cluster decoupling.
pub fn higgs_like(n: usize, seed: u64) -> Dataset {
    physics_like(n, 28, seed.wrapping_add(1), 0.10, "HIGGS-like")
}

/// KDD99-like: 41 features, 23 classes with the original's extreme
/// imbalance (smurf ≈ 57%, neptune ≈ 22%, normal ≈ 20%, the remaining 20
/// classes share ~1.5%).
///
/// Two properties of the real data matter for reproducing Table 7's ~80%:
/// * attack families form well-separated clusters (categorical one-hots);
/// * the dominant DoS classes are near-duplicate records (smurf packets are
///   practically identical), so their blobs have tiny variance — redundant
///   FCM centers collapse onto the same point instead of splitting the
///   class, keeping it intact under cluster↔class matching.
pub fn kdd_like(n: usize, seed: u64) -> Dataset {
    let d = 41;
    let k = 23;
    // Real KDD99-10% class proportions: smurf, neptune, normal, then the
    // graded attack tail (back, satan, ipsweep, portsweep, warezclient,
    // teardrop, pod, nmap, guess_passwd, ..., spy). Counts from the
    // published kddcup.data_10_percent distribution (494 021 records).
    let weights: Vec<f64> = [
        280_790.0, 107_201.0, 97_278.0, 2_203.0, 1_589.0, 1_247.0, 1_040.0,
        1_020.0, 979.0, 264.0, 231.0, 53.0, 30.0, 21.0, 20.0, 12.0, 10.0,
        9.0, 8.0, 7.0, 4.0, 3.0, 2.0,
    ]
    .iter()
    .map(|c| c / 494_021.0)
    .collect();
    let means = spread_means(d, k, 1.6, seed.wrapping_add(2));
    let comps: Vec<Component> = means
        .into_iter()
        .zip(weights)
        .enumerate()
        .map(|(label, (mean, weight))| Component {
            mean,
            // Near-duplicate DoS floods vs broader "normal"/rare attacks.
            std: vec![if label < 2 { 0.04 } else if label == 2 { 0.45 } else { 0.30 }; d],
            weight,
            label,
        })
        .collect();
    gaussian_mixture(n, &comps, seed, "KDD99-like")
}

/// Pima-like diabetes: 768 records × 8 features, 2 classes with the
/// published 65/35 split and per-feature class means/stds from the UCI
/// summary statistics (pregnancies, glucose, blood pressure, skin fold,
/// insulin, BMI, pedigree, age).
pub fn pima_like(n: usize, seed: u64) -> Dataset {
    // (negative mean, positive mean, shared-ish std) per feature, from the
    // published per-class summary of the Pima Indian Diabetes data.
    const STATS: [(f64, f64, f64); 8] = [
        (3.30, 4.87, 3.20),     // pregnancies
        (109.98, 141.26, 28.0), // plasma glucose
        (68.18, 70.82, 18.0),   // diastolic bp
        (19.66, 22.16, 15.0),   // triceps skin fold
        (68.79, 100.34, 100.0), // serum insulin
        (30.30, 35.14, 7.0),    // bmi
        (0.43, 0.55, 0.30),     // diabetes pedigree
        (31.19, 37.07, 11.0),   // age
    ];
    let neg = Component {
        mean: STATS.iter().map(|s| s.0).collect(),
        std: STATS.iter().map(|s| s.2).collect(),
        weight: 0.651,
        label: 0,
    };
    let pos = Component {
        mean: STATS.iter().map(|s| s.1).collect(),
        std: STATS.iter().map(|s| s.2).collect(),
        weight: 0.349,
        label: 1,
    };
    gaussian_mixture(n, &[neg, pos], seed, "Pima-like")
}

/// Well-separated blobs for tests and the quickstart example.
pub fn blobs(n: usize, d: usize, k: usize, spread: f64, seed: u64) -> Dataset {
    let means = spread_means(d, k, 4.0, seed);
    let comps: Vec<Component> = means
        .into_iter()
        .enumerate()
        .map(|(label, mean)| Component {
            mean,
            std: vec![spread; d],
            weight: 1.0 / k as f64,
            label,
        })
        .collect();
    gaussian_mixture(n, &comps, seed, "blobs")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let s = susy_like(500, 1);
        assert_eq!((s.rows(), s.dims(), s.n_classes), (500, 18, 2));
        let h = higgs_like(500, 1);
        assert_eq!((h.rows(), h.dims(), h.n_classes), (500, 28, 2));
        let k = kdd_like(4000, 1);
        // The rarest KDD classes (spy: 2 records in 494k) won't appear in a
        // 4k draw; the dominant ones must.
        assert_eq!((k.rows(), k.dims()), (4000, 41));
        assert!(k.n_classes >= 9 && k.n_classes <= 23, "{}", k.n_classes);
        let p = pima_like(768, 1);
        assert_eq!((p.rows(), p.dims(), p.n_classes), (768, 8, 2));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = susy_like(100, 9);
        let b = susy_like(100, 9);
        assert_eq!(a.features.as_slice(), b.features.as_slice());
        let c = susy_like(100, 10);
        assert_ne!(a.features.as_slice(), c.features.as_slice());
    }

    #[test]
    fn kdd_imbalance_present() {
        let d = kdd_like(20_000, 3);
        let labels = d.labels.unwrap();
        let mut counts = vec![0usize; 23];
        for &l in &labels {
            counts[l] += 1;
        }
        // smurf-like class dominates; tail classes are rare but present.
        assert!(counts[0] > 10_000, "{counts:?}");
        assert!(counts[1] > 3_000);
        let tail: usize = counts[3..].iter().sum();
        assert!(tail < 1_000, "tail too heavy: {tail}");
    }

    #[test]
    fn blobs_are_separated() {
        let d = blobs(300, 4, 3, 0.2, 5);
        let labels = d.labels.as_ref().unwrap();
        // Mean intra-class distance must be far below inter-class.
        let m = &d.features;
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n_intra = 0;
        let mut n_inter = 0;
        for i in (0..300).step_by(7) {
            for j in (1..300).step_by(11) {
                let dd = m.row_dist2(i, m.row(j));
                if labels[i] == labels[j] {
                    intra += dd;
                    n_intra += 1;
                } else {
                    inter += dd;
                    n_inter += 1;
                }
            }
        }
        assert!(inter / n_inter as f64 > 5.0 * (intra / n_intra as f64));
    }

    #[test]
    fn pima_class_balance() {
        let d = pima_like(768, 11);
        let labels = d.labels.unwrap();
        let pos = labels.iter().filter(|&&l| l == 1).count();
        let frac = pos as f64 / 768.0;
        assert!((0.28..0.42).contains(&frac), "positive fraction {frac}");
    }
}
