//! Built-in datasets: the embedded real Iris data plus named constructors
//! for every dataset of the paper's evaluation (synthetic stand-ins are
//! documented in DESIGN.md §3).

mod iris_data;

use crate::data::{synth, Dataset, Matrix};

/// The real Iris dataset: 150 × 4, 3 classes.
pub fn iris() -> Dataset {
    let rows: Vec<Vec<f32>> = iris_data::IRIS_FEATURES.iter().map(|r| r.to_vec()).collect();
    Dataset::labelled("Iris", Matrix::from_rows(&rows), iris_data::IRIS_LABELS.to_vec())
}

/// Pima-like diabetes data: 768 × 8, 2 classes (statistics from the
/// published UCI summary; see `synth::pima_like`).
pub fn pima(seed: u64) -> Dataset {
    synth::pima_like(768, seed)
}

/// SUSY-like physics data at the requested size (paper: 5M × 18, 2 classes).
pub fn susy(n: usize, seed: u64) -> Dataset {
    synth::susy_like(n, seed)
}

/// HIGGS-like physics data (paper: 11M × 28, 2 classes).
pub fn higgs(n: usize, seed: u64) -> Dataset {
    synth::higgs_like(n, seed)
}

/// KDD99-like intrusion data (paper: 494k × 41 after one-hot, 23 classes).
pub fn kdd99(n: usize, seed: u64) -> Dataset {
    synth::kdd_like(n, seed)
}

/// Resolve a dataset by its paper name (used by the CLI and bench harness).
/// `n` is the record count for the synthetic families (ignored for Iris/Pima).
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    match name.to_ascii_lowercase().as_str() {
        "iris" => Some(iris()),
        "pima" => Some(pima(seed)),
        "susy" => Some(susy(n, seed)),
        "higgs" => Some(higgs(n, seed)),
        "kdd99" | "kdd" => Some(kdd99(n, seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iris_shape_and_ranges() {
        let d = iris();
        assert_eq!(d.rows(), 150);
        assert_eq!(d.dims(), 4);
        assert_eq!(d.n_classes, 3);
        // Sanity against the published value ranges.
        for row in d.features.iter_rows() {
            assert!(row[0] >= 4.0 && row[0] <= 8.0, "sepal length {row:?}");
            assert!(row[3] >= 0.0 && row[3] <= 2.6, "petal width {row:?}");
        }
        // Class blocks of 50.
        let labels = d.labels.unwrap();
        assert!(labels[..50].iter().all(|&l| l == 0));
        assert!(labels[50..100].iter().all(|&l| l == 1));
        assert!(labels[100..].iter().all(|&l| l == 2));
    }

    #[test]
    fn iris_known_first_row() {
        let d = iris();
        assert_eq!(d.features.row(0), &[5.1, 3.5, 1.4, 0.2]);
        assert_eq!(d.features.row(149), &[5.9, 3.0, 5.1, 1.8]);
    }

    #[test]
    fn by_name_resolves_all() {
        for name in ["iris", "pima", "susy", "higgs", "kdd99"] {
            let d = by_name(name, 1000, 7).unwrap();
            assert!(d.rows() > 0, "{name}");
        }
        assert!(by_name("nope", 10, 0).is_none());
    }
}
