//! CSV codec for records — the format the paper's mappers parse line-by-line
//! ("eliminate spaces, comma"; Algorithm 3 lines 7–9).
//!
//! Reader tolerates the mess the paper's mapper cleans up: surrounding
//! whitespace, empty lines, an optional trailing label column, and either
//! comma or whitespace separators.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use crate::data::{Dataset, Matrix};
use crate::error::{Error, Result};

/// Parse one record line into features (and optional trailing label).
/// Returns `None` for blank/comment lines.
pub fn parse_line(line: &str, with_label: bool) -> Result<Option<(Vec<f32>, Option<usize>)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let fields: Vec<&str> = if line.contains(',') {
        line.split(',').map(str::trim).filter(|f| !f.is_empty()).collect()
    } else {
        line.split_whitespace().collect()
    };
    if fields.is_empty() {
        return Ok(None);
    }
    let (feat_fields, label_field) = if with_label && fields.len() > 1 {
        (&fields[..fields.len() - 1], Some(fields[fields.len() - 1]))
    } else {
        (&fields[..], None)
    };
    let mut feats = Vec::with_capacity(feat_fields.len());
    for f in feat_fields {
        feats.push(
            f.parse::<f32>()
                .map_err(|_| Error::Dataset(format!("bad numeric field `{f}`")))?,
        );
    }
    let label = match label_field {
        Some(l) => Some(
            l.parse::<usize>()
                .map_err(|_| Error::Dataset(format!("bad label `{l}`")))?,
        ),
        None => None,
    };
    Ok(Some((feats, label)))
}

/// Read a whole CSV stream into a dataset.
pub fn read_csv(reader: impl Read, name: &str, with_label: bool) -> Result<Dataset> {
    let buf = BufReader::new(reader);
    let mut rows: Vec<Vec<f32>> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut width = None;
    for (lineno, line) in buf.lines().enumerate() {
        let line = line.map_err(|e| Error::Dataset(format!("read error: {e}")))?;
        if let Some((feats, label)) = parse_line(&line, with_label)? {
            if let Some(w) = width {
                if feats.len() != w {
                    return Err(Error::Dataset(format!(
                        "line {}: width {} != {}",
                        lineno + 1,
                        feats.len(),
                        w
                    )));
                }
            } else {
                width = Some(feats.len());
            }
            rows.push(feats);
            if let Some(l) = label {
                labels.push(l);
            }
        }
    }
    let features = Matrix::from_rows(&rows);
    if with_label && labels.len() == features.rows() && !labels.is_empty() {
        Ok(Dataset::labelled(name, features, labels))
    } else {
        Ok(Dataset::unlabelled(name, features))
    }
}

/// Read a CSV file from disk.
pub fn read_csv_file(path: &Path, with_label: bool) -> Result<Dataset> {
    let f = std::fs::File::open(path).map_err(|e| Error::io(path, e))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "csv".to_string());
    read_csv(f, &name, with_label)
}

/// Write a dataset as CSV (features, then label if present).
pub fn write_csv(dataset: &Dataset, mut w: impl Write) -> Result<()> {
    let wrap = |e: std::io::Error| Error::Dataset(format!("write error: {e}"));
    for i in 0..dataset.rows() {
        let row = dataset.features.row(i);
        let mut line = row
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        if let Some(labels) = &dataset.labels {
            line.push(',');
            line.push_str(&labels[i].to_string());
        }
        line.push('\n');
        w.write_all(line.as_bytes()).map_err(wrap)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_messy_lines() {
        assert_eq!(
            parse_line(" 1.5, 2 ,3.25 ", false).unwrap().unwrap().0,
            vec![1.5, 2.0, 3.25]
        );
        assert_eq!(
            parse_line("1.5 2 3.25", false).unwrap().unwrap().0,
            vec![1.5, 2.0, 3.25]
        );
        assert!(parse_line("", false).unwrap().is_none());
        assert!(parse_line("# comment", false).unwrap().is_none());
        assert!(parse_line("1.5,abc", false).is_err());
    }

    #[test]
    fn label_column_split() {
        let (f, l) = parse_line("1,2,3,1", true).unwrap().unwrap();
        assert_eq!(f, vec![1.0, 2.0, 3.0]);
        assert_eq!(l, Some(1));
    }

    #[test]
    fn roundtrip() {
        let d = crate::data::synth::blobs(20, 3, 2, 0.3, 1);
        let mut buf = Vec::new();
        write_csv(&d, &mut buf).unwrap();
        let back = read_csv(&buf[..], "t", true).unwrap();
        assert_eq!(back.rows(), 20);
        assert_eq!(back.dims(), 3);
        assert_eq!(back.labels.as_ref().unwrap(), d.labels.as_ref().unwrap());
        for i in 0..20 {
            for j in 0..3 {
                let a = d.features.get(i, j);
                let b = back.features.get(i, j);
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn rejects_ragged_width() {
        let csv = "1,2,3\n1,2\n";
        assert!(read_csv(csv.as_bytes(), "t", false).is_err());
    }
}
