//! Row-major f32 matrix — the record container used across the stack.
//!
//! Deliberately minimal: the clustering hot paths operate on `&[f32]` row
//! slices, and the PJRT runtime consumes the contiguous buffer directly, so
//! no BLAS-style abstraction is needed here.

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Wrap an existing buffer; panics on length mismatch.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length != rows*cols");
        Self { data, rows, cols }
    }

    /// Build from row slices; panics on ragged input.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        if rows.is_empty() {
            return Self::zeros(0, 0);
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { data, rows: rows.len(), cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// The backing buffer (row-major).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Copy of the row range [start, end).
    pub fn slice_rows(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows);
        Matrix {
            data: self.data[start * self.cols..end * self.cols].to_vec(),
            rows: end - start,
            cols: self.cols,
        }
    }

    /// New matrix from the given row indices.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vstack column mismatch");
        let mut data = Vec::with_capacity(self.data.len() + other.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix { data, rows: self.rows + other.rows, cols: self.cols }
    }

    /// Append one row. On an empty (0×0) matrix the first push sets the width.
    pub fn push_row(&mut self, row: &[f32]) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Squared Euclidean distance between row `i` and a center slice.
    #[inline]
    pub fn row_dist2(&self, i: usize, center: &[f32]) -> f64 {
        dist2(self.row(i), center)
    }

    /// Iterator over row slices.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols.max(1)).take(self.rows)
    }

    /// Transposed copy (cols × rows). The tiled kernels in `fcm::native`
    /// stream a transposed (d × C) center panel so the innermost lane loop
    /// reads one contiguous slice of center components per dimension.
    pub fn transposed(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Iterator over fixed-height row tiles: `(first_row, rows_in_tile,
    /// contiguous row-major slab)`. The last tile may be short; a 0-row
    /// matrix yields no tiles.
    pub fn iter_row_tiles(&self, tile: usize) -> impl Iterator<Item = (usize, usize, &[f32])> {
        let tile = tile.max(1);
        let n_tiles = (self.rows + tile - 1) / tile;
        (0..n_tiles).map(move |t| {
            let base = t * tile;
            let len = tile.min(self.rows - base);
            (base, len, &self.data[base * self.cols..(base + len) * self.cols])
        })
    }
}

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.get(0, 1), 2.0);
    }

    #[test]
    fn slice_and_select() {
        let m = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        assert_eq!(m.slice_rows(1, 3).as_slice(), &[1.0, 2.0]);
        assert_eq!(m.select_rows(&[3, 0]).as_slice(), &[3.0, 0.0]);
    }

    #[test]
    fn vstack_and_push() {
        let a = Matrix::from_rows(&[vec![1.0, 1.0]]);
        let b = Matrix::from_rows(&[vec![2.0, 2.0]]);
        let c = a.vstack(&b);
        assert_eq!(c.rows(), 2);
        let mut d = Matrix::zeros(0, 0);
        d.push_row(&[5.0, 6.0]);
        d.push_row(&[7.0, 8.0]);
        assert_eq!(d.rows(), 2);
        assert_eq!(d.cols(), 2);
        assert_eq!(d.row(1), &[7.0, 8.0]);
    }

    #[test]
    fn dist2_matches_manual() {
        assert_eq!(dist2(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        let _ = Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn iter_rows_covers_all() {
        let m = Matrix::from_rows(&[vec![1.0], vec![2.0]]);
        let rows: Vec<&[f32]> = m.iter_rows().collect();
        assert_eq!(rows, vec![&[1.0f32][..], &[2.0f32][..]]);
    }

    #[test]
    fn transposed_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let t = m.transposed();
        assert_eq!((t.rows(), t.cols()), (3, 2));
        assert_eq!(t.row(0), &[1.0, 4.0]);
        assert_eq!(t.row(2), &[3.0, 6.0]);
        assert_eq!(t.transposed(), m);
    }

    #[test]
    fn row_tiles_cover_all_rows_with_short_tail() {
        let m = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        let tiles: Vec<(usize, usize, Vec<f32>)> = m
            .iter_row_tiles(2)
            .map(|(base, len, slab)| (base, len, slab.to_vec()))
            .collect();
        assert_eq!(tiles.len(), 3);
        assert_eq!(tiles[0], (0, 2, vec![0.0, 1.0]));
        assert_eq!(tiles[1], (2, 2, vec![2.0, 3.0]));
        assert_eq!(tiles[2], (4, 1, vec![4.0]));
        // Tile height larger than the matrix: one tile with every row.
        let all: Vec<_> = m.iter_row_tiles(100).collect();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].1, 5);
    }
}
