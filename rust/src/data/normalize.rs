//! Feature normalisation — the paper normalises KDD99 and converts its
//! categorical features to numeric before clustering (§4.1).

use crate::data::Matrix;

/// Per-feature affine transform learned from data (min-max or z-score).
#[derive(Clone, Debug)]
pub struct Scaler {
    /// Per-feature offset subtracted first.
    pub offset: Vec<f32>,
    /// Per-feature divisor (1 where the feature is constant).
    pub scale: Vec<f32>,
}

impl Scaler {
    /// Min-max scaler mapping each feature to [0, 1].
    pub fn min_max(m: &Matrix) -> Scaler {
        let d = m.cols();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for row in m.iter_rows() {
            for j in 0..d {
                lo[j] = lo[j].min(row[j]);
                hi[j] = hi[j].max(row[j]);
            }
        }
        let scale = lo
            .iter()
            .zip(&hi)
            .map(|(&l, &h)| if h > l { h - l } else { 1.0 })
            .collect();
        Scaler { offset: lo, scale }
    }

    /// Z-score scaler (mean 0, std 1).
    pub fn z_score(m: &Matrix) -> Scaler {
        let d = m.cols();
        let n = m.rows().max(1) as f64;
        let mut mean = vec![0.0f64; d];
        for row in m.iter_rows() {
            for j in 0..d {
                mean[j] += row[j] as f64;
            }
        }
        for v in &mut mean {
            *v /= n;
        }
        let mut var = vec![0.0f64; d];
        for row in m.iter_rows() {
            for j in 0..d {
                let diff = row[j] as f64 - mean[j];
                var[j] += diff * diff;
            }
        }
        let scale = var
            .iter()
            .map(|&v| {
                let s = (v / n).sqrt() as f32;
                if s > 0.0 {
                    s
                } else {
                    1.0
                }
            })
            .collect();
        Scaler { offset: mean.iter().map(|&x| x as f32).collect(), scale }
    }

    /// Apply in place.
    pub fn apply(&self, m: &mut Matrix) {
        let d = m.cols();
        assert_eq!(d, self.offset.len(), "scaler dims mismatch");
        for i in 0..m.rows() {
            let row = m.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] - self.offset[j]) / self.scale[j];
            }
        }
    }

    /// Invert a transformed center back to original units (for reports).
    pub fn invert_row(&self, row: &[f32]) -> Vec<f32> {
        row.iter()
            .zip(self.offset.iter().zip(&self.scale))
            .map(|(&v, (&o, &s))| v * s + o)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_maps_to_unit_interval() {
        let mut m = Matrix::from_rows(&[vec![0.0, 10.0], vec![5.0, 20.0], vec![10.0, 30.0]]);
        let s = Scaler::min_max(&m);
        s.apply(&mut m);
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[1.0, 1.0]);
        assert_eq!(m.row(1), &[0.5, 0.5]);
    }

    #[test]
    fn z_score_moments() {
        let mut m = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        let s = Scaler::z_score(&m);
        s.apply(&mut m);
        let mean: f32 = m.as_slice().iter().sum::<f32>() / 4.0;
        let var: f32 = m.as_slice().iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }

    #[test]
    fn constant_feature_is_safe() {
        let mut m = Matrix::from_rows(&[vec![7.0], vec![7.0]]);
        let s = Scaler::min_max(&m);
        s.apply(&mut m);
        assert!(m.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn invert_roundtrips() {
        let m = Matrix::from_rows(&[vec![2.0, -1.0], vec![8.0, 3.0]]);
        let s = Scaler::min_max(&m);
        let mut t = m.clone();
        s.apply(&mut t);
        let back = s.invert_row(t.row(1));
        assert!((back[0] - 8.0).abs() < 1e-6);
        assert!((back[1] - 3.0).abs() < 1e-6);
    }
}
