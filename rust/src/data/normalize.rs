//! Feature normalisation — the paper normalises KDD99 and converts its
//! categorical features to numeric before clustering (§4.1).

use crate::data::Matrix;

/// Per-feature affine transform learned from data (min-max or z-score).
#[derive(Clone, Debug)]
pub struct Scaler {
    /// Per-feature offset subtracted first.
    pub offset: Vec<f32>,
    /// Per-feature divisor (1 where the feature is constant).
    pub scale: Vec<f32>,
}

impl Scaler {
    /// Degenerate-column guard shared by both fitters: a constant column
    /// (`range == 0`), an empty fit, or non-finite statistics (±∞ from an
    /// empty scan, NaN from poisoned inputs) would otherwise put NaN/∞
    /// into every normalized value — and a NaN feature poisons every
    /// distance, membership and center downstream (the serving layer
    /// scores through persisted scalers, so the guard is load-bearing
    /// there too). Such columns collapse to the safe affine `(x − o) / 1`
    /// with a finite `o` (0 when even the offset statistic is unusable).
    fn guarded(offset: f32, range: f32) -> (f32, f32) {
        let offset = if offset.is_finite() { offset } else { 0.0 };
        if range.is_finite() && range > 0.0 {
            (offset, range)
        } else {
            (offset, 1.0)
        }
    }

    /// Identity transform over `d` features (bundles without stats).
    pub fn identity(d: usize) -> Scaler {
        Scaler { offset: vec![0.0; d], scale: vec![1.0; d] }
    }

    /// Min-max scaler mapping each feature to [0, 1]; zero-range columns
    /// map to 0 (see [`Self::guarded`]).
    pub fn min_max(m: &Matrix) -> Scaler {
        let d = m.cols();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for row in m.iter_rows() {
            for j in 0..d {
                lo[j] = lo[j].min(row[j]);
                hi[j] = hi[j].max(row[j]);
            }
        }
        let mut offset = Vec::with_capacity(d);
        let mut scale = Vec::with_capacity(d);
        for (&l, &h) in lo.iter().zip(&hi) {
            let (o, s) = Self::guarded(l, h - l);
            offset.push(o);
            scale.push(s);
        }
        Scaler { offset, scale }
    }

    /// Z-score scaler (mean 0, std 1); zero-σ columns map to 0 (see
    /// [`Self::guarded`]).
    pub fn z_score(m: &Matrix) -> Scaler {
        let d = m.cols();
        let n = m.rows().max(1) as f64;
        let mut mean = vec![0.0f64; d];
        for row in m.iter_rows() {
            for j in 0..d {
                mean[j] += row[j] as f64;
            }
        }
        for v in &mut mean {
            *v /= n;
        }
        let mut var = vec![0.0f64; d];
        for row in m.iter_rows() {
            for j in 0..d {
                let diff = row[j] as f64 - mean[j];
                var[j] += diff * diff;
            }
        }
        let mut offset = Vec::with_capacity(d);
        let mut scale = Vec::with_capacity(d);
        for (&mu, &v) in mean.iter().zip(&var) {
            let (o, s) = Self::guarded(mu as f32, (v / n).sqrt() as f32);
            offset.push(o);
            scale.push(s);
        }
        Scaler { offset, scale }
    }

    /// Apply to one record in place (the serving layer's per-request
    /// transform — one row, no matrix wrapper).
    pub fn apply_row(&self, row: &mut [f32]) {
        assert_eq!(row.len(), self.offset.len(), "scaler dims mismatch");
        for ((x, &o), &s) in row.iter_mut().zip(&self.offset).zip(&self.scale) {
            *x = (*x - o) / s;
        }
    }

    /// Apply in place.
    pub fn apply(&self, m: &mut Matrix) {
        let d = m.cols();
        assert_eq!(d, self.offset.len(), "scaler dims mismatch");
        for i in 0..m.rows() {
            self.apply_row(m.row_mut(i));
        }
    }

    /// Invert a transformed center back to original units (for reports).
    pub fn invert_row(&self, row: &[f32]) -> Vec<f32> {
        row.iter()
            .zip(self.offset.iter().zip(&self.scale))
            .map(|(&v, (&o, &s))| v * s + o)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_max_maps_to_unit_interval() {
        let mut m = Matrix::from_rows(&[vec![0.0, 10.0], vec![5.0, 20.0], vec![10.0, 30.0]]);
        let s = Scaler::min_max(&m);
        s.apply(&mut m);
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[1.0, 1.0]);
        assert_eq!(m.row(1), &[0.5, 0.5]);
    }

    #[test]
    fn z_score_moments() {
        let mut m = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        let s = Scaler::z_score(&m);
        s.apply(&mut m);
        let mean: f32 = m.as_slice().iter().sum::<f32>() / 4.0;
        let var: f32 = m.as_slice().iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }

    #[test]
    fn constant_feature_is_safe() {
        let mut m = Matrix::from_rows(&[vec![7.0], vec![7.0]]);
        let s = Scaler::min_max(&m);
        s.apply(&mut m);
        assert!(m.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_range_and_zero_sigma_columns_normalize_to_zero() {
        // The regression the serving layer depends on: a constant column
        // next to a live one must come out as exactly 0, never NaN, under
        // both fitters — and must stay finite on *unseen* records too.
        let rows = vec![vec![7.0, 1.0], vec![7.0, 2.0], vec![7.0, 3.0]];
        for fit in [Scaler::min_max, Scaler::z_score] {
            let m = Matrix::from_rows(&rows);
            let s = fit(&m);
            let mut t = m.clone();
            s.apply(&mut t);
            for i in 0..3 {
                assert!(t.row(i).iter().all(|v| v.is_finite()), "non-finite at row {i}");
                assert_eq!(t.get(i, 0), 0.0, "constant column must map to 0");
            }
            // A record the fit never saw, off the constant value.
            let mut unseen = vec![9.5f32, 2.5];
            s.apply_row(&mut unseen);
            assert!(unseen.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn non_finite_statistics_are_guarded() {
        // NaN/∞ feature values poison the fitted statistics; the guard
        // must still produce a finite affine map (offset 0, scale 1 for
        // fully poisoned columns), not NaN normalized output.
        let rows = vec![
            vec![f32::NAN, 1.0, f32::INFINITY],
            vec![f32::NAN, 2.0, f32::INFINITY],
        ];
        for fit in [Scaler::min_max, Scaler::z_score] {
            let s = fit(&Matrix::from_rows(&rows));
            assert!(s.offset.iter().all(|v| v.is_finite()), "offset not guarded");
            assert!(s.scale.iter().all(|v| v.is_finite() && *v > 0.0), "scale not guarded");
            let mut clean = vec![5.0f32, 1.5, 3.0];
            s.apply_row(&mut clean);
            assert!(clean.iter().all(|v| v.is_finite()));
        }
        // Empty fit (0 rows): ±∞ min/max stats must be guarded too.
        let s = Scaler::min_max(&Matrix::zeros(0, 2));
        let mut row = vec![1.0f32, 2.0];
        s.apply_row(&mut row);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn apply_row_matches_apply_and_identity_is_noop() {
        let m = Matrix::from_rows(&[vec![2.0, -1.0], vec![8.0, 3.0]]);
        let s = Scaler::min_max(&m);
        let mut whole = m.clone();
        s.apply(&mut whole);
        let mut row = m.row(1).to_vec();
        s.apply_row(&mut row);
        assert_eq!(row.as_slice(), whole.row(1));
        let id = Scaler::identity(2);
        let mut same = m.row(0).to_vec();
        id.apply_row(&mut same);
        assert_eq!(same.as_slice(), m.row(0));
    }

    #[test]
    fn invert_roundtrips() {
        let m = Matrix::from_rows(&[vec![2.0, -1.0], vec![8.0, 3.0]]);
        let s = Scaler::min_max(&m);
        let mut t = m.clone();
        s.apply(&mut t);
        let back = s.invert_row(t.row(1));
        assert!((back[0] - 8.0).abs() < 1e-6);
        assert!((back[1] - 3.0).abs() < 1e-6);
    }
}
