//! Offline shim for the `xla` crate's PJRT surface.
//!
//! The production build links the real `xla` crate (HLO → PJRT CPU client);
//! this container builds fully offline, so the runtime modules import this
//! shim instead (`use crate::xla;`). It exposes the exact API shape
//! [`crate::runtime`] consumes and fails at *client construction* — the one
//! place [`crate::runtime::server`] already handles gracefully — so every
//! backend-resolution path (`Backend::Auto` falling back to native, benches
//! skipping PJRT rows, `info` reporting "unavailable") behaves identically
//! to a machine without a PJRT plugin.
//!
//! Swapping in the real crate is a one-line change per importing module
//! (`use xla;` instead of `use crate::xla;`) plus the Cargo dependency.

use std::fmt;

/// Error type mirroring `xla::Error` (opaque message).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla/pjrt backend not linked in this build (offline shim) — \
         vendor the xla crate and point `use` at it to enable PJRT"
            .to_string(),
    ))
}

/// PJRT client handle. The shim can never construct one, which statically
/// guarantees the downstream entry points below are unreachable at runtime.
pub struct PjRtClient;

impl PjRtClient {
    /// Real crate: build the CPU (Eigen) PJRT client. Shim: always errors.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (real crate: protobuf-backed).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable resident on a PJRT device.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Real crate: execute and return per-device, per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (dense array value).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("shim must not build a client");
        assert!(err.to_string().contains("offline shim"));
    }

    #[test]
    fn error_converts_into_crate_error() {
        let e: crate::error::Error = Error("boom".into()).into();
        assert!(e.to_string().contains("boom"));
    }
}
