//! Artifact registry: `artifacts/manifest.json` written by the AOT build.

use std::path::Path;

use crate::error::{Error, Result};
use crate::json;
use crate::runtime::Graph;

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub graph: Graph,
    pub dims: usize,
    pub clusters: usize,
    pub chunk: usize,
    /// Parameter count of the lowered entry (4 for fcm/classic, 3 kmeans).
    pub params: usize,
    /// File name relative to the artifacts dir.
    pub file: String,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub chunk: usize,
    pub row_block: usize,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let chunk = v
            .require("chunk")?
            .as_usize()
            .ok_or_else(|| Error::Artifact("chunk is not a number".into()))?;
        let row_block = v
            .require("row_block")?
            .as_usize()
            .ok_or_else(|| Error::Artifact("row_block is not a number".into()))?;
        let arr = v
            .require("artifacts")?
            .as_array()
            .ok_or_else(|| Error::Artifact("artifacts is not an array".into()))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_usize = |k: &str| -> Result<usize> {
                a.require(k)?
                    .as_usize()
                    .ok_or_else(|| Error::Artifact(format!("{k} is not a number")))
            };
            let get_str = |k: &str| -> Result<String> {
                Ok(a.require(k)?
                    .as_str()
                    .ok_or_else(|| Error::Artifact(format!("{k} is not a string")))?
                    .to_string())
            };
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                graph: Graph::parse(&get_str("graph")?)?,
                dims: get_usize("dims")?,
                clusters: get_usize("clusters")?,
                chunk: get_usize("chunk")?,
                params: get_usize("params")?,
                file: get_str("file")?,
            });
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest lists no artifacts".into()));
        }
        Ok(Manifest { chunk, row_block, artifacts })
    }

    /// Find the artifact for a shape.
    pub fn find(&self, graph: Graph, dims: usize, clusters: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.graph == graph && a.dims == dims && a.clusters == clusters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "chunk": 4096, "row_block": 512,
      "artifacts": [
        {"name": "fcm_d4_c3", "graph": "fcm", "dims": 4, "clusters": 3,
         "chunk": 4096, "params": 4, "file": "fcm_d4_c3.hlo.txt", "bytes": 100},
        {"name": "kmeans_d4_c3", "graph": "kmeans", "dims": 4, "clusters": 3,
         "chunk": 4096, "params": 3, "file": "kmeans_d4_c3.hlo.txt", "bytes": 90}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.chunk, 4096);
        assert_eq!(m.row_block, 512);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find(Graph::Fcm, 4, 3).unwrap();
        assert_eq!(a.params, 4);
        assert!(m.find(Graph::Fcm, 9, 9).is_none());
        assert!(m.find(Graph::Kmeans, 4, 3).is_some());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"chunk": 4096}"#).is_err());
        assert!(Manifest::parse(r#"{"chunk": 4096, "row_block": 1, "artifacts": []}"#).is_err());
    }

    #[test]
    fn rejects_unknown_graph() {
        let bad = SAMPLE.replace("\"kmeans\"", "\"mystery\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
