//! One compiled HLO executable + literal marshalling. Lives on the PJRT
//! device-owner thread ([`super::server`]); callers marshal padded buffers.

use std::path::Path;

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::Partials;
use crate::runtime::ArtifactMeta;
use crate::xla;

/// A compiled chunk-step executable for one `(graph, dims, clusters)` shape.
pub struct ChunkExecutor {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl ChunkExecutor {
    /// Load HLO text and compile it on the client.
    pub fn compile(client: &xla::PjRtClient, path: &Path, meta: ArtifactMeta) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self { exe, meta })
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute one pre-padded chunk.
    ///
    /// * `x` — chunk×dims row-major, tail rows zeroed;
    /// * `v` — clusters×dims;
    /// * `w` — chunk weights, tail zeroed (padding contract);
    /// * `m` — fuzzifier, ignored by 3-parameter (kmeans) artifacts.
    pub fn execute_padded(&self, x: &[f32], v: &[f32], w: &[f32], m: f64) -> Result<Partials> {
        let chunk = self.meta.chunk;
        let d = self.meta.dims;
        let c = self.meta.clusters;
        if x.len() != chunk * d || v.len() != c * d || w.len() != chunk {
            return Err(Error::Artifact(format!(
                "buffer shapes for {}: x={} (want {}), v={} (want {}), w={} (want {chunk})",
                self.meta.name,
                x.len(),
                chunk * d,
                v.len(),
                c * d,
                w.len()
            )));
        }

        let x_lit = xla::Literal::vec1(x).reshape(&[chunk as i64, d as i64])?;
        let v_lit = xla::Literal::vec1(v).reshape(&[c as i64, d as i64])?;
        let w_lit = xla::Literal::vec1(w).reshape(&[chunk as i64])?;

        let result = if self.meta.params == 4 {
            let m_lit = xla::Literal::scalar(m as f32);
            self.exe.execute::<xla::Literal>(&[x_lit, v_lit, w_lit, m_lit])?
        } else {
            self.exe.execute::<xla::Literal>(&[x_lit, v_lit, w_lit])?
        };
        let out = result[0][0].to_literal_sync()?;

        // Graphs are lowered with return_tuple=True → one 3-tuple.
        let (vnum_lit, wacc_lit, obj_lit) = out.to_tuple3()?;
        let vnum = vnum_lit.to_vec::<f32>()?;
        let wacc = wacc_lit.to_vec::<f32>()?;
        let obj = obj_lit.to_vec::<f32>()?;
        if vnum.len() != c * d || wacc.len() != c || obj.len() != 1 {
            return Err(Error::Xla(format!(
                "unexpected output shapes from {}: {} {} {}",
                self.meta.name,
                vnum.len(),
                wacc.len(),
                obj.len()
            )));
        }
        Ok(Partials {
            v_num: Matrix::from_vec(vnum, c, d),
            w_acc: wacc.into_iter().map(|x| x as f64).collect(),
            objective: obj[0] as f64,
        })
    }
}
