//! PJRT runtime: loads the AOT HLO text artifacts produced by
//! `python/compile/aot.py` and executes them as the chunk backend.
//!
//! Flow per artifact (see /opt/xla-example/load_hlo): HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile` →
//! `PjRtLoadedExecutable`, compiled lazily and cached per
//! `(graph, dims, clusters)` on a dedicated device-owner thread
//! ([`server`]) because the `xla` crate types are `!Send`.
//!
//! [`PjrtRuntime`] implements [`crate::fcm::KernelBackend`]: inputs are split
//! into fixed `chunk`-row pieces (the artifact's lowered shape), the last
//! piece zero-padded with zero weights (exactly ignored by the kernels —
//! the padding contract tested in `python/tests/test_kernel.py` and
//! `rust/tests/integration_runtime.rs`), partials merged host-side.

pub mod artifact;
pub mod executor;
pub mod server;

pub use artifact::{ArtifactMeta, Manifest};
pub use executor::ChunkExecutor;
pub use server::ServerStats;

use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::{
    BlockBounds, BoundConfig, BoundRows, Kernel, KernelBackend, NativeBackend, Partials, PruneStats,
};

/// Graph families in the artifact matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Graph {
    Fcm,
    Classic,
    Kmeans,
}

impl Graph {
    pub fn as_str(&self) -> &'static str {
        match self {
            Graph::Fcm => "fcm",
            Graph::Classic => "classic",
            Graph::Kmeans => "kmeans",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fcm" => Ok(Graph::Fcm),
            "classic" => Ok(Graph::Classic),
            "kmeans" => Ok(Graph::Kmeans),
            other => Err(Error::Artifact(format!("unknown graph `{other}`"))),
        }
    }
}

/// The PJRT-backed chunk backend: a `Send + Sync` handle to the device
/// thread.
pub struct PjrtRuntime {
    manifest: Manifest,
    tx: Mutex<Sender<server::Request>>,
}

impl PjrtRuntime {
    /// Open the artifact registry and start the device-owner thread.
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        let tx = server::spawn(artifacts_dir.to_path_buf(), manifest.clone());
        Ok(Self { manifest, tx: Mutex::new(tx) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The chunk row count all artifacts were lowered with.
    pub fn chunk(&self) -> usize {
        self.manifest.chunk
    }

    /// Whether an artifact exists for this shape.
    pub fn supports(&self, graph: Graph, dims: usize, clusters: usize) -> bool {
        self.manifest.find(graph, dims, clusters).is_some()
    }

    /// Aggregate execution statistics from the device thread.
    pub fn stats(&self) -> Result<ServerStats> {
        let (reply_tx, reply_rx) = channel();
        self.send(server::Request::Stats(reply_tx))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Xla("pjrt server thread is gone".into()))
    }

    fn send(&self, req: server::Request) -> Result<()> {
        self.tx
            .lock()
            .expect("pjrt sender poisoned")
            .send(req)
            .map_err(|_| Error::Xla("pjrt server thread is gone".into()))
    }

    fn run_chunked(
        &self,
        graph: Graph,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> Result<Partials> {
        let d = x.cols();
        let c = v.rows();
        if !self.supports(graph, d, c) {
            return Err(Error::Artifact(format!(
                "no artifact for graph={} dims={d} clusters={c} — add the combo to \
                 python/compile/aot.py::SHAPES and re-run `make artifacts`",
                graph.as_str()
            )));
        }
        let chunk = self.manifest.chunk;
        let mut total = Partials::zeros(c, d);
        let mut start = 0usize;
        while start < x.rows() {
            let end = (start + chunk).min(x.rows());
            let live = end - start;
            // Marshal padded buffers (tail zeros are exactly ignored).
            let mut xbuf = vec![0.0f32; chunk * d];
            xbuf[..live * d].copy_from_slice(&x.as_slice()[start * d..end * d]);
            let mut wbuf = vec![0.0f32; chunk];
            wbuf[..live].copy_from_slice(&w[start..end]);
            let (reply_tx, reply_rx) = channel();
            self.send(server::Request::Run(
                server::ChunkRequest {
                    graph,
                    dims: d,
                    clusters: c,
                    x: xbuf,
                    v: v.as_slice().to_vec(),
                    w: wbuf,
                    m,
                },
                reply_tx,
            ))?;
            let partial = reply_rx
                .recv()
                .map_err(|_| Error::Xla("pjrt server thread is gone".into()))??;
            total.merge(&partial);
            start = end;
        }
        Ok(total)
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        let _ = self.send(server::Request::Shutdown);
    }
}

impl KernelBackend for PjrtRuntime {
    fn exact_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> Result<Partials> {
        self.run_chunked(graph_of(kernel), x, v, w, m)
    }

    /// The current AOT artifacts lower only the plain partials graphs —
    /// they return no per-record bound rows. Surfaced as an error rather
    /// than a silent host-side recompute; [`PjrtRuntime::pruned_partials`]
    /// opts out of pruning instead.
    fn partials_with_bounds(
        &self,
        _kernel: Kernel,
        _x: &Matrix,
        _v: &Matrix,
        _w: &[f32],
        _m: f64,
        _rows: &mut BoundRows,
    ) -> Result<Partials> {
        Err(Error::Artifact(
            "the AOT artifacts do not export per-record bound rows — add the bound-emitting \
             graphs to python/compile/aot.py and re-run `make artifacts`, or use the \
             `shim` backend"
                .into(),
        ))
    }

    /// The artifacts lower only the partials graphs — no membership rows
    /// either; surfaced as an error (the generic default would bounce off
    /// [`Self::partials_with_bounds`] with a bound-row message that
    /// misleads a serving caller).
    fn score_chunk(
        &self,
        _kernel: Kernel,
        _x: &Matrix,
        _v: &Matrix,
        _m: f64,
        _u: &mut Matrix,
    ) -> Result<()> {
        Err(Error::Artifact(
            "the AOT artifacts do not export membership rows — lower a scoring graph in \
             python/compile/aot.py and re-run `make artifacts`, or serve through the `shim` \
             backend"
                .into(),
        ))
    }

    /// No bound outputs from the artifacts yet: reset the state and run
    /// exactly — correct (no stale bound can survive), just unpruned.
    #[allow(clippy::too_many_arguments)]
    fn pruned_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut BlockBounds,
        _cfg: &BoundConfig,
    ) -> Result<(Partials, PruneStats)> {
        state.reset();
        Ok((self.exact_partials(kernel, x, v, w, m)?, PruneStats::default()))
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

fn graph_of(kernel: Kernel) -> Graph {
    match kernel {
        Kernel::FcmFast => Graph::Fcm,
        // Both classic evaluations lower to the same classic graph — the
        // pair loop is a host-side compute model, not a different result.
        Kernel::FcmClassic | Kernel::FcmClassicPair => Graph::Classic,
        Kernel::KMeans => Graph::Kmeans,
    }
}

/// Offline stand-in for a PJRT device backend with the bound-emitting
/// kernels lowered: reproduces the runtime's execution shape — fixed
/// `chunk`-row pieces, zero-padded tails with zero weights (the padding
/// contract), per-chunk partials merged host-side — while computing each
/// chunk with the native kernels, exactly as `bigfcm::xla` shims the
/// device client. Because [`KernelBackend::partials_with_bounds`] is
/// implemented per chunk, the portable pruning protocol runs on it
/// unchanged — the session layer's bounds survive the backend swap, and
/// the claim is CI-testable without artifacts
/// (`rust/tests/integration_streaming.rs`).
pub struct PjrtShimBackend {
    chunk: usize,
    native: NativeBackend,
}

impl PjrtShimBackend {
    /// `chunk` is the fixed row count per device execution (the lowered
    /// shape's leading dimension; `cluster.chunk` in config).
    pub fn new(chunk: usize) -> Self {
        Self { chunk: chunk.max(1), native: NativeBackend }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// The one copy of the padded-chunk marshalling loop: run `f` over
    /// every fixed `chunk`-row piece of (x, w) — tail zero-padded with
    /// zero weights (the padding contract) into buffers reused across
    /// chunks — passing the global row offset and live prefix length, and
    /// merge the per-chunk partials host-side.
    fn for_each_padded_chunk<F>(&self, x: &Matrix, v: &Matrix, w: &[f32], mut f: F) -> Result<Partials>
    where
        F: FnMut(&Matrix, &[f32], usize, usize) -> Result<Partials>,
    {
        let d = x.cols();
        let mut total = Partials::zeros(v.rows(), d);
        let mut xc = Matrix::zeros(self.chunk, d);
        let mut wbuf = vec![0.0f32; self.chunk];
        let mut start = 0usize;
        while start < x.rows() {
            let end = (start + self.chunk).min(x.rows());
            let live = end - start;
            let xs = xc.as_mut_slice();
            xs[..live * d].copy_from_slice(&x.as_slice()[start * d..end * d]);
            xs[live * d..].fill(0.0);
            wbuf[..live].copy_from_slice(&w[start..end]);
            wbuf[live..].fill(0.0);
            total.merge(&f(&xc, &wbuf, start, live)?);
            start = end;
        }
        Ok(total)
    }
}

impl KernelBackend for PjrtShimBackend {
    fn exact_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> Result<Partials> {
        self.for_each_padded_chunk(x, v, w, |xc, wc, _start, _live| {
            self.native.exact_partials(kernel, xc, v, wc, m)
        })
    }

    fn partials_with_bounds(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        rows: &mut BoundRows,
    ) -> Result<Partials> {
        let c = v.rows();
        self.for_each_padded_chunk(x, v, w, |xc, wc, start, live| {
            // "Device" output for the whole padded chunk; only the live
            // prefix is copied back (padding rows carry no information).
            let mut chunk_rows = BoundRows::for_kernel(kernel, self.chunk, c);
            let partial =
                self.native.partials_with_bounds(kernel, xc, v, wc, m, &mut chunk_rows)?;
            for r in 0..live {
                let k = start + r;
                rows.d2.row_mut(k).copy_from_slice(chunk_rows.d2.row(r));
                rows.obj[k] = chunk_rows.obj[r];
                if kernel.is_kmeans() {
                    rows.best[k] = chunk_rows.best[r];
                } else {
                    rows.um.row_mut(k).copy_from_slice(chunk_rows.um.row(r));
                }
            }
            Ok(partial)
        })
    }

    fn name(&self) -> &'static str {
        "pjrt-shim"
    }
}

/// Backend resolved from config: PJRT artifacts when available, native
/// otherwise (or forced by `runtime.backend`).
pub enum ResolvedBackend {
    Pjrt(Arc<PjrtRuntime>),
    Native(NativeBackend),
    /// PJRT runtime with native fallback for unsupported shapes.
    Auto(Arc<PjrtRuntime>, NativeBackend),
    /// Offline PJRT shim (chunked device execution shape, no artifacts).
    Shim(PjrtShimBackend),
}

impl ResolvedBackend {
    /// Resolve from config. `Auto` degrades to native (with no error) when
    /// the artifacts directory is missing.
    pub fn from_config(cfg: &crate::config::Config) -> Result<ResolvedBackend> {
        use crate::config::Backend;
        match cfg.backend {
            Backend::Native => Ok(ResolvedBackend::Native(NativeBackend)),
            Backend::Pjrt => {
                let rt = Arc::new(PjrtRuntime::open(&cfg.artifacts_dir)?);
                Ok(ResolvedBackend::Pjrt(rt))
            }
            Backend::Auto => match PjrtRuntime::open(&cfg.artifacts_dir) {
                Ok(rt) => Ok(ResolvedBackend::Auto(Arc::new(rt), NativeBackend)),
                Err(_) => Ok(ResolvedBackend::Native(NativeBackend)),
            },
            Backend::Shim => Ok(ResolvedBackend::Shim(PjrtShimBackend::new(cfg.cluster.chunk))),
        }
    }

    fn pick(&self, graph: Graph, dims: usize, clusters: usize) -> &dyn KernelBackend {
        match self {
            ResolvedBackend::Pjrt(rt) => rt.as_ref(),
            ResolvedBackend::Native(nb) => nb,
            ResolvedBackend::Shim(sb) => sb,
            ResolvedBackend::Auto(rt, nb) => {
                if rt.supports(graph, dims, clusters) {
                    rt.as_ref()
                } else {
                    nb
                }
            }
        }
    }
}

// Forward both primitives and the pruned protocol entry to whatever
// backend the shape resolves to, so Auto/Native/Shim resolutions keep
// real shift-bounded pruning (a PJRT pick opts out via its own override,
// which resets the state — no stale bound can cross a backend switch).
impl KernelBackend for ResolvedBackend {
    fn exact_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> Result<Partials> {
        self.pick(graph_of(kernel), x.cols(), v.rows()).exact_partials(kernel, x, v, w, m)
    }

    fn partials_with_bounds(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        rows: &mut BoundRows,
    ) -> Result<Partials> {
        self.pick(graph_of(kernel), x.cols(), v.rows())
            .partials_with_bounds(kernel, x, v, w, m, rows)
    }

    #[allow(clippy::too_many_arguments)]
    fn pruned_partials(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut BlockBounds,
        cfg: &BoundConfig,
    ) -> Result<(Partials, PruneStats)> {
        self.pick(graph_of(kernel), x.cols(), v.rows())
            .pruned_partials(kernel, x, v, w, m, state, cfg)
    }

    /// Forwarded (not defaulted) so a native resolution serves through its
    /// direct tiled membership kernel instead of the generic bound-row
    /// derivation.
    fn score_chunk(
        &self,
        kernel: Kernel,
        x: &Matrix,
        v: &Matrix,
        m: f64,
        u: &mut Matrix,
    ) -> Result<()> {
        self.pick(graph_of(kernel), x.cols(), v.rows()).score_chunk(kernel, x, v, m, u)
    }

    fn name(&self) -> &'static str {
        match self {
            ResolvedBackend::Pjrt(_) => "pjrt",
            ResolvedBackend::Native(_) => "native",
            ResolvedBackend::Auto(_, _) => "auto",
            ResolvedBackend::Shim(_) => "pjrt-shim",
        }
    }
}
