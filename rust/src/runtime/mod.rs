//! PJRT runtime: loads the AOT HLO text artifacts produced by
//! `python/compile/aot.py` and executes them as the chunk backend.
//!
//! Flow per artifact (see /opt/xla-example/load_hlo): HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile` →
//! `PjRtLoadedExecutable`, compiled lazily and cached per
//! `(graph, dims, clusters)` on a dedicated device-owner thread
//! ([`server`]) because the `xla` crate types are `!Send`.
//!
//! [`PjrtRuntime`] implements [`crate::fcm::ChunkBackend`]: inputs are split
//! into fixed `chunk`-row pieces (the artifact's lowered shape), the last
//! piece zero-padded with zero weights (exactly ignored by the kernels —
//! the padding contract tested in `python/tests/test_kernel.py` and
//! `rust/tests/integration_runtime.rs`), partials merged host-side.

pub mod artifact;
pub mod executor;
pub mod server;

pub use artifact::{ArtifactMeta, Manifest};
pub use executor::ChunkExecutor;
pub use server::ServerStats;

use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::{ChunkBackend, NativeBackend, Partials};

/// Graph families in the artifact matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Graph {
    Fcm,
    Classic,
    Kmeans,
}

impl Graph {
    pub fn as_str(&self) -> &'static str {
        match self {
            Graph::Fcm => "fcm",
            Graph::Classic => "classic",
            Graph::Kmeans => "kmeans",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fcm" => Ok(Graph::Fcm),
            "classic" => Ok(Graph::Classic),
            "kmeans" => Ok(Graph::Kmeans),
            other => Err(Error::Artifact(format!("unknown graph `{other}`"))),
        }
    }
}

/// The PJRT-backed chunk backend: a `Send + Sync` handle to the device
/// thread.
pub struct PjrtRuntime {
    manifest: Manifest,
    tx: Mutex<Sender<server::Request>>,
}

impl PjrtRuntime {
    /// Open the artifact registry and start the device-owner thread.
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))?;
        let tx = server::spawn(artifacts_dir.to_path_buf(), manifest.clone());
        Ok(Self { manifest, tx: Mutex::new(tx) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The chunk row count all artifacts were lowered with.
    pub fn chunk(&self) -> usize {
        self.manifest.chunk
    }

    /// Whether an artifact exists for this shape.
    pub fn supports(&self, graph: Graph, dims: usize, clusters: usize) -> bool {
        self.manifest.find(graph, dims, clusters).is_some()
    }

    /// Aggregate execution statistics from the device thread.
    pub fn stats(&self) -> Result<ServerStats> {
        let (reply_tx, reply_rx) = channel();
        self.send(server::Request::Stats(reply_tx))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Xla("pjrt server thread is gone".into()))
    }

    fn send(&self, req: server::Request) -> Result<()> {
        self.tx
            .lock()
            .expect("pjrt sender poisoned")
            .send(req)
            .map_err(|_| Error::Xla("pjrt server thread is gone".into()))
    }

    fn run_chunked(
        &self,
        graph: Graph,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
    ) -> Result<Partials> {
        let d = x.cols();
        let c = v.rows();
        if !self.supports(graph, d, c) {
            return Err(Error::Artifact(format!(
                "no artifact for graph={} dims={d} clusters={c} — add the combo to \
                 python/compile/aot.py::SHAPES and re-run `make artifacts`",
                graph.as_str()
            )));
        }
        let chunk = self.manifest.chunk;
        let mut total = Partials::zeros(c, d);
        let mut start = 0usize;
        while start < x.rows() {
            let end = (start + chunk).min(x.rows());
            let live = end - start;
            // Marshal padded buffers (tail zeros are exactly ignored).
            let mut xbuf = vec![0.0f32; chunk * d];
            xbuf[..live * d].copy_from_slice(&x.as_slice()[start * d..end * d]);
            let mut wbuf = vec![0.0f32; chunk];
            wbuf[..live].copy_from_slice(&w[start..end]);
            let (reply_tx, reply_rx) = channel();
            self.send(server::Request::Run(
                server::ChunkRequest {
                    graph,
                    dims: d,
                    clusters: c,
                    x: xbuf,
                    v: v.as_slice().to_vec(),
                    w: wbuf,
                    m,
                },
                reply_tx,
            ))?;
            let partial = reply_rx
                .recv()
                .map_err(|_| Error::Xla("pjrt server thread is gone".into()))??;
            total.merge(&partial);
            start = end;
        }
        Ok(total)
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        let _ = self.send(server::Request::Shutdown);
    }
}

impl ChunkBackend for PjrtRuntime {
    fn fcm_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.run_chunked(Graph::Fcm, x, v, w, m)
    }

    fn classic_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.run_chunked(Graph::Classic, x, v, w, m)
    }

    fn kmeans_partials(&self, x: &Matrix, v: &Matrix, w: &[f32]) -> Result<Partials> {
        self.run_chunked(Graph::Kmeans, x, v, w, 0.0)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Backend resolved from config: PJRT artifacts when available, native
/// otherwise (or forced by `runtime.backend`).
pub enum ResolvedBackend {
    Pjrt(Arc<PjrtRuntime>),
    Native(NativeBackend),
    /// PJRT runtime with native fallback for unsupported shapes.
    Auto(Arc<PjrtRuntime>, NativeBackend),
}

impl ResolvedBackend {
    /// Resolve from config. `Auto` degrades to native (with no error) when
    /// the artifacts directory is missing.
    pub fn from_config(cfg: &crate::config::Config) -> Result<ResolvedBackend> {
        use crate::config::Backend;
        match cfg.backend {
            Backend::Native => Ok(ResolvedBackend::Native(NativeBackend)),
            Backend::Pjrt => {
                let rt = Arc::new(PjrtRuntime::open(&cfg.artifacts_dir)?);
                Ok(ResolvedBackend::Pjrt(rt))
            }
            Backend::Auto => match PjrtRuntime::open(&cfg.artifacts_dir) {
                Ok(rt) => Ok(ResolvedBackend::Auto(Arc::new(rt), NativeBackend)),
                Err(_) => Ok(ResolvedBackend::Native(NativeBackend)),
            },
        }
    }

    fn pick(&self, graph: Graph, dims: usize, clusters: usize) -> &dyn ChunkBackend {
        match self {
            ResolvedBackend::Pjrt(rt) => rt.as_ref(),
            ResolvedBackend::Native(nb) => nb,
            ResolvedBackend::Auto(rt, nb) => {
                if rt.supports(graph, dims, clusters) {
                    rt.as_ref()
                } else {
                    nb
                }
            }
        }
    }
}

impl ChunkBackend for ResolvedBackend {
    fn fcm_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.pick(Graph::Fcm, x.cols(), v.rows()).fcm_partials(x, v, w, m)
    }

    fn classic_partials(&self, x: &Matrix, v: &Matrix, w: &[f32], m: f64) -> Result<Partials> {
        self.pick(Graph::Classic, x.cols(), v.rows()).classic_partials(x, v, w, m)
    }

    fn kmeans_partials(&self, x: &Matrix, v: &Matrix, w: &[f32]) -> Result<Partials> {
        self.pick(Graph::Kmeans, x.cols(), v.rows()).kmeans_partials(x, v, w)
    }

    // Forward the pruned entry points to whatever backend the shape
    // resolves to, so Auto/Native resolutions keep real shift-bounded
    // pruning (a PJRT pick falls back to its exact default, which resets
    // the state — no stale bound can cross a backend switch).
    #[allow(clippy::too_many_arguments)]
    fn fcm_partials_pruned(
        &self,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut crate::fcm::BlockPruneState,
        tol: f64,
        refresh_every: usize,
    ) -> Result<(Partials, usize)> {
        self.pick(Graph::Fcm, x.cols(), v.rows())
            .fcm_partials_pruned(x, v, w, m, state, tol, refresh_every)
    }

    #[allow(clippy::too_many_arguments)]
    fn classic_partials_pruned(
        &self,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        m: f64,
        state: &mut crate::fcm::BlockPruneState,
        tol: f64,
        refresh_every: usize,
    ) -> Result<(Partials, usize)> {
        self.pick(Graph::Classic, x.cols(), v.rows())
            .classic_partials_pruned(x, v, w, m, state, tol, refresh_every)
    }

    fn kmeans_partials_pruned(
        &self,
        x: &Matrix,
        v: &Matrix,
        w: &[f32],
        state: &mut crate::fcm::BlockPruneState,
        tol: f64,
        refresh_every: usize,
    ) -> Result<(Partials, usize)> {
        self.pick(Graph::Kmeans, x.cols(), v.rows())
            .kmeans_partials_pruned(x, v, w, state, tol, refresh_every)
    }

    fn name(&self) -> &'static str {
        match self {
            ResolvedBackend::Pjrt(_) => "pjrt",
            ResolvedBackend::Native(_) => "native",
            ResolvedBackend::Auto(_, _) => "auto",
        }
    }
}
