//! The PJRT device-owner thread.
//!
//! The `xla` crate's client/executable types are `!Send` (Rc + raw
//! pointers), but map tasks run on a thread pool. So all PJRT state lives
//! on one dedicated thread — the pattern a real accelerator runtime uses —
//! and [`super::PjrtRuntime`] talks to it over a channel. CPU PJRT
//! parallelises execution internally (Eigen thread pool), so a single
//! dispatcher thread does not serialise the actual math.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::fcm::Partials;
use crate::runtime::executor::ChunkExecutor;
use crate::runtime::{Graph, Manifest};
use crate::xla;

/// One chunk execution request (buffers pre-padded by the caller).
pub struct ChunkRequest {
    pub graph: Graph,
    pub dims: usize,
    pub clusters: usize,
    /// chunk×dims, zero-padded.
    pub x: Vec<f32>,
    /// clusters×dims.
    pub v: Vec<f32>,
    /// chunk, zero-padded.
    pub w: Vec<f32>,
    pub m: f64,
}

/// Aggregate server statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub chunks: u64,
    pub exec_time: Duration,
    pub compiled: usize,
}

pub enum Request {
    Run(ChunkRequest, Sender<Result<Partials>>),
    Stats(Sender<ServerStats>),
    Shutdown,
}

/// Spawn the device-owner thread. Returns its request sender.
pub fn spawn(artifacts_dir: PathBuf, manifest: Manifest) -> Sender<Request> {
    let (tx, rx) = channel::<Request>();
    std::thread::Builder::new()
        .name("bigfcm-pjrt".to_string())
        .spawn(move || serve(artifacts_dir, manifest, rx))
        .expect("spawn pjrt server thread");
    tx
}

fn serve(artifacts_dir: PathBuf, manifest: Manifest, rx: Receiver<Request>) {
    // Client construction happens on the owner thread.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Serve errors to every request until shutdown.
            let msg = format!("pjrt client init failed: {e}");
            for req in rx {
                match req {
                    Request::Run(_, reply) => {
                        let _ = reply.send(Err(Error::Xla(msg.clone())));
                    }
                    Request::Stats(reply) => {
                        let _ = reply.send(ServerStats::default());
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };

    let mut executors: HashMap<(Graph, usize, usize), ChunkExecutor> = HashMap::new();
    let mut stats = ServerStats::default();

    for req in rx {
        match req {
            Request::Shutdown => break,
            Request::Stats(reply) => {
                stats.compiled = executors.len();
                let _ = reply.send(stats.clone());
            }
            Request::Run(cr, reply) => {
                let key = (cr.graph, cr.dims, cr.clusters);
                // Compile on first use.
                if !executors.contains_key(&key) {
                    let meta = match manifest.find(cr.graph, cr.dims, cr.clusters) {
                        Some(m) => m.clone(),
                        None => {
                            let _ = reply.send(Err(Error::Artifact(format!(
                                "no artifact for {} d={} c={}",
                                cr.graph.as_str(),
                                cr.dims,
                                cr.clusters
                            ))));
                            continue;
                        }
                    };
                    let path = artifacts_dir.join(&meta.file);
                    match ChunkExecutor::compile(&client, &path, meta) {
                        Ok(exec) => {
                            executors.insert(key, exec);
                        }
                        Err(e) => {
                            let _ = reply.send(Err(e));
                            continue;
                        }
                    }
                }
                let exec = executors.get(&key).expect("just inserted");
                let t0 = std::time::Instant::now();
                let out = exec.execute_padded(&cr.x, &cr.v, &cr.w, cr.m);
                stats.exec_time += t0.elapsed();
                stats.chunks += 1;
                let _ = reply.send(out);
            }
        }
    }
}
