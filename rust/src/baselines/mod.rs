//! Mahout-style baselines: K-Means and Fuzzy K-Means driven the way Apache
//! Mahout drives them on Hadoop — **one MapReduce job per iteration**, with
//! randomly seeded initial centers. This is the comparison system of every
//! table in the paper; the per-iteration job launch is exactly why BigFCM's
//! single-job design wins (Tables 3–6).
//!
//! Each iteration job: map tasks compute partial sufficient statistics for
//! their block against the current centers (from the distributed cache);
//! the reducer merges partials and emits the new centers; the driver then
//! launches the next job until the epsilon criterion or the iteration cap.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::seeding::random_records;
use crate::fcm::{max_center_shift2, KernelBackend, Partials};
use crate::hdfs::BlockStore;
use crate::mapreduce::{DistributedCache, Engine, MapReduceJob, SessionOptions, SimCost, TaskCtx};
use crate::prng::Pcg;

/// Which baseline algorithm an iteration job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineAlgo {
    /// Mahout K-Means (hard assignment).
    KMeans,
    /// Mahout Fuzzy K-Means (classic FCM memberships, O(n·c²)).
    FuzzyKMeans,
}

impl BaselineAlgo {
    pub fn as_str(&self) -> &'static str {
        match self {
            BaselineAlgo::KMeans => "mahout-km",
            BaselineAlgo::FuzzyKMeans => "mahout-fkm",
        }
    }
}

impl std::str::FromStr for BaselineAlgo {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "km" | "kmeans" => Ok(BaselineAlgo::KMeans),
            "fkm" | "fuzzy" => Ok(BaselineAlgo::FuzzyKMeans),
            other => Err(Error::InvalidArgument(format!(
                "unknown baseline `{other}` (km|fkm)"
            ))),
        }
    }
}

/// Result of a full baseline run.
#[derive(Clone, Debug)]
pub struct BaselineRun {
    pub algo: BaselineAlgo,
    pub centers: Matrix,
    pub iterations: usize,
    pub converged: bool,
    /// One MR job per iteration — this is the cost driver.
    pub jobs: usize,
    pub wall: Duration,
    pub sim: SimCost,
    pub objective: f64,
}

impl BaselineRun {
    pub fn modelled_s(&self) -> f64 {
        self.sim.total_s()
    }
}

/// The per-iteration MR job: one pass of partials against fixed centers.
struct IterationJob {
    algo: BaselineAlgo,
    m: f64,
    backend: Arc<dyn KernelBackend>,
}

const KEY_CENTERS: &str = "baseline_centers";

impl MapReduceJob for IterationJob {
    type MapOut = Partials;
    type Output = Partials;

    fn map_combine(&self, block: &Matrix, ctx: &TaskCtx) -> Result<Partials> {
        let v = ctx
            .cache
            .get_matrix(KEY_CENTERS)
            .ok_or_else(|| Error::Job("baseline centers missing from cache".into()))?;
        let w = vec![1.0f32; block.rows()];
        match self.algo {
            BaselineAlgo::KMeans => self.backend.kmeans_partials(block, &v, &w),
            // Mahout FKM runs the classic O(n·c²) membership math — the
            // pair-loop kernel, deliberately NOT the fused O(n·c) path the
            // pipeline uses, so the baseline's compute model stays honest.
            BaselineAlgo::FuzzyKMeans => self.backend.classic_partials_pair(block, &v, &w, self.m),
        }
    }

    fn reduce(&self, parts: Vec<Partials>, _ctx: &TaskCtx) -> Result<Partials> {
        let mut it = parts.into_iter();
        let mut acc = it
            .next()
            .ok_or_else(|| Error::Job("no partials to reduce".into()))?;
        for p in it {
            acc.merge(&p);
        }
        Ok(acc)
    }

    // `Partials` merge pairwise — but the baseline runner pins the flat
    // reduce (`SessionOptions::per_job`) so the Mahout model stays honest;
    // the combiner is only exercised when a caller opts a baseline job
    // into a tree-combining engine explicitly.
    fn supports_combine(&self) -> bool {
        true
    }

    fn combine(&self, mut left: Partials, right: Partials) -> Result<Partials> {
        left.merge(&right);
        Ok(left)
    }

    fn shuffle_bytes(&self, part: &Partials) -> u64 {
        part.encoded_bytes()
    }

    fn name(&self) -> &str {
        self.algo.as_str()
    }
}

/// Run a Mahout-style baseline to convergence, one MR job per iteration.
/// Iteration jobs stream the same store, so the engine's block cache keeps
/// hot blocks decoded across iterations.
pub fn run_baseline(
    algo: BaselineAlgo,
    cfg: &Config,
    store: &Arc<BlockStore>,
    backend: Arc<dyn KernelBackend>,
    engine: &mut Engine,
) -> Result<BaselineRun> {
    let started = Instant::now();
    let sim_before = engine.clock().cost();
    let mut rng = Pcg::new(cfg.seed ^ 0xBA5E11E5);

    // Mahout seeds with random records (its RandomSeedGenerator job — we
    // charge one extra job's startup for it, as Mahout pays).
    let sample = store.sample_records(cfg.fcm.clusters * 8, &mut rng)?;
    let mut centers = random_records(&sample, cfg.fcm.clusters, &mut rng);
    engine.charge_scan(store.total_bytes() / store.num_blocks().max(1) as u64);

    let job = Arc::new(IterationJob {
        algo,
        m: cfg.fcm.fuzzifier,
        backend,
    });

    // The baselines run through the session API like every iterative
    // caller now does, but with the per-job control options: full job
    // startup every iteration and the flat reduce funnel — exactly how
    // Mahout drives Hadoop, and the A/B control for the
    // iteration-resident session loop (`fcm::loops::run_fcm_session`).
    let mut session = engine.session(store, SessionOptions::per_job());
    let mut iterations = 0usize;
    let mut converged = false;
    let mut objective = f64::INFINITY;
    for it in 1..=cfg.fcm.max_iterations {
        iterations = it;
        // Fresh cache per job (Hadoop re-distributes it each submission).
        let cache = Arc::new(DistributedCache::new());
        cache.put_matrix(KEY_CENTERS, centers.clone());
        let (partials, _stats) = session.run_iteration(Arc::clone(&job), cache)?;
        objective = partials.objective;
        let new_centers = partials.into_centers(&centers);
        let shift = max_center_shift2(&centers, &new_centers);
        centers = new_centers;
        if shift <= cfg.fcm.epsilon {
            converged = true;
            break;
        }
    }
    drop(session);

    // Report only this run's share when the engine is reused.
    let sim = engine.clock().cost().delta(&sim_before);

    Ok(BaselineRun {
        algo,
        centers,
        iterations,
        converged,
        jobs: iterations,
        wall: started.elapsed(),
        sim,
        objective,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::NativeBackend;
    use crate::mapreduce::EngineOptions;

    fn setup(c: usize, eps: f64) -> (Config, Arc<BlockStore>, Engine) {
        let mut cfg = Config::default();
        cfg.fcm.clusters = c;
        cfg.fcm.epsilon = eps;
        cfg.fcm.max_iterations = 200;
        let data = blobs(1200, 3, c, 0.2, 11);
        let store = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
        let engine = Engine::new(EngineOptions::default(), cfg.overhead.clone());
        (cfg, store, engine)
    }

    #[test]
    fn kmeans_baseline_converges_on_blobs() {
        let (cfg, store, mut engine) = setup(3, 1e-9);
        let r = run_baseline(BaselineAlgo::KMeans, &cfg, &store, Arc::new(NativeBackend), &mut engine)
            .unwrap();
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        assert_eq!(r.jobs, r.iterations);
        // Modelled time includes one job startup per iteration.
        assert!(r.sim.job_startup_s >= cfg.overhead.job_startup_s * r.jobs as f64 * 0.99);
    }

    #[test]
    fn fkm_baseline_converges_on_blobs() {
        let (cfg, store, mut engine) = setup(3, 1e-7);
        let r = run_baseline(
            BaselineAlgo::FuzzyKMeans,
            &cfg,
            &store,
            Arc::new(NativeBackend),
            &mut engine,
        )
        .unwrap();
        assert!(r.converged);
        assert!(r.objective.is_finite());
    }

    #[test]
    fn tighter_epsilon_needs_more_jobs() {
        let (mut cfg, store, _) = setup(3, 0.0);
        cfg.fcm.epsilon = 5e-2;
        let mut e1 = Engine::new(EngineOptions::default(), cfg.overhead.clone());
        let loose = run_baseline(
            BaselineAlgo::FuzzyKMeans,
            &cfg,
            &store,
            Arc::new(NativeBackend),
            &mut e1,
        )
        .unwrap();
        cfg.fcm.epsilon = 5e-9;
        let mut e2 = Engine::new(EngineOptions::default(), cfg.overhead.clone());
        let tight = run_baseline(
            BaselineAlgo::FuzzyKMeans,
            &cfg,
            &store,
            Arc::new(NativeBackend),
            &mut e2,
        )
        .unwrap();
        assert!(
            tight.jobs > loose.jobs,
            "tight {} vs loose {}",
            tight.jobs,
            loose.jobs
        );
        assert!(tight.modelled_s() > loose.modelled_s());
    }

    #[test]
    fn per_run_sim_share_isolated_on_shared_engine() {
        let (cfg, store, mut engine) = setup(3, 1e-6);
        let a = run_baseline(BaselineAlgo::KMeans, &cfg, &store, Arc::new(NativeBackend), &mut engine)
            .unwrap();
        let b = run_baseline(BaselineAlgo::KMeans, &cfg, &store, Arc::new(NativeBackend), &mut engine)
            .unwrap();
        // Same dataset + same seed → identical share both times.
        assert!((a.modelled_s() - b.modelled_s()).abs() < a.modelled_s() * 0.05);
    }
}
