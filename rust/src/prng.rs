//! Deterministic pseudo-random number generation.
//!
//! The crate is fully offline (no `rand` dependency), so we carry our own
//! small, well-known generators: SplitMix64 for seeding and xoshiro256++ as
//! the workhorse, plus the distribution samplers the data generators and
//! seeding strategies need (uniform, normal via Ziggurat-free Box–Muller,
//! shuffles, reservoir helpers).
//!
//! Everything in the repository that involves randomness threads one of
//! these PRNGs explicitly — experiments are reproducible from a single seed.

/// SplitMix64: used to expand a user seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Pcg {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Pcg {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-worker/per-partition RNGs).
    pub fn fork(&mut self, stream: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Pick an index from unnormalised non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.next_index(weights.len());
        }
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&y));
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut r = Pcg::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            // Expect 10_000 each; allow ±5%.
            assert!((9_500..10_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg::new(13);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn sample_indices_full_population() {
        let mut r = Pcg::new(14);
        let mut idx = r.sample_indices(10, 10);
        idx.sort_unstable();
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(15);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Pcg::new(17);
        let w = [0.0, 0.0, 1.0, 3.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 0);
        let ratio = counts[3] as f64 / counts[2] as f64;
        assert!((2.7..3.3).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Pcg::new(21);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
