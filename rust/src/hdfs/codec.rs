//! Binary block file codec (`.bfb` — "bigfcm block").
//!
//! Layout: magic `BFCMBLK1` (8 bytes), rows u32 LE, cols u32 LE, then
//! rows·cols f32 LE. Checksummed with a trailing FNV-1a u64 of the payload
//! so corrupt blocks fail loudly (HDFS does the same with CRCs).

use std::io::{Read, Write};
use std::path::Path;

use crate::data::Matrix;
use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"BFCMBLK1";

/// FNV-1a over a byte payload — the checksum discipline every on-disk
/// artifact of this crate uses (block files here, slab spill images in
/// `crate::fcm::backend`), so corruption fails loudly instead of feeding
/// silently wrong numbers back into the math.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Serialised size in bytes of a block holding `m`.
pub fn encoded_size(m: &Matrix) -> u64 {
    (8 + 4 + 4 + m.rows() * m.cols() * 4 + 8) as u64
}

/// Write a block file; returns bytes written.
pub fn write_block_file(path: &Path, m: &Matrix) -> Result<u64> {
    let mut payload = Vec::with_capacity(m.rows() * m.cols() * 4 + 8);
    payload.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    payload.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for &v in m.as_slice() {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let checksum = fnv1a(&payload);
    let mut f = std::fs::File::create(path).map_err(|e| Error::io(path, e))?;
    f.write_all(MAGIC).map_err(|e| Error::io(path, e))?;
    f.write_all(&payload).map_err(|e| Error::io(path, e))?;
    f.write_all(&checksum.to_le_bytes())
        .map_err(|e| Error::io(path, e))?;
    Ok(encoded_size(m))
}

/// Read only a block file's header — magic plus (rows, cols) — and verify
/// the file length against the declared shape, without the full payload
/// checksum pass. Manifest recovery for [`crate::hdfs::BlockStore::open_disk`]:
/// opening a store of thousands of blocks reads 16 bytes per block instead
/// of the whole store; corruption inside the payload still fails loudly at
/// [`read_block_file`] time, exactly like HDFS verifying CRCs on read.
pub fn read_block_header(path: &Path) -> Result<(usize, usize, u64)> {
    let mut f = std::fs::File::open(path).map_err(|e| Error::io(path, e))?;
    let mut head = [0u8; 16];
    f.read_exact(&mut head).map_err(|e| Error::io(path, e))?;
    if &head[..8] != MAGIC {
        return Err(Error::BlockStore(format!("{}: bad magic", path.display())));
    }
    let rows = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(head[12..16].try_into().unwrap()) as usize;
    let len = f.metadata().map_err(|e| Error::io(path, e))?.len();
    let expect = (8 + 4 + 4 + rows * cols * 4 + 8) as u64;
    if len != expect {
        return Err(Error::BlockStore(format!(
            "{}: file is {len} B, header shape ({rows} x {cols}) implies {expect}",
            path.display()
        )));
    }
    Ok((rows, cols, len))
}

/// Read and verify a block file.
pub fn read_block_file(path: &Path) -> Result<Matrix> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| Error::io(path, e))?
        .read_to_end(&mut bytes)
        .map_err(|e| Error::io(path, e))?;
    if bytes.len() < 8 + 8 + 8 || &bytes[..8] != MAGIC {
        return Err(Error::BlockStore(format!("{}: bad magic/short file", path.display())));
    }
    let payload = &bytes[8..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv1a(payload) != stored {
        return Err(Error::BlockStore(format!("{}: checksum mismatch", path.display())));
    }
    let rows = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    let cols = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    let expect = rows * cols * 4;
    let data = &payload[8..];
    if data.len() != expect {
        return Err(Error::BlockStore(format!(
            "{}: payload {} != expected {expect}",
            path.display(),
            data.len()
        )));
    }
    let mut values = Vec::with_capacity(rows * cols);
    for chunk in data.chunks_exact(4) {
        values.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(Matrix::from_vec(values, rows, cols))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("bigfcm_codec_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let m = Matrix::from_rows(&[vec![1.5, -2.0], vec![0.0, 3.25]]);
        let p = tmp("rt.bfb");
        let bytes = write_block_file(&p, &m).unwrap();
        assert_eq!(bytes, encoded_size(&m));
        let back = read_block_file(&p).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn detects_corruption() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let p = tmp("bad.bfb");
        write_block_file(&p, &m).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_block_file(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn header_read_recovers_shape_without_payload_pass() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let p = tmp("head.bfb");
        let bytes = write_block_file(&p, &m).unwrap();
        let (rows, cols, len) = read_block_header(&p).unwrap();
        assert_eq!((rows, cols, len), (2, 3, bytes));
        // Truncated payload: the length check must fail loudly.
        let img = std::fs::read(&p).unwrap();
        std::fs::write(&p, &img[..img.len() - 4]).unwrap();
        assert!(read_block_header(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let p = tmp("magic.bfb");
        std::fs::write(&p, b"NOTABLOCKFILE_____________").unwrap();
        assert!(read_block_file(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
