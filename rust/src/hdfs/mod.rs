//! HDFS-like block store substrate.
//!
//! The paper's pipeline reads record blocks out of HDFS, one map task per
//! block. This module provides that substrate on a single machine: a
//! dataset is split into fixed-record-count blocks, each block stored
//! either on disk (binary f32 format + manifest, exercising real I/O) or in
//! memory (for benches isolating compute). The namenode-equivalent is the
//! [`BlockStore`] manifest; locality hints assign each block a preferred
//! worker the scheduler honours.

mod codec;

pub use codec::{read_block_file, write_block_file};

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::Matrix;
use crate::error::{Error, Result};

/// Monotonic store id source — every [`BlockStore`] gets a process-unique
/// id so block caches can key on `(store, block)` without aliasing between
/// stores.
static NEXT_STORE_UID: AtomicU64 = AtomicU64::new(1);

/// Metadata of one stored block.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub id: usize,
    pub rows: usize,
    /// Preferred worker (locality hint).
    pub preferred_worker: usize,
    /// Byte size of the serialised block (drives modelled HDFS I/O cost).
    pub bytes: u64,
}

enum Storage {
    Memory(Vec<Matrix>),
    Disk { dir: PathBuf },
}

/// A sharded, immutable dataset: the namenode view plus block access.
///
/// Immutable after construction and internally unshared, so it is `Sync`
/// and cheap to hand to the map-task pool behind an `Arc` — the engine's
/// streaming pipeline reads blocks from worker threads.
pub struct BlockStore {
    uid: u64,
    name: String,
    cols: usize,
    total_rows: usize,
    blocks: Vec<BlockMeta>,
    storage: Storage,
}

impl BlockStore {
    /// Shard `features` into in-memory blocks of `block_records` rows.
    pub fn in_memory(
        name: impl Into<String>,
        features: &Matrix,
        block_records: usize,
        workers: usize,
    ) -> Result<Self> {
        let (metas, mats) = shard(features, block_records, workers)?;
        Ok(Self {
            uid: NEXT_STORE_UID.fetch_add(1, Ordering::Relaxed),
            name: name.into(),
            cols: features.cols(),
            total_rows: features.rows(),
            blocks: metas,
            storage: Storage::Memory(mats),
        })
    }

    /// Shard `features` into binary block files under `dir` (created).
    pub fn on_disk(
        name: impl Into<String>,
        features: &Matrix,
        block_records: usize,
        workers: usize,
        dir: PathBuf,
    ) -> Result<Self> {
        std::fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        let (mut metas, mats) = shard(features, block_records, workers)?;
        for (meta, mat) in metas.iter_mut().zip(&mats) {
            let path = dir.join(format!("block_{:06}.bfb", meta.id));
            let bytes = write_block_file(&path, mat)?;
            meta.bytes = bytes;
        }
        Ok(Self {
            uid: NEXT_STORE_UID.fetch_add(1, Ordering::Relaxed),
            name: name.into(),
            cols: features.cols(),
            total_rows: features.rows(),
            blocks: metas,
            storage: Storage::Disk { dir },
        })
    }

    /// Process-unique store id (block-cache key component).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn total_rows(&self) -> usize {
        self.total_rows
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn blocks(&self) -> &[BlockMeta] {
        &self.blocks
    }

    /// Total serialised bytes (drives the modelled scan cost).
    pub fn total_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.bytes).sum()
    }

    /// Fetch a block's records.
    pub fn read_block(&self, id: usize) -> Result<Matrix> {
        if id >= self.blocks.len() {
            return Err(Error::BlockStore(format!("block {id} out of range")));
        }
        match &self.storage {
            Storage::Memory(mats) => Ok(mats[id].clone()),
            Storage::Disk { dir } => {
                let path = dir.join(format!("block_{id:06}.bfb"));
                read_block_file(&path)
            }
        }
    }

    /// Uniformly sample `k` records across blocks (used by the driver job;
    /// reservoir-equivalent because block sizes are known).
    pub fn sample_records(&self, k: usize, rng: &mut crate::prng::Pcg) -> Result<Matrix> {
        let k = k.min(self.total_rows);
        let idx = rng.sample_indices(self.total_rows, k);
        let mut sorted = idx;
        sorted.sort_unstable();
        let mut out = Matrix::zeros(k, self.cols);
        let mut cursor = 0usize; // global row offset of current block
        let mut bi = 0usize;
        let mut current: Option<Matrix> = None;
        for (slot, &global) in sorted.iter().enumerate() {
            // Advance to the block containing `global`.
            while global >= cursor + self.blocks[bi].rows {
                cursor += self.blocks[bi].rows;
                bi += 1;
                current = None;
            }
            if current.is_none() {
                current = Some(self.read_block(bi)?);
            }
            let local = global - cursor;
            out.row_mut(slot)
                .copy_from_slice(current.as_ref().unwrap().row(local));
        }
        Ok(out)
    }
}

fn shard(
    features: &Matrix,
    block_records: usize,
    workers: usize,
) -> Result<(Vec<BlockMeta>, Vec<Matrix>)> {
    if features.rows() == 0 {
        return Err(Error::BlockStore("cannot shard an empty dataset".into()));
    }
    if block_records == 0 {
        return Err(Error::BlockStore("block_records must be positive".into()));
    }
    let workers = workers.max(1);
    let mut metas = Vec::new();
    let mut mats = Vec::new();
    let mut start = 0usize;
    let mut id = 0usize;
    while start < features.rows() {
        let end = (start + block_records).min(features.rows());
        let mat = features.slice_rows(start, end);
        metas.push(BlockMeta {
            id,
            rows: mat.rows(),
            preferred_worker: id % workers,
            // In-memory blocks model the same bytes as the binary codec.
            bytes: codec::encoded_size(&mat),
        });
        mats.push(mat);
        start = end;
        id += 1;
    }
    Ok((metas, mats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::prng::Pcg;

    #[test]
    fn shards_cover_all_rows() {
        let d = blobs(1000, 4, 2, 0.3, 1);
        let s = BlockStore::in_memory("t", &d.features, 300, 4).unwrap();
        assert_eq!(s.num_blocks(), 4);
        assert_eq!(s.blocks()[3].rows, 100);
        let total: usize = s.blocks().iter().map(|b| b.rows).sum();
        assert_eq!(total, 1000);
        // Round-trip a row.
        let b2 = s.read_block(2).unwrap();
        assert_eq!(b2.row(0), d.features.row(600));
    }

    #[test]
    fn disk_roundtrip() {
        let d = blobs(500, 3, 2, 0.3, 2);
        let dir = std::env::temp_dir().join(format!("bigfcm_bs_{}", std::process::id()));
        let s = BlockStore::on_disk("t", &d.features, 128, 2, dir.clone()).unwrap();
        assert_eq!(s.num_blocks(), 4);
        for b in 0..4 {
            let m = s.read_block(b).unwrap();
            assert_eq!(m.cols(), 3);
            assert_eq!(m.row(0), d.features.row(b * 128));
        }
        assert!(s.total_bytes() > 500 * 3 * 4);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn locality_hints_round_robin() {
        let d = blobs(600, 2, 2, 0.3, 3);
        let s = BlockStore::in_memory("t", &d.features, 100, 3).unwrap();
        let hints: Vec<usize> = s.blocks().iter().map(|b| b.preferred_worker).collect();
        assert_eq!(hints, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn sampling_returns_real_records() {
        let d = blobs(400, 3, 2, 0.3, 4);
        let s = BlockStore::in_memory("t", &d.features, 64, 2).unwrap();
        let mut rng = Pcg::new(5);
        let sample = s.sample_records(50, &mut rng).unwrap();
        assert_eq!(sample.rows(), 50);
        for i in 0..50 {
            let found = (0..400).any(|j| d.features.row(j) == sample.row(i));
            assert!(found, "sampled row {i} is not a dataset record");
        }
    }

    #[test]
    fn sample_more_than_population_clamps() {
        let d = blobs(20, 2, 2, 0.3, 5);
        let s = BlockStore::in_memory("t", &d.features, 7, 2).unwrap();
        let mut rng = Pcg::new(6);
        let sample = s.sample_records(100, &mut rng).unwrap();
        assert_eq!(sample.rows(), 20);
    }

    #[test]
    fn rejects_empty_and_zero_block() {
        let empty = Matrix::zeros(0, 3);
        assert!(BlockStore::in_memory("t", &empty, 10, 1).is_err());
        let d = blobs(10, 2, 2, 0.3, 7);
        assert!(BlockStore::in_memory("t", &d.features, 0, 1).is_err());
    }

    #[test]
    fn store_uids_are_unique() {
        let d = blobs(20, 2, 2, 0.3, 9);
        let a = BlockStore::in_memory("a", &d.features, 10, 1).unwrap();
        let b = BlockStore::in_memory("b", &d.features, 10, 1).unwrap();
        assert_ne!(a.uid(), b.uid());
    }

    #[test]
    fn out_of_range_block_errors() {
        let d = blobs(10, 2, 2, 0.3, 8);
        let s = BlockStore::in_memory("t", &d.features, 5, 1).unwrap();
        assert!(s.read_block(2).is_err());
    }
}
