//! HDFS-like block store substrate.
//!
//! The paper's pipeline reads record blocks out of HDFS, one map task per
//! block. This module provides that substrate on a single machine: a
//! dataset is split into fixed-record-count blocks, each block stored
//! either on disk (binary f32 format + manifest, exercising real I/O) or in
//! memory (for benches isolating compute). The namenode-equivalent is the
//! [`BlockStore`] manifest; locality hints assign each block a preferred
//! worker the scheduler honours.

mod codec;

pub use codec::{fnv1a, read_block_file, read_block_header, write_block_file};

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::Matrix;
use crate::error::{Error, Result};

/// Monotonic store id source — every [`BlockStore`] gets a process-unique
/// id so block caches can key on `(store, block)` without aliasing between
/// stores.
static NEXT_STORE_UID: AtomicU64 = AtomicU64::new(1);

/// Metadata of one stored block.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub id: usize,
    pub rows: usize,
    /// Preferred worker (locality hint).
    pub preferred_worker: usize,
    /// Byte size of the serialised block (drives modelled HDFS I/O cost).
    pub bytes: u64,
}

enum Storage {
    Memory(Vec<Matrix>),
    Disk { dir: PathBuf },
}

/// A sharded, immutable dataset: the namenode view plus block access.
///
/// Immutable after construction and internally unshared, so it is `Sync`
/// and cheap to hand to the map-task pool behind an `Arc` — the engine's
/// streaming pipeline reads blocks from worker threads.
pub struct BlockStore {
    uid: u64,
    name: String,
    cols: usize,
    total_rows: usize,
    blocks: Vec<BlockMeta>,
    storage: Storage,
}

impl BlockStore {
    /// Shard `features` into in-memory blocks of `block_records` rows.
    pub fn in_memory(
        name: impl Into<String>,
        features: &Matrix,
        block_records: usize,
        workers: usize,
    ) -> Result<Self> {
        let (metas, mats) = shard(features, block_records, workers)?;
        Ok(Self {
            uid: NEXT_STORE_UID.fetch_add(1, Ordering::Relaxed),
            name: name.into(),
            cols: features.cols(),
            total_rows: features.rows(),
            blocks: metas,
            storage: Storage::Memory(mats),
        })
    }

    /// Shard `features` into binary block files under `dir` (created).
    /// Built on [`BlockStoreWriter`] so the on-disk layout has exactly one
    /// implementation; prefer the writer directly for datasets too large
    /// to materialize.
    pub fn on_disk(
        name: impl Into<String>,
        features: &Matrix,
        block_records: usize,
        workers: usize,
        dir: PathBuf,
    ) -> Result<Self> {
        if features.rows() == 0 {
            return Err(Error::BlockStore("cannot shard an empty dataset".into()));
        }
        if block_records == 0 {
            return Err(Error::BlockStore("block_records must be positive".into()));
        }
        let mut writer = BlockStoreWriter::create(name, features.cols(), workers, dir)?;
        let mut start = 0usize;
        while start < features.rows() {
            let end = (start + block_records).min(features.rows());
            writer.append(&features.slice_rows(start, end))?;
            start = end;
        }
        writer.finish()
    }

    /// Reopen a store previously written under `dir` (by
    /// [`BlockStoreWriter`] or [`BlockStore::on_disk`]) from its block
    /// files alone — the manifest is recovered from per-block headers
    /// ([`read_block_header`], 16 bytes each), so a labeled membership
    /// store written by the bulk ScoreJob (or any block store) can be
    /// served again in a later process without rewriting anything.
    pub fn open_disk(name: impl Into<String>, workers: usize, dir: PathBuf) -> Result<Self> {
        let workers = workers.max(1);
        let mut metas = Vec::new();
        let mut cols = 0usize;
        let mut total_rows = 0usize;
        loop {
            let id = metas.len();
            let path = dir.join(format!("block_{id:06}.bfb"));
            if !path.exists() {
                break;
            }
            let (rows, bcols, bytes) = codec::read_block_header(&path)?;
            if id == 0 {
                cols = bcols;
            } else if bcols != cols {
                return Err(Error::BlockStore(format!(
                    "{}: block {id} has {bcols} cols, store has {cols}",
                    dir.display()
                )));
            }
            metas.push(BlockMeta { id, rows, preferred_worker: id % workers, bytes });
            total_rows += rows;
        }
        if metas.is_empty() {
            return Err(Error::BlockStore(format!(
                "{}: no block files (block_000000.bfb missing)",
                dir.display()
            )));
        }
        // A gap must fail loudly, not silently truncate the store: a
        // partially copied or corrupted directory can be missing one
        // mid-range block while later blocks survive — serving the prefix
        // as if it were the whole store would be silent data loss.
        for entry in std::fs::read_dir(&dir).map_err(|e| Error::io(&dir, e))? {
            let entry = entry.map_err(|e| Error::io(&dir, e))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(id) = name
                .strip_prefix("block_")
                .and_then(|s| s.strip_suffix(".bfb"))
                .and_then(|s| s.parse::<usize>().ok())
            {
                if id >= metas.len() {
                    return Err(Error::BlockStore(format!(
                        "{}: found {name} but block_{:06}.bfb is missing — the store has a gap",
                        dir.display(),
                        metas.len()
                    )));
                }
            }
        }
        Ok(Self {
            uid: NEXT_STORE_UID.fetch_add(1, Ordering::Relaxed),
            name: name.into(),
            cols,
            total_rows,
            blocks: metas,
            storage: Storage::Disk { dir },
        })
    }

    /// Process-unique store id (block-cache key component).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn total_rows(&self) -> usize {
        self.total_rows
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn blocks(&self) -> &[BlockMeta] {
        &self.blocks
    }

    /// Total serialised bytes (drives the modelled scan cost).
    pub fn total_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.bytes).sum()
    }

    /// Largest serialised block (the per-worker term of the streaming
    /// residency envelope `budget + workers × max_block_bytes`).
    pub fn max_block_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.bytes).max().unwrap_or(0)
    }

    /// Fetch a block's records.
    pub fn read_block(&self, id: usize) -> Result<Matrix> {
        if id >= self.blocks.len() {
            return Err(Error::BlockStore(format!("block {id} out of range")));
        }
        match &self.storage {
            Storage::Memory(mats) => Ok(mats[id].clone()),
            Storage::Disk { dir } => {
                let path = dir.join(format!("block_{id:06}.bfb"));
                read_block_file(&path)
            }
        }
    }

    /// Uniformly sample `k` records across blocks (used by the driver job;
    /// reservoir-equivalent because block sizes are known).
    pub fn sample_records(&self, k: usize, rng: &mut crate::prng::Pcg) -> Result<Matrix> {
        let k = k.min(self.total_rows);
        let idx = rng.sample_indices(self.total_rows, k);
        let mut sorted = idx;
        sorted.sort_unstable();
        let mut out = Matrix::zeros(k, self.cols);
        let mut cursor = 0usize; // global row offset of current block
        let mut bi = 0usize;
        let mut current: Option<Matrix> = None;
        for (slot, &global) in sorted.iter().enumerate() {
            // Advance to the block containing `global`.
            while global >= cursor + self.blocks[bi].rows {
                cursor += self.blocks[bi].rows;
                bi += 1;
                current = None;
            }
            if current.is_none() {
                current = Some(self.read_block(bi)?);
            }
            let local = global - cursor;
            out.row_mut(slot)
                .copy_from_slice(current.as_ref().unwrap().row(local));
        }
        Ok(out)
    }
}

/// Incremental on-disk store builder for datasets too large to materialize:
/// blocks are generated, written and dropped one at a time, so building a
/// multi-GiB store needs only one block of memory at a time (the scale
/// harness's generator path, `examples/scale_susy.rs`).
///
/// ```no_run
/// # use bigfcm::hdfs::BlockStoreWriter;
/// # use bigfcm::data::Matrix;
/// let mut w = BlockStoreWriter::create("susy", 18, 4, "/tmp/susy".into()).unwrap();
/// for _ in 0..100 {
///     let block = Matrix::zeros(65_536, 18); // generate one block
///     w.append(&block).unwrap();             // write it, drop it
/// }
/// let store = w.finish().unwrap();
/// ```
pub struct BlockStoreWriter {
    name: String,
    dir: PathBuf,
    cols: usize,
    workers: usize,
    metas: Vec<BlockMeta>,
    total_rows: usize,
}

impl BlockStoreWriter {
    /// Start a store under `dir` (created). Blocks appended later must all
    /// have `cols` columns; locality hints round-robin over `workers`.
    pub fn create(
        name: impl Into<String>,
        cols: usize,
        workers: usize,
        dir: PathBuf,
    ) -> Result<Self> {
        if cols == 0 {
            return Err(Error::BlockStore("cols must be positive".into()));
        }
        std::fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        Ok(Self {
            name: name.into(),
            dir,
            cols,
            workers: workers.max(1),
            metas: Vec::new(),
            total_rows: 0,
        })
    }

    /// Write one block file and record its manifest entry; returns the
    /// block id. The caller drops `block` afterwards — nothing is retained.
    pub fn append(&mut self, block: &Matrix) -> Result<usize> {
        if block.cols() != self.cols {
            return Err(Error::BlockStore(format!(
                "block has {} cols, store expects {}",
                block.cols(),
                self.cols
            )));
        }
        if block.rows() == 0 {
            return Err(Error::BlockStore("cannot append an empty block".into()));
        }
        let id = self.metas.len();
        let path = self.dir.join(format!("block_{id:06}.bfb"));
        let bytes = write_block_file(&path, block)?;
        self.metas.push(BlockMeta {
            id,
            rows: block.rows(),
            preferred_worker: id % self.workers,
            bytes,
        });
        self.total_rows += block.rows();
        Ok(id)
    }

    pub fn num_blocks(&self) -> usize {
        self.metas.len()
    }

    pub fn total_rows(&self) -> usize {
        self.total_rows
    }

    /// Serialised bytes written so far.
    pub fn total_bytes(&self) -> u64 {
        self.metas.iter().map(|b| b.bytes).sum()
    }

    /// Seal the manifest into a readable store.
    pub fn finish(self) -> Result<BlockStore> {
        if self.metas.is_empty() {
            return Err(Error::BlockStore("store has no blocks".into()));
        }
        Ok(BlockStore {
            uid: NEXT_STORE_UID.fetch_add(1, Ordering::Relaxed),
            name: self.name,
            cols: self.cols,
            total_rows: self.total_rows,
            blocks: self.metas,
            storage: Storage::Disk { dir: self.dir },
        })
    }
}

/// Slot path of `block` in a spill-ring directory — the on-disk layout of
/// the session slab's state ring ([`crate::mapreduce::StateSlab`]): one
/// slot file per block id, overwritten in place on re-spill, the same
/// block-file-per-id discipline [`BlockStoreWriter`] uses for record
/// blocks, applied to opaque state images.
pub fn spill_slot_path(dir: &std::path::Path, block: usize) -> PathBuf {
    dir.join(format!("slab_{block:06}.sbin"))
}

fn shard(
    features: &Matrix,
    block_records: usize,
    workers: usize,
) -> Result<(Vec<BlockMeta>, Vec<Matrix>)> {
    if features.rows() == 0 {
        return Err(Error::BlockStore("cannot shard an empty dataset".into()));
    }
    if block_records == 0 {
        return Err(Error::BlockStore("block_records must be positive".into()));
    }
    let workers = workers.max(1);
    let mut metas = Vec::new();
    let mut mats = Vec::new();
    let mut start = 0usize;
    let mut id = 0usize;
    while start < features.rows() {
        let end = (start + block_records).min(features.rows());
        let mat = features.slice_rows(start, end);
        metas.push(BlockMeta {
            id,
            rows: mat.rows(),
            preferred_worker: id % workers,
            // In-memory blocks model the same bytes as the binary codec.
            bytes: codec::encoded_size(&mat),
        });
        mats.push(mat);
        start = end;
        id += 1;
    }
    Ok((metas, mats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::prng::Pcg;

    #[test]
    fn shards_cover_all_rows() {
        let d = blobs(1000, 4, 2, 0.3, 1);
        let s = BlockStore::in_memory("t", &d.features, 300, 4).unwrap();
        assert_eq!(s.num_blocks(), 4);
        assert_eq!(s.blocks()[3].rows, 100);
        let total: usize = s.blocks().iter().map(|b| b.rows).sum();
        assert_eq!(total, 1000);
        // Round-trip a row.
        let b2 = s.read_block(2).unwrap();
        assert_eq!(b2.row(0), d.features.row(600));
    }

    #[test]
    fn disk_roundtrip() {
        let d = blobs(500, 3, 2, 0.3, 2);
        let dir = std::env::temp_dir().join(format!("bigfcm_bs_{}", std::process::id()));
        let s = BlockStore::on_disk("t", &d.features, 128, 2, dir.clone()).unwrap();
        assert_eq!(s.num_blocks(), 4);
        for b in 0..4 {
            let m = s.read_block(b).unwrap();
            assert_eq!(m.cols(), 3);
            assert_eq!(m.row(0), d.features.row(b * 128));
        }
        assert!(s.total_bytes() > 500 * 3 * 4);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn locality_hints_round_robin() {
        let d = blobs(600, 2, 2, 0.3, 3);
        let s = BlockStore::in_memory("t", &d.features, 100, 3).unwrap();
        let hints: Vec<usize> = s.blocks().iter().map(|b| b.preferred_worker).collect();
        assert_eq!(hints, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn sampling_returns_real_records() {
        let d = blobs(400, 3, 2, 0.3, 4);
        let s = BlockStore::in_memory("t", &d.features, 64, 2).unwrap();
        let mut rng = Pcg::new(5);
        let sample = s.sample_records(50, &mut rng).unwrap();
        assert_eq!(sample.rows(), 50);
        for i in 0..50 {
            let found = (0..400).any(|j| d.features.row(j) == sample.row(i));
            assert!(found, "sampled row {i} is not a dataset record");
        }
    }

    #[test]
    fn sample_more_than_population_clamps() {
        let d = blobs(20, 2, 2, 0.3, 5);
        let s = BlockStore::in_memory("t", &d.features, 7, 2).unwrap();
        let mut rng = Pcg::new(6);
        let sample = s.sample_records(100, &mut rng).unwrap();
        assert_eq!(sample.rows(), 20);
    }

    #[test]
    fn rejects_empty_and_zero_block() {
        let empty = Matrix::zeros(0, 3);
        assert!(BlockStore::in_memory("t", &empty, 10, 1).is_err());
        let d = blobs(10, 2, 2, 0.3, 7);
        assert!(BlockStore::in_memory("t", &d.features, 0, 1).is_err());
    }

    #[test]
    fn writer_streams_blocks_to_disk_and_reads_back() {
        let d = blobs(600, 3, 2, 0.3, 11);
        let dir = std::env::temp_dir().join(format!("bigfcm_bsw_{}", std::process::id()));
        let mut w = BlockStoreWriter::create("t", 3, 4, dir.clone()).unwrap();
        for b in 0..3 {
            let block = d.features.slice_rows(b * 200, (b + 1) * 200);
            assert_eq!(w.append(&block).unwrap(), b);
        }
        assert_eq!(w.num_blocks(), 3);
        assert_eq!(w.total_rows(), 600);
        let s = w.finish().unwrap();
        assert_eq!(s.num_blocks(), 3);
        assert_eq!(s.total_rows(), 600);
        assert_eq!(s.blocks()[2].preferred_worker, 2);
        assert_eq!(s.max_block_bytes(), s.blocks()[0].bytes);
        let m = s.read_block(1).unwrap();
        assert_eq!(m.row(0), d.features.row(200));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn writer_rejects_mismatched_and_empty_blocks() {
        let dir = std::env::temp_dir().join(format!("bigfcm_bsw_bad_{}", std::process::id()));
        let mut w = BlockStoreWriter::create("t", 3, 2, dir.clone()).unwrap();
        assert!(w.append(&Matrix::zeros(5, 4)).is_err(), "wrong col count");
        assert!(w.append(&Matrix::zeros(0, 3)).is_err(), "empty block");
        let empty = BlockStoreWriter::create("t", 3, 2, dir.clone()).unwrap();
        assert!(empty.finish().is_err(), "store with no blocks");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn open_disk_recovers_manifest_from_block_files() {
        let d = blobs(500, 3, 2, 0.3, 12);
        let dir = std::env::temp_dir().join(format!("bigfcm_bso_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let written = BlockStore::on_disk("t", &d.features, 128, 2, dir.clone()).unwrap();
        let reopened = BlockStore::open_disk("t2", 3, dir.clone()).unwrap();
        assert_eq!(reopened.num_blocks(), written.num_blocks());
        assert_eq!(reopened.cols(), 3);
        assert_eq!(reopened.total_rows(), 500);
        assert_eq!(reopened.total_bytes(), written.total_bytes());
        assert_eq!(reopened.blocks()[2].preferred_worker, 2 % 3);
        for b in 0..reopened.num_blocks() {
            assert_eq!(reopened.read_block(b).unwrap(), written.read_block(b).unwrap());
        }
        assert!(BlockStore::open_disk("empty", 2, dir.join("nope")).is_err());
        // A mid-range gap must fail loudly, never silently truncate.
        std::fs::remove_file(dir.join("block_000001.bfb")).unwrap();
        assert!(
            BlockStore::open_disk("gap", 2, dir.clone()).is_err(),
            "store with a missing mid-range block must not open as a prefix"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn store_uids_are_unique() {
        let d = blobs(20, 2, 2, 0.3, 9);
        let a = BlockStore::in_memory("a", &d.features, 10, 1).unwrap();
        let b = BlockStore::in_memory("b", &d.features, 10, 1).unwrap();
        assert_ne!(a.uid(), b.uid());
    }

    #[test]
    fn out_of_range_block_errors() {
        let d = blobs(10, 2, 2, 0.3, 8);
        let s = BlockStore::in_memory("t", &d.features, 5, 1).unwrap();
        assert!(s.read_block(2).is_err());
    }
}
