//! Minimal JSON parser and writer.
//!
//! The repository builds fully offline (no serde), so this module carries the
//! small JSON surface the system needs: parsing the AOT `manifest.json` /
//! `golden.json` emitted by the python compile path, and serialising run
//! reports.  It is a complete, strict RFC 8259 value parser (objects, arrays,
//! strings with escapes, numbers, booleans, null) — not a framework.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `None` if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// `get` that fails loudly with context (for required manifest fields).
    pub fn require(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json { offset: 0, message: format!("missing field `{key}`") })
    }

    /// Decode an array of numbers into f32s (golden vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_array()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|x| x as f32).collect())
    }
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> Error {
        Error::Json { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Serialise a [`Value`] to compact JSON text.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::String(s) => write_escaped(out, s),
        Value::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(out, k);
                out.push(':');
                write_value(out, val);
            }
            out.push('}');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object values.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: number value.
pub fn num(n: f64) -> Value {
    Value::Number(n)
}

/// Convenience: string value.
pub fn s(v: impl Into<String>) -> Value {
    Value::String(v.into())
}

/// Convenience: array of numbers.
pub fn nums(xs: &[f64]) -> Value {
    Value::Array(xs.iter().map(|&x| Value::Number(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"name":"x\n","nested":{"ok":true,"z":null}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(Default::default()));
    }

    #[test]
    fn f32_vec_decoding() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn require_reports_missing() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert!(v.require("a").is_ok());
        assert!(v.require("b").is_err());
    }
}
