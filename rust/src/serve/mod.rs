//! Online membership-serving subsystem — the paper's actual deliverable.
//!
//! BigFCM positions the membership matrix "as a preprocessing step in many
//! data mining process implementations": training fast is only half the
//! system, the other half is *answering membership queries* against the
//! trained model. This module is that second phase (the same two-phase
//! shape as CFM-BD in PAPERS.md — distributed fit, then a compact model
//! served for classification), in three layers:
//!
//! * **[`bundle`]** — a [`ModelBundle`] persists everything scoring needs
//!   (centers, the [`crate::data::normalize::Scaler`] that normalized the
//!   training data, algorithm/variant/fuzzifier, seed and training
//!   counters) behind a checksummed bitwise LE codec, the same write/read
//!   discipline as the slab spill images and `.bfb` block files. Saved by
//!   `bigfcm run/session --save-model`, inspected by `bigfcm info
//!   --model`.
//! * **[`service`]** — a [`ScoreService`] answers concurrent single-record
//!   membership queries online: requests enter a bounded admission queue
//!   (backpressure when full), a batcher thread coalesces them into
//!   zero-padded micro-batches and executes each batch through one
//!   [`crate::fcm::KernelBackend::score_chunk`] call — so the device-shape
//!   backends (the PJRT shim today, lowered scoring artifacts tomorrow)
//!   serve traffic through exactly the kernels that trained the model.
//!   Queue depth, batch fill and p50/p95/p99 latency are metered
//!   ([`ServeStats`]); `bigfcm serve-bench` drives a closed-loop load
//!   harness against it.
//! * **[`bulk`]** — [`run_score_job`] labels an entire
//!   [`crate::hdfs::BlockStore`] as one MapReduce job through the engine's
//!   cache/locality/prefetch path, writing top-k sparse membership rows
//!   back out block-by-block via [`crate::hdfs::BlockStoreWriter`] (a
//!   bounded reorder buffer keeps appends in block order while map tasks
//!   finish out of order), so multi-GiB stores are labeled end-to-end
//!   without materializing the membership matrix.
//! * **[`registry`]** — a [`ModelRegistry`] runs many services at once,
//!   keyed by model id, with **hot reload**: re-publishing an id swaps
//!   its bundle atomically (generation-stamped; in-flight micro-batches
//!   finish on the generation they admitted under) and `retire` shuts a
//!   service down under the drain-and-reject contract.
//! * **[`front`]** — a [`ServeFront`] serves the registry over TCP with
//!   a length-prefixed frame protocol on the crate's thread pool:
//!   per-connection framing errors are isolated from the process, and
//!   wire bytes are charged to the [`crate::mapreduce::SimClock`] the
//!   way HDFS I/O already is.
//!
//! ```text
//!   tcp clients ──► ServeFront (frames · per-conn isolation · net cost
//!                      │        modelled in SimClock)
//!                      ▼
//!                ModelRegistry (model id → service; hot reload = atomic
//!                      │        generation-stamped bundle swap; retire)
//!                      ▼
//!      bigfcm run/session --save-model      bigfcm serve-bench / score
//!                 │                                   │
//!                 ▼                                   ▼
//!           ModelBundle  ──────────────►  ScoreService        run_score_job
//!        (centers·scaler·m·counters,      (bounded 2-lane     (MR job over a
//!         checksummed bitwise codec)       queue + tenant      BlockStore)
//!                                          quotas → micro-        │
//!                                          batches)               │
//!                                                │                │
//!                                                └── score_chunk ─┘
//!                                                 (one KernelBackend
//!                                                  primitive: native,
//!                                                  shim, PJRT-ready)
//! ```

pub mod bulk;
pub mod bundle;
pub mod front;
pub mod registry;
pub mod service;

pub use bulk::{dense_from_top_k, run_score_job, ScoreJobOutcome, ScoreJobTotals};
pub use bundle::ModelBundle;
pub use front::{client_call, FrontOptions, FrontStats, ServeFront};
pub use registry::ModelRegistry;
pub use service::{Lane, Scored, ScoreService, ScoreServiceBuilder, ServeOptions, ServeStats};
