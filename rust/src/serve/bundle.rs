//! Persisted model bundles: everything membership scoring needs, behind a
//! checksummed bitwise LE codec.
//!
//! A bundle is the contract between the training half of the system (the
//! BigFCM pipeline, the iteration-resident session loop) and the serving
//! half ([`crate::serve::service`], [`crate::serve::bulk`]): final
//! centers and their weight mass, the [`Scaler`] that normalized the
//! training data (raw records at serve time go through the *same* affine
//! map, or memberships are computed in the wrong space), the algorithm /
//! chunk-math variant / fuzzifier that define the membership formula, and
//! the provenance counters a `bigfcm info --model` inspection reports
//! (seed, dataset, rows, iterations, objective, convergence,
//! records_pruned).
//!
//! The codec follows the slab spill images bit for bit in discipline:
//! little-endian fixed-width fields through the shared
//! [`crate::fcm::backend`] codec primitives, an FNV-1a trailer over the
//! payload, decode failing loudly on any corruption — a truncated or
//! bit-flipped bundle must never score traffic with silently wrong
//! centers. Because every f32/f64 travels as its exact bit pattern, a
//! save → load roundtrip reproduces scoring decisions identically
//! (pinned by `rust/tests/integration_serving.rs`).

use std::path::Path;

use crate::data::normalize::Scaler;
use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::faults::{corrupt_image, FaultPlan, FaultSite, Injected, MAX_READ_RETRIES};
use crate::fcm::backend::{
    put_blob, put_f32s, put_f64, put_f64s, put_matrix, put_u32, put_u64, put_u8, Cur,
};
use crate::fcm::{Kernel, SessionAlgo, Variant};
use crate::hdfs::fnv1a;

const BUNDLE_MAGIC: u32 = 0xB16F_40DE;
const BUNDLE_VERSION: u8 = 1;

/// A trained clustering model plus the context scoring needs.
#[derive(Clone, Debug)]
pub struct ModelBundle {
    /// Final centers (C, d) — in *normalized* feature space when
    /// [`Self::scaler`] is set.
    pub centers: Matrix,
    /// Per-center weight mass at convergence (Σ u^m w); empty when the
    /// trainer did not report it.
    pub weights: Vec<f64>,
    /// The normalization fitted on the training data; raw records are
    /// pushed through it before scoring. `None` means the model was
    /// trained on raw features.
    pub scaler: Option<Scaler>,
    /// Which algorithm produced (and therefore scores against) the model.
    pub algo: SessionAlgo,
    /// FCM chunk-math variant (ignored by K-Means).
    pub variant: Variant,
    /// Fuzzifier m (> 1 for FCM; ignored by K-Means).
    pub m: f64,
    /// Master seed of the training run.
    pub seed: u64,
    /// Dataset name the model was trained on (provenance only).
    pub dataset: String,
    /// Records the trainer saw.
    pub trained_rows: u64,
    /// Training iterations executed.
    pub iterations: u64,
    /// Final training objective.
    pub objective: f64,
    /// Whether training met its epsilon criterion.
    pub converged: bool,
    /// Records served from the pruning slab across training (0 when
    /// pruning was off).
    pub records_pruned: u64,
}

impl ModelBundle {
    /// A bundle with the given model and neutral provenance; callers fill
    /// the public counter fields they know.
    pub fn new(centers: Matrix, algo: SessionAlgo, variant: Variant, m: f64) -> Self {
        Self {
            centers,
            weights: Vec::new(),
            scaler: None,
            algo,
            variant,
            m,
            seed: 0,
            dataset: String::new(),
            trained_rows: 0,
            iterations: 0,
            objective: 0.0,
            converged: false,
            records_pruned: 0,
        }
    }

    /// Cluster count C.
    pub fn clusters(&self) -> usize {
        self.centers.rows()
    }

    /// Feature count d (of the *raw* record space; the scaler is affine,
    /// so normalized and raw dimensionality coincide).
    pub fn dims(&self) -> usize {
        self.centers.cols()
    }

    /// The backend dispatch token scoring runs under.
    pub fn kernel(&self) -> Kernel {
        self.algo.kernel(self.variant)
    }

    /// Normalize one raw record in place (no-op without a scaler).
    pub fn normalize_row(&self, row: &mut [f32]) {
        if let Some(s) = &self.scaler {
            s.apply_row(row);
        }
    }

    /// Normalize a block of raw records in place (no-op without a scaler).
    pub fn normalize_block(&self, block: &mut Matrix) {
        if let Some(s) = &self.scaler {
            s.apply(block);
        }
    }

    /// Structural invariants every encode/decode endpoint enforces.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Bundle(m));
        if self.centers.rows() == 0 || self.centers.cols() == 0 {
            return err(format!(
                "centers must be non-empty, got {} x {}",
                self.centers.rows(),
                self.centers.cols()
            ));
        }
        if !self.weights.is_empty() && self.weights.len() != self.centers.rows() {
            return err(format!(
                "{} weights for {} centers",
                self.weights.len(),
                self.centers.rows()
            ));
        }
        if self.algo == SessionAlgo::Fcm && !(self.m > 1.0) {
            return err(format!("fuzzifier must be > 1 for FCM, got {}", self.m));
        }
        if let Some(s) = &self.scaler {
            if s.offset.len() != self.centers.cols() || s.scale.len() != self.centers.cols() {
                return err(format!(
                    "scaler covers {} features, centers have {}",
                    s.offset.len(),
                    self.centers.cols()
                ));
            }
            if s.scale.iter().any(|&v| !(v.is_finite() && v != 0.0))
                || s.offset.iter().any(|v| !v.is_finite())
            {
                return err("scaler carries non-finite or zero terms".into());
            }
        }
        Ok(())
    }

    /// Bitwise serialisation (checksummed; see the module docs).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            64 + self.dataset.len()
                + self.centers.rows() * self.centers.cols() * 4
                + self.weights.len() * 8,
        );
        put_u32(&mut b, BUNDLE_MAGIC);
        put_u8(&mut b, BUNDLE_VERSION);
        put_u8(&mut b, match self.algo {
            SessionAlgo::Fcm => 0,
            SessionAlgo::KMeans => 1,
        });
        put_u8(&mut b, match self.variant {
            Variant::Fast => 0,
            Variant::Classic => 1,
        });
        put_f64(&mut b, self.m);
        put_u64(&mut b, self.seed);
        put_blob(&mut b, self.dataset.as_bytes());
        put_u64(&mut b, self.trained_rows);
        put_u64(&mut b, self.iterations);
        put_f64(&mut b, self.objective);
        put_u8(&mut b, self.converged as u8);
        put_u64(&mut b, self.records_pruned);
        put_matrix(&mut b, &self.centers);
        put_f64s(&mut b, &self.weights);
        match &self.scaler {
            None => put_u8(&mut b, 0),
            Some(s) => {
                put_u8(&mut b, 1);
                put_f32s(&mut b, &s.offset);
                put_f32s(&mut b, &s.scale);
            }
        }
        let sum = fnv1a(&b);
        put_u64(&mut b, sum);
        b
    }

    /// Decode and validate an image; any corruption fails loudly.
    pub fn decode(bytes: &[u8]) -> Result<ModelBundle> {
        let err = |m: &str| Error::Bundle(m.to_string());
        if bytes.len() < 16 {
            return Err(err("image too short"));
        }
        let (payload, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().expect("8-byte trailer"));
        if fnv1a(payload) != stored {
            return Err(err("checksum mismatch"));
        }
        let mut c = Cur::new(payload);
        if c.u32().ok_or_else(|| err("truncated magic"))? != BUNDLE_MAGIC {
            return Err(err("bad magic"));
        }
        if c.u8().ok_or_else(|| err("truncated version"))? != BUNDLE_VERSION {
            return Err(err("unsupported version"));
        }
        let algo = match c.u8().ok_or_else(|| err("truncated algo"))? {
            0 => SessionAlgo::Fcm,
            1 => SessionAlgo::KMeans,
            _ => return Err(err("unknown algo tag")),
        };
        let variant = match c.u8().ok_or_else(|| err("truncated variant"))? {
            0 => Variant::Fast,
            1 => Variant::Classic,
            _ => return Err(err("unknown variant tag")),
        };
        let m = c.f64().ok_or_else(|| err("truncated fuzzifier"))?;
        let seed = c.u64().ok_or_else(|| err("truncated seed"))?;
        let dataset = String::from_utf8(
            c.blob().ok_or_else(|| err("truncated dataset name"))?.to_vec(),
        )
        .map_err(|_| err("dataset name is not utf-8"))?;
        let trained_rows = c.u64().ok_or_else(|| err("truncated trained_rows"))?;
        let iterations = c.u64().ok_or_else(|| err("truncated iterations"))?;
        let objective = c.f64().ok_or_else(|| err("truncated objective"))?;
        let converged = match c.u8().ok_or_else(|| err("truncated converged"))? {
            0 => false,
            1 => true,
            _ => return Err(err("bad converged flag")),
        };
        let records_pruned = c.u64().ok_or_else(|| err("truncated records_pruned"))?;
        let centers = c.matrix().ok_or_else(|| err("truncated centers"))?;
        let weights = c.f64s().ok_or_else(|| err("truncated weights"))?;
        let scaler = match c.u8().ok_or_else(|| err("truncated scaler flag"))? {
            0 => None,
            1 => {
                let offset = c.f32s().ok_or_else(|| err("truncated scaler offset"))?;
                let scale = c.f32s().ok_or_else(|| err("truncated scaler scale"))?;
                Some(Scaler { offset, scale })
            }
            _ => return Err(err("bad scaler flag")),
        };
        if !c.done() {
            return Err(err("trailing bytes"));
        }
        let bundle = ModelBundle {
            centers,
            weights,
            scaler,
            algo,
            variant,
            m,
            seed,
            dataset,
            trained_rows,
            iterations,
            objective,
            converged,
            records_pruned,
        };
        bundle.validate()?;
        Ok(bundle)
    }

    /// Save to a file; returns bytes written.
    pub fn save(&self, path: &Path) -> Result<u64> {
        self.validate()?;
        let img = self.encode();
        std::fs::write(path, &img).map_err(|e| Error::io(path, e))?;
        Ok(img.len() as u64)
    }

    /// Load and verify from a file.
    pub fn load(path: &Path) -> Result<ModelBundle> {
        let bytes = std::fs::read(path).map_err(|e| Error::io(path, e))?;
        Self::decode(&bytes)
    }

    /// Load and verify from a file under the chaos plan's `BundleLoad`
    /// site. Transient injected faults retry (bounded, like every other
    /// read boundary); injected corruption flips a byte in the freshly
    /// read image and routes it through the real codec — the FNV-1a
    /// trailer must reject it — before re-reading clean bytes; exhaustion
    /// surfaces a structured error naming the path. With `faults` `None`
    /// this is exactly [`Self::load`].
    pub fn load_with_faults(path: &Path, faults: Option<&FaultPlan>) -> Result<ModelBundle> {
        let Some(plan) = faults else { return Self::load(path) };
        let mut attempt: u32 = 0;
        loop {
            attempt += 1;
            match plan.check(FaultSite::BundleLoad) {
                None => return Self::load(path),
                Some(Injected::Corrupt) => {
                    let mut img = std::fs::read(path).map_err(|e| Error::io(path, e))?;
                    corrupt_image(&mut img, plan.seed() ^ attempt as u64);
                    if let Ok(bundle) = Self::decode(&img) {
                        // Pathological checksum collision: the torn image
                        // still decoded and validated — serve it.
                        return Ok(bundle);
                    }
                    // Quarantined; loop around and re-read clean bytes.
                }
                Some(_) => {}
            }
            if attempt >= MAX_READ_RETRIES {
                return Err(Error::Bundle(format!(
                    "{}: load failed after {MAX_READ_RETRIES} attempts \
                     (fault persisted through retries)",
                    path.display()
                )));
            }
        }
    }

    /// Human-readable report for `bigfcm info --model`.
    pub fn summary(&self) -> String {
        format!(
            "algo={} variant={:?} C={} d={} m={} scaler={} seed={:#x}\n\
             trained: dataset={} rows={} iterations={} objective={:.6e} converged={} \
             records_pruned={}",
            self.algo.as_str(),
            self.variant,
            self.clusters(),
            self.dims(),
            self.m,
            if self.scaler.is_some() { "yes" } else { "no" },
            self.seed,
            if self.dataset.is_empty() { "?" } else { &self.dataset },
            self.trained_rows,
            self.iterations,
            self.objective,
            self.converged,
            self.records_pruned,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg;

    fn sample_bundle(seed: u64) -> ModelBundle {
        let mut rng = Pcg::new(seed);
        let (c, d) = (2 + rng.next_index(4), 1 + rng.next_index(6));
        let mut centers = Matrix::zeros(c, d);
        for v in centers.as_mut_slice() {
            *v = rng.normal() as f32;
        }
        let mut b = ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0);
        b.weights = (0..c).map(|_| rng.next_f64() * 100.0).collect();
        b.scaler = Some(Scaler {
            offset: (0..d).map(|_| rng.normal() as f32).collect(),
            scale: (0..d).map(|_| rng.next_f32() + 0.5).collect(),
        });
        b.seed = seed;
        b.dataset = format!("synthetic-{seed}");
        b.trained_rows = 10_000 + seed;
        b.iterations = 17;
        b.objective = 123.456;
        b.converged = true;
        b.records_pruned = 42;
        b
    }

    #[test]
    fn encode_decode_roundtrips_bitwise() {
        for seed in 0..6 {
            let b = sample_bundle(seed);
            let img = b.encode();
            let back = ModelBundle::decode(&img).unwrap();
            assert_eq!(back.encode(), img, "seed {seed}: re-encode differs");
            assert_eq!(back.centers, b.centers);
            assert_eq!(back.weights, b.weights);
            assert_eq!(back.m, b.m);
            assert_eq!(back.dataset, b.dataset);
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let b = sample_bundle(9);
        let img = b.encode();
        assert!(ModelBundle::decode(&[]).is_err());
        let mut flipped = img.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x10;
        assert!(ModelBundle::decode(&flipped).is_err(), "bit flip must not decode");
        let mut truncated = img.clone();
        truncated.truncate(img.len() - 5);
        assert!(ModelBundle::decode(&truncated).is_err(), "truncation must not decode");
    }

    #[test]
    fn validate_catches_structural_errors() {
        let mut b = sample_bundle(3);
        b.weights = vec![1.0];
        assert!(b.validate().is_err(), "weights/centers mismatch");
        let mut b = sample_bundle(4);
        b.m = 1.0;
        assert!(b.validate().is_err(), "FCM fuzzifier must be > 1");
        b.algo = SessionAlgo::KMeans;
        assert!(b.validate().is_ok(), "K-Means ignores the fuzzifier");
        let mut b = sample_bundle(5);
        b.scaler = Some(Scaler { offset: vec![0.0], scale: vec![1.0] });
        assert!(b.validate().is_err(), "scaler dims mismatch");
        let mut b = sample_bundle(6);
        if let Some(s) = &mut b.scaler {
            s.scale[0] = 0.0;
        }
        assert!(b.validate().is_err(), "zero scale must be rejected");
    }

    fn saved_sample(tag: &str) -> (std::path::PathBuf, ModelBundle) {
        let dir = std::env::temp_dir()
            .join(format!("bigfcm_bundle_chaos_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bundle");
        let b = sample_bundle(2);
        b.save(&path).unwrap();
        (path, b)
    }

    #[test]
    fn load_with_faults_none_is_plain_load() {
        let (path, b) = saved_sample("plain");
        let back = ModelBundle::load_with_faults(&path, None).unwrap();
        assert_eq!(back.encode(), b.encode());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn transient_bundle_fault_retries_then_loads_bitwise() {
        let (path, b) = saved_sample("transient");
        let plan = FaultPlan::tripping(17, FaultSite::BundleLoad, 0);
        let back = ModelBundle::load_with_faults(&path, Some(plan.as_ref())).unwrap();
        assert_eq!(back.encode(), b.encode());
        assert_eq!(plan.injected_at(FaultSite::BundleLoad), 1);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn corrupt_bundle_is_quarantined_then_reread_bitwise() {
        let (path, b) = saved_sample("corrupt");
        let plan = FaultPlan::tripping_corrupt(17, FaultSite::BundleLoad, 0);
        let back = ModelBundle::load_with_faults(&path, Some(plan.as_ref())).unwrap();
        assert_eq!(back.encode(), b.encode());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn persistent_bundle_fault_aborts_with_path() {
        let (path, _) = saved_sample("persistent");
        let plan = FaultPlan::for_site(17, FaultSite::BundleLoad, 1.0, 0.0);
        let err = ModelBundle::load_with_faults(&path, Some(plan.as_ref())).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("after 3 attempts"), "{msg}");
        assert!(msg.contains("m.bundle"), "{msg}");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn kernel_dispatch_matches_algo() {
        let b = sample_bundle(7);
        assert_eq!(b.kernel(), Kernel::FcmFast);
        let mut b = sample_bundle(8);
        b.variant = Variant::Classic;
        assert_eq!(b.kernel(), Kernel::FcmClassic);
        b.algo = SessionAlgo::KMeans;
        assert_eq!(b.kernel(), Kernel::KMeans);
    }
}
