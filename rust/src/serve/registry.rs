//! Multi-model registry: many [`ScoreService`]s keyed by model id, with
//! hot publish/reload and orderly retirement.
//!
//! Production serving is never one model: tenants score against different
//! models, and models get retrained underneath live traffic. The registry
//! owns one running [`ScoreService`] per model id, all built through the
//! one construction path ([`ScoreServiceBuilder`]) with the registry's
//! shared backend and options:
//!
//! * [`ModelRegistry::publish`] — first publish of an id spawns a fresh
//!   service (generation 1); re-publishing an existing id **hot-reloads**
//!   it in place via the service's atomic bundle swap, so open
//!   connections and queued requests keep flowing — in-flight
//!   micro-batches finish on the generation they admitted under, later
//!   batches score on the new one, every response stamped.
//! * [`ModelRegistry::retire`] — removes the id and closes its service
//!   under the drain-and-reject shutdown contract: queued requests get
//!   [`crate::error::Error::ShuttingDown`], nothing hangs.
//!
//! Lookups hand out `Arc<ScoreService>` clones, so a caller scoring
//! against a service that is concurrently retired still gets its answers
//! (or clean shutdown errors) — the service object outlives its registry
//! slot.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::error::{Error, Result};
use crate::fcm::KernelBackend;
use crate::json::{self, Value};
use crate::serve::bundle::ModelBundle;
use crate::serve::service::{ScoreService, ServeOptions};

/// The model registry (see module docs). Share behind an `Arc`; all
/// methods take `&self`.
pub struct ModelRegistry {
    backend: Arc<dyn KernelBackend>,
    opts: ServeOptions,
    models: RwLock<HashMap<String, Arc<ScoreService>>>,
    reloads: AtomicU64,
}

impl ModelRegistry {
    /// A registry whose services all run on `backend` with `opts`.
    pub fn new(backend: Arc<dyn KernelBackend>, opts: ServeOptions) -> Self {
        Self {
            backend,
            opts,
            models: RwLock::new(HashMap::new()),
            reloads: AtomicU64::new(0),
        }
    }

    /// Publish `bundle` under `id`: spawn a new service if the id is new,
    /// hot-reload the existing one otherwise. Returns the generation now
    /// serving (1 for a fresh spawn).
    pub fn publish(&self, id: &str, bundle: ModelBundle) -> Result<u64> {
        if id.is_empty() || id.contains(char::is_whitespace) {
            return Err(Error::InvalidArgument(format!(
                "model id {id:?} must be non-empty and whitespace-free"
            )));
        }
        // Fast path: the id exists — reload without the write lock (the
        // swap is the service's own atomic; the map doesn't change).
        if let Some(svc) = self.get(id) {
            let generation = svc.reload(bundle)?;
            self.reloads.fetch_add(1, Ordering::Relaxed);
            return Ok(generation);
        }
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .options(self.opts.clone())
                .spawn(Arc::clone(&self.backend))?,
        );
        let mut map = self.models.write().expect("registry lock poisoned");
        // Two concurrent first-publishes of one id race to this insert;
        // the loser's freshly spawned service must not clobber the
        // winner's (clients may already hold it) — reload it instead.
        if let Some(existing) = map.get(id) {
            let existing = Arc::clone(existing);
            drop(map);
            svc.close();
            let generation = existing.reload(svc.bundle().as_ref().clone())?;
            self.reloads.fetch_add(1, Ordering::Relaxed);
            return Ok(generation);
        }
        map.insert(id.to_string(), svc);
        Ok(1)
    }

    /// The running service for `id`, if any.
    pub fn get(&self, id: &str) -> Option<Arc<ScoreService>> {
        self.models.read().expect("registry lock poisoned").get(id).cloned()
    }

    /// Remove `id` and shut its service down (drain-and-reject; queued
    /// requests answered, batcher joined). Errors if the id is unknown.
    pub fn retire(&self, id: &str) -> Result<()> {
        let svc = self
            .models
            .write()
            .expect("registry lock poisoned")
            .remove(id)
            .ok_or_else(|| Error::InvalidArgument(format!("no model {id:?} in the registry")))?;
        svc.close();
        Ok(())
    }

    /// Registered model ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        let mut ids: Vec<String> =
            self.models.read().expect("registry lock poisoned").keys().cloned().collect();
        ids.sort();
        ids
    }

    /// Total successful hot reloads across all ids.
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Publish the registry-wide reload counter and every model's
    /// [`crate::serve::ServeStats`] into `reg` under `serve.{id}.*` — the
    /// unified-registry view behind the wire `stats`/`metrics` verbs.
    pub fn publish_metrics(&self, reg: &crate::telemetry::metrics::MetricsRegistry) {
        reg.set_counter("serve.reloads", self.reloads());
        let map = self.models.read().expect("registry lock poisoned");
        for (id, svc) in map.iter() {
            svc.stats().publish_metrics(reg, &format!("serve.{id}"));
        }
    }

    /// Per-model stats snapshot as JSON: `{ "reloads": n, "models":
    /// { id: ServeStats... } }` — the wire front's `stats` verb.
    pub fn stats_json(&self) -> Value {
        let map = self.models.read().expect("registry lock poisoned");
        let mut ids: Vec<&String> = map.keys().collect();
        ids.sort();
        let models = ids
            .into_iter()
            .map(|id| (id.as_str(), map[id].stats().to_json()))
            .collect::<Vec<_>>();
        json::obj(vec![
            ("reloads", json::num(self.reloads() as f64)),
            ("models", json::obj(models)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::data::Matrix;
    use crate::fcm::{NativeBackend, SessionAlgo, Variant};

    fn bundle(seed: u64) -> (ModelBundle, Matrix) {
        let data = blobs(128, 3, 3, 0.3, seed);
        let mut centers = Matrix::zeros(3, 3);
        for i in 0..3 {
            centers.row_mut(i).copy_from_slice(data.features.row(i * 40));
        }
        (ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0), data.features)
    }

    fn registry() -> ModelRegistry {
        ModelRegistry::new(Arc::new(NativeBackend), ServeOptions::default())
    }

    #[test]
    fn publish_get_retire_roundtrip() {
        let reg = registry();
        let (b1, x) = bundle(31);
        let (b2, _) = bundle(32);
        assert_eq!(reg.publish("susy", b1).unwrap(), 1);
        assert_eq!(reg.publish("higgs", b2).unwrap(), 1);
        assert_eq!(reg.ids(), vec!["higgs".to_string(), "susy".to_string()]);
        let svc = reg.get("susy").expect("published model resolves");
        let u = svc.score(x.row(0)).unwrap();
        assert!((u.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(reg.get("nope").is_none());
        reg.retire("susy").unwrap();
        assert!(reg.get("susy").is_none());
        assert!(reg.retire("susy").is_err(), "double retire errors");
        assert_eq!(reg.ids(), vec!["higgs".to_string()]);
    }

    #[test]
    fn republish_hot_reloads_in_place() {
        let reg = registry();
        let (b1, x) = bundle(33);
        let (b2, _) = bundle(34);
        let new_centers = b2.centers.clone();
        assert_eq!(reg.publish("m", b1).unwrap(), 1);
        let held = reg.get("m").unwrap(); // client holds the service across the reload
        assert_eq!(reg.publish("m", b2).unwrap(), 2);
        assert_eq!(reg.reloads(), 1);
        // The held handle *is* the reloaded service, not a stale one.
        assert_eq!(held.generation(), 2);
        let scored = held.score_stamped(x.row(5)).unwrap();
        assert_eq!(scored.generation, 2);
        let oracle = crate::fcm::native::memberships(&x, &new_centers, 2.0);
        for (a, b) in scored.memberships.iter().zip(oracle.row(5)) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn retired_service_held_by_client_rejects_cleanly() {
        let reg = registry();
        let (b, x) = bundle(35);
        reg.publish("m", b).unwrap();
        let held = reg.get("m").unwrap();
        reg.retire("m").unwrap();
        match held.score(x.row(0)) {
            Err(Error::ShuttingDown) => {}
            other => panic!("retired service must reject with ShuttingDown, got {other:?}"),
        }
    }

    #[test]
    fn bad_ids_and_mismatched_reload_bundles_error() {
        let reg = registry();
        let (b, _) = bundle(36);
        assert!(reg.publish("", b.clone()).is_err());
        assert!(reg.publish("two words", b.clone()).is_err());
        reg.publish("m", b).unwrap();
        let narrow = ModelBundle::new(Matrix::zeros(3, 2), SessionAlgo::Fcm, Variant::Fast, 2.0);
        assert!(reg.publish("m", narrow).is_err(), "dim-mismatched reload must fail");
        assert_eq!(reg.get("m").unwrap().generation(), 1);
    }
}
