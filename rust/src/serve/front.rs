//! The network serving front: a length-prefixed line protocol over TCP,
//! served on the crate's own thread pool.
//!
//! Production traffic arrives over a wire, not through an in-process
//! call. The front binds a `TcpListener`, accepts connections on one
//! acceptor thread, and runs each connection's handler on a
//! [`ThreadPool`] worker. Framing is the simplest thing that is
//! unambiguous over a stream:
//!
//! ```text
//! frame := [u32 length, little-endian][length bytes of UTF-8 text]
//! ```
//!
//! Request text is one command per frame; the response is one frame back
//! on the same connection:
//!
//! | command | response |
//! |---|---|
//! | `ping` | `ok pong` |
//! | `score <model> <tenant> <lane> <v0,v1,...>` | `ok <generation> <u0,u1,...>` |
//! | `reload <model> <bundle-path>` | `ok <generation>` |
//! | `retire <model>` | `ok retired` |
//! | `stats` | `ok <json>` (front + registry + unified metrics snapshot) |
//! | `metrics` | `ok\n<text>` (Prometheus-style exposition of the registry) |
//! | `shutdown` | `ok shutting-down` (front begins draining) |
//!
//! Application errors (unknown model, over-quota tenant, bad record)
//! answer `err <message>` and the connection **stays open** — only
//! *framing* violations (oversized length, truncated frame, invalid
//! UTF-8) close the connection, and even those are isolated to it: the
//! counter [`FrontStats::framing_errors`] ticks, the other connections
//! and the process carry on.
//!
//! Transport is modelled in the [`SimClock`] the way HDFS I/O already
//! is: every frame pair charges its wire bytes at
//! [`OverheadConfig::net_s_per_mib`], so serve-bench reports carry a
//! modelled network cost alongside the measured latencies.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::OverheadConfig;
use crate::error::{Error, Result};
use crate::faults::{FaultPlan, FaultSite, Injected};
use crate::json::{self, Value};
use crate::mapreduce::SimClock;
use crate::serve::bundle::ModelBundle;
use crate::serve::registry::ModelRegistry;
use crate::serve::service::Lane;
use crate::telemetry::metrics;
use crate::threadpool::ThreadPool;

/// Knobs of one [`ServeFront`].
#[derive(Clone, Debug)]
pub struct FrontOptions {
    /// Connection-handler pool size (concurrent connections served).
    pub conn_workers: usize,
    /// Frames longer than this are a framing violation (connection
    /// closed). Bounds a malicious/corrupt length prefix.
    pub max_frame_bytes: usize,
    /// Socket read timeout: how often an idle handler wakes to check the
    /// shutdown flag.
    pub read_timeout: Duration,
    /// Chaos plan: each accepted connection checks the `Connection` site —
    /// an injected drop closes it before any frame is served (counted in
    /// [`FrontStats::conn_drops`]), an injected latency spike charges the
    /// modelled clock. `None` (the default) checks nothing.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for FrontOptions {
    fn default() -> Self {
        Self {
            conn_workers: 8,
            max_frame_bytes: 1 << 20,
            read_timeout: Duration::from_millis(250),
            faults: None,
        }
    }
}

/// Snapshot of the front's wire meters.
#[derive(Clone, Debug)]
pub struct FrontStats {
    /// Connections accepted.
    pub connections: u64,
    /// Frames answered (including `err` responses).
    pub frames: u64,
    /// Framing violations (oversized/truncated/non-UTF-8 frames) — each
    /// closed its connection, none touched the process.
    pub framing_errors: u64,
    /// Wire bytes received / sent (headers included).
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Records scored over the wire.
    pub scored: u64,
    /// Modelled transport seconds charged to the SimClock.
    pub modelled_net_s: f64,
    /// Connections killed by an injected fault before serving a frame
    /// (chaos runs only; clients see a clean close, never a hang).
    pub conn_drops: u64,
    /// Modelled injected-latency seconds (chaos runs only; virtual time,
    /// the front never actually sleeps).
    pub injected_wait_s: f64,
}

impl FrontStats {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("connections", json::num(self.connections as f64)),
            ("frames", json::num(self.frames as f64)),
            ("framing_errors", json::num(self.framing_errors as f64)),
            ("bytes_in", json::num(self.bytes_in as f64)),
            ("bytes_out", json::num(self.bytes_out as f64)),
            ("scored", json::num(self.scored as f64)),
            ("modelled_net_s", json::num(self.modelled_net_s)),
            ("conn_drops", json::num(self.conn_drops as f64)),
            ("injected_wait_s", json::num(self.injected_wait_s)),
        ])
    }

    /// Publish into `reg` under `front.*` — the unified-registry view the
    /// wire `stats` and `metrics` verbs expose.
    pub fn publish_metrics(&self, reg: &crate::telemetry::metrics::MetricsRegistry) {
        reg.set_counter("front.connections", self.connections);
        reg.set_counter("front.frames", self.frames);
        reg.set_counter("front.framing_errors", self.framing_errors);
        reg.set_counter("front.bytes_in", self.bytes_in);
        reg.set_counter("front.bytes_out", self.bytes_out);
        reg.set_counter("front.scored", self.scored);
        reg.set_counter("front.conn_drops", self.conn_drops);
        reg.set_gauge("front.modelled_net_s", self.modelled_net_s);
        reg.set_gauge("front.injected_wait_s", self.injected_wait_s);
    }
}

struct FrontShared {
    registry: Arc<ModelRegistry>,
    opts: FrontOptions,
    overhead: OverheadConfig,
    clock: Mutex<SimClock>,
    shutdown: AtomicBool,
    connections: AtomicU64,
    frames: AtomicU64,
    framing_errors: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    scored: AtomicU64,
    conn_drops: AtomicU64,
}

/// The running front: listener + acceptor thread + handler pool (see
/// module docs). Shut down via [`Self::shutdown`] (or the wire
/// `shutdown` command followed by it); dropped fronts shut down too.
pub struct ServeFront {
    shared: Arc<FrontShared>,
    addr: SocketAddr,
    acceptor: Mutex<Option<JoinHandle<()>>>,
}

impl ServeFront {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// serving `registry`.
    pub fn bind(
        registry: Arc<ModelRegistry>,
        addr: &str,
        opts: FrontOptions,
        overhead: OverheadConfig,
    ) -> Result<ServeFront> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Job(format!("serve front cannot bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Job(format!("serve front local_addr: {e}")))?;
        let shared = Arc::new(FrontShared {
            registry,
            opts,
            overhead,
            clock: Mutex::new(SimClock::new()),
            shutdown: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            framing_errors: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            scored: AtomicU64::new(0),
            conn_drops: AtomicU64::new(0),
        });
        let for_acceptor = Arc::clone(&shared);
        let acceptor = std::thread::Builder::new()
            .name("bigfcm-front".to_string())
            .spawn(move || {
                // The pool lives (and dies) with the acceptor: when the
                // loop breaks, dropping it joins every handler, which
                // exit within one read timeout of the shutdown flag.
                let pool = ThreadPool::new(for_acceptor.opts.conn_workers);
                for stream in listener.incoming() {
                    if for_acceptor.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    for_acceptor.connections.fetch_add(1, Ordering::Relaxed);
                    let sh = Arc::clone(&for_acceptor);
                    pool.execute(move || handle_connection(sh, stream));
                }
            })
            .map_err(|e| Error::Job(format!("spawning the front acceptor thread: {e}")))?;
        Ok(ServeFront { shared, addr: local, acceptor: Mutex::new(Some(acceptor)) })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether the wire `shutdown` command (or [`Self::shutdown`]) has
    /// been issued — the server loop's exit condition.
    pub fn is_shutdown(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain handlers, join the acceptor. Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.lock().expect("acceptor handle poisoned").take() {
            let _ = h.join();
        }
    }

    /// Wire meter snapshot.
    pub fn stats(&self) -> FrontStats {
        let sh = &self.shared;
        FrontStats {
            connections: sh.connections.load(Ordering::Relaxed),
            frames: sh.frames.load(Ordering::Relaxed),
            framing_errors: sh.framing_errors.load(Ordering::Relaxed),
            bytes_in: sh.bytes_in.load(Ordering::Relaxed),
            bytes_out: sh.bytes_out.load(Ordering::Relaxed),
            scored: sh.scored.load(Ordering::Relaxed),
            modelled_net_s: sh.clock.lock().expect("front clock poisoned").cost().net_s,
            conn_drops: sh.conn_drops.load(Ordering::Relaxed),
            injected_wait_s: sh.clock.lock().expect("front clock poisoned").cost().backoff_s,
        }
    }
}

impl Drop for ServeFront {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Why a connection's framing broke (all close the connection).
enum FrameFault {
    /// Peer closed cleanly between frames — not an error.
    Eof,
    /// Truncated header/payload, oversized length, or invalid UTF-8.
    Violation(String),
}

/// Read exactly `buf.len()` bytes, tolerating read timeouts (the idle
/// poll) as long as the shutdown flag stays clear. `started` says whether
/// any earlier byte of this frame already arrived — EOF before the first
/// byte is a clean close, EOF (or shutdown) mid-frame is a violation.
fn read_full(
    sh: &FrontShared,
    stream: &mut TcpStream,
    buf: &mut [u8],
    mut started: bool,
) -> std::result::Result<(), FrameFault> {
    let mut got = 0usize;
    while got < buf.len() {
        if sh.shutdown.load(Ordering::SeqCst) {
            return Err(if started || got > 0 {
                FrameFault::Violation("shutdown mid-frame".into())
            } else {
                FrameFault::Eof
            });
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if started || got > 0 {
                    FrameFault::Violation("connection closed mid-frame".into())
                } else {
                    FrameFault::Eof
                });
            }
            Ok(n) => {
                got += n;
                started = true;
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameFault::Violation(format!("read failed: {e}"))),
        }
    }
    Ok(())
}

/// Read one `[u32 LE len][payload]` frame.
fn read_frame(sh: &FrontShared, stream: &mut TcpStream) -> std::result::Result<String, FrameFault> {
    let mut header = [0u8; 4];
    read_full(sh, stream, &mut header, false)?;
    let len = u32::from_le_bytes(header) as usize;
    if len > sh.opts.max_frame_bytes {
        return Err(FrameFault::Violation(format!(
            "frame length {len} exceeds cap {}",
            sh.opts.max_frame_bytes
        )));
    }
    let mut payload = vec![0u8; len];
    read_full(sh, stream, &mut payload, true)?;
    sh.bytes_in.fetch_add(4 + len as u64, Ordering::Relaxed);
    String::from_utf8(payload)
        .map_err(|_| FrameFault::Violation("frame payload is not UTF-8".into()))
}

/// Write one frame; best-effort (a peer gone mid-write just ends the
/// connection).
fn write_frame(sh: &FrontShared, stream: &mut TcpStream, text: &str) -> bool {
    let bytes = text.as_bytes();
    let header = (bytes.len() as u32).to_le_bytes();
    if stream.write_all(&header).is_err() || stream.write_all(bytes).is_err() {
        return false;
    }
    let _ = stream.flush();
    sh.bytes_out.fetch_add(4 + bytes.len() as u64, Ordering::Relaxed);
    true
}

/// One connection's serve loop: frames in, responses out, until the peer
/// closes, framing breaks, or the front shuts down.
fn handle_connection(sh: Arc<FrontShared>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(sh.opts.read_timeout));
    let _ = stream.set_nodelay(true);
    // Chaos: each accepted connection draws once at the Connection site.
    // A latency spike is charged to the modelled clock (virtual time, no
    // real sleep); any other injection kills the connection before the
    // first frame — the peer sees a clean close, never a hang.
    if let Some(plan) = sh.opts.faults.as_ref() {
        match plan.check(FaultSite::Connection) {
            None => {}
            Some(Injected::Latency(us)) => {
                sh.clock
                    .lock()
                    .expect("front clock poisoned")
                    .charge_backoff(us as f64 / 1e6);
            }
            Some(_) => {
                sh.conn_drops.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }
    loop {
        let cmd = match read_frame(&sh, &mut stream) {
            Ok(text) => text,
            Err(FrameFault::Eof) => return,
            Err(FrameFault::Violation(why)) => {
                sh.framing_errors.fetch_add(1, Ordering::Relaxed);
                let _ = write_frame(&sh, &mut stream, &format!("err framing: {why}"));
                return; // violation closes this connection only
            }
        };
        let response = dispatch(&sh, &cmd);
        let alive = write_frame(&sh, &mut stream, &response);
        sh.frames.fetch_add(1, Ordering::Relaxed);
        // Model the frame pair's wire cost (headers included) like HDFS
        // I/O.
        let frame_bytes = (8 + cmd.len() + response.len()) as u64;
        sh.clock
            .lock()
            .expect("front clock poisoned")
            .charge_net(&sh.overhead, frame_bytes);
        if !alive {
            return;
        }
    }
}

/// Execute one command; application failures become `err <msg>` (the
/// connection survives).
fn dispatch(sh: &FrontShared, cmd: &str) -> String {
    match dispatch_inner(sh, cmd) {
        Ok(resp) => resp,
        Err(e) => format!("err {e}"),
    }
}

/// Snapshot the front's own counters (the `front.*` half of `stats`).
fn front_stats(sh: &FrontShared) -> FrontStats {
    let cost = sh.clock.lock().expect("front clock poisoned").cost();
    FrontStats {
        connections: sh.connections.load(Ordering::Relaxed),
        frames: sh.frames.load(Ordering::Relaxed),
        framing_errors: sh.framing_errors.load(Ordering::Relaxed),
        bytes_in: sh.bytes_in.load(Ordering::Relaxed),
        bytes_out: sh.bytes_out.load(Ordering::Relaxed),
        scored: sh.scored.load(Ordering::Relaxed),
        modelled_net_s: cost.net_s,
        conn_drops: sh.conn_drops.load(Ordering::Relaxed),
        injected_wait_s: cost.backoff_s,
    }
}

fn dispatch_inner(sh: &FrontShared, cmd: &str) -> Result<String> {
    let mut parts = cmd.split_whitespace();
    let verb = parts.next().unwrap_or("");
    match verb {
        "ping" => Ok("ok pong".into()),
        // Liveness probe for degraded-mode monitors: touches no registry
        // lock, so it answers even while reloads or scoring are wedged.
        "health" => Ok(if sh.shutdown.load(Ordering::SeqCst) {
            "ok draining".into()
        } else {
            "ok up".into()
        }),
        "score" => {
            let model = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("score needs: model tenant lane csv".into()))?;
            let tenant = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("score needs: model tenant lane csv".into()))?;
            let lane: Lane = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("score needs: model tenant lane csv".into()))?
                .parse()?;
            let csv = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("score needs: model tenant lane csv".into()))?;
            if parts.next().is_some() {
                return Err(Error::InvalidArgument("score takes exactly 4 arguments".into()));
            }
            let record = csv
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<f32>()
                        .map_err(|_| Error::InvalidArgument(format!("bad feature value `{t}`")))
                })
                .collect::<Result<Vec<f32>>>()?;
            let svc = sh
                .registry
                .get(model)
                .ok_or_else(|| Error::InvalidArgument(format!("no model {model:?}")))?;
            let scored = svc.score_as(&record, tenant, lane)?;
            sh.scored.fetch_add(1, Ordering::Relaxed);
            let csv_out = scored
                .memberships
                .iter()
                .map(|u| u.to_string())
                .collect::<Vec<_>>()
                .join(",");
            Ok(format!("ok {} {}", scored.generation, csv_out))
        }
        "reload" => {
            let model = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("reload needs: model bundle-path".into()))?;
            let path = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("reload needs: model bundle-path".into()))?;
            let bundle = ModelBundle::load_with_faults(
                std::path::Path::new(path),
                sh.opts.faults.as_deref(),
            )?;
            let generation = sh.registry.publish(model, bundle)?;
            Ok(format!("ok {generation}"))
        }
        "retire" => {
            let model = parts
                .next()
                .ok_or_else(|| Error::InvalidArgument("retire needs: model".into()))?;
            sh.registry.retire(model)?;
            Ok("ok retired".into())
        }
        "stats" => {
            // Refresh the unified registry from the live counters, then
            // answer from it — the wire view, the CLI report and the
            // Prometheus exposition all read the same names.
            let reg = metrics::global();
            let front = front_stats(sh);
            front.publish_metrics(reg);
            sh.registry.publish_metrics(reg);
            let doc = json::obj(vec![
                ("front", front.to_json()),
                ("registry", sh.registry.stats_json()),
                ("metrics", reg.to_json()),
            ]);
            Ok(format!("ok {}", json::to_string(&doc)))
        }
        "metrics" => {
            // Prometheus-style text exposition of the unified registry,
            // refreshed from the live counters on every call.
            let reg = metrics::global();
            front_stats(sh).publish_metrics(reg);
            sh.registry.publish_metrics(reg);
            Ok(format!("ok\n{}", reg.prometheus_text()))
        }
        "shutdown" => {
            sh.shutdown.store(true, Ordering::SeqCst);
            Ok("ok shutting-down".into())
        }
        other => Err(Error::InvalidArgument(format!("unknown command `{other}`"))),
    }
}

/// One-shot client: connect, send `cmd` as a frame, return the response
/// payload. Used by `bigfcm serve --connect`, the verify smoke and the
/// integration tests.
pub fn client_call(addr: &str, cmd: &str, timeout: Duration) -> Result<String> {
    use std::net::ToSocketAddrs;
    // Distinguish "down" (refused/unreachable — `Error::Job`) from "slow"
    // (peer up but unresponsive — `Error::Timeout`), so callers can retry
    // a slow front but fail fast on a dead one.
    let is_timeout = |k: ErrorKind| matches!(k, ErrorKind::TimedOut | ErrorKind::WouldBlock);
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| Error::Job(format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| Error::Job(format!("resolve {addr}: no addresses")))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout).map_err(|e| {
        if is_timeout(e.kind()) {
            Error::Timeout(format!("connect {addr}: no answer within {timeout:?}"))
        } else {
            Error::Job(format!("connect {addr}: {e}"))
        }
    })?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| Error::Job(format!("socket timeout: {e}")))?;
    let _ = stream.set_nodelay(true);
    let bytes = cmd.as_bytes();
    let header = (bytes.len() as u32).to_le_bytes();
    stream
        .write_all(&header)
        .and_then(|_| stream.write_all(bytes))
        .map_err(|e| Error::Job(format!("send to {addr}: {e}")))?;
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr).map_err(|e| {
        if is_timeout(e.kind()) {
            Error::Timeout(format!("response header from {addr}: no answer within {timeout:?}"))
        } else {
            Error::Job(format!("response header from {addr}: {e}"))
        }
    })?;
    let len = u32::from_le_bytes(hdr) as usize;
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload).map_err(|e| {
        if is_timeout(e.kind()) {
            Error::Timeout(format!("response payload from {addr}: no answer within {timeout:?}"))
        } else {
            Error::Job(format!("response payload from {addr}: {e}"))
        }
    })?;
    String::from_utf8(payload).map_err(|_| Error::Job("response is not UTF-8".into()))
}
