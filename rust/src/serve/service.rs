//! The online scoring service: bounded admission, micro-batched execution.
//!
//! One request is one raw record; the response is its membership row. The
//! paper's serving regime ("heavy traffic from millions of users" — the
//! ROADMAP north star) is throughput-bound on kernel dispatch, not on any
//! single record's math, so the service never scores records one at a
//! time: a batcher thread pops the first waiting request, lingers a
//! configurable few hundred microseconds for concurrent requests to pile
//! in ([`ServeOptions::linger`], the standard micro-batching trade — a
//! bounded latency tax buys multiplicative throughput), zero-pads the
//! batch up to a row multiple ([`ServeOptions::pad_rows`], the fixed-shape
//! discipline a lowered device kernel wants; padding rows are discarded,
//! the same contract as the chunked runtime) and executes it as **one**
//! [`KernelBackend::score_chunk`] call. The admission queue is bounded:
//! a full queue blocks the caller (backpressure, counted) instead of
//! growing without limit.
//!
//! Metering is part of the contract: queue depth peak, batch fill (mean
//! live records per executed batch — > 1 means coalescing actually
//! happens), pad utilization, and the full request-latency distribution
//! (p50/p95/p99, enqueue → response) surface in [`ServeStats`] and feed
//! the `bigfcm serve-bench` JSON.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::KernelBackend;
use crate::json::{self, Value};
use crate::serve::bundle::ModelBundle;

/// Knobs of one [`ScoreService`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Max live records coalesced into one micro-batch.
    pub max_batch: usize,
    /// Batches are zero-padded up to a multiple of this row count.
    pub pad_rows: usize,
    /// Bounded admission-queue capacity (full queue blocks enqueuers).
    pub queue_cap: usize,
    /// How long the batcher waits after a batch's first request for
    /// concurrent requests to coalesce; zero scores singles immediately.
    pub linger: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_batch: 64,
            pad_rows: 8,
            queue_cap: 1024,
            linger: Duration::from_micros(200),
        }
    }
}

impl ServeOptions {
    pub fn from_config(cfg: &ServeConfig) -> Self {
        Self {
            max_batch: cfg.max_batch.max(1),
            pad_rows: cfg.pad_rows.max(1),
            queue_cap: cfg.queue_cap.max(1),
            linger: Duration::from_micros(cfg.linger_us),
        }
    }
}

/// Snapshot of a service's meters.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Requests answered (successfully or with a batch error).
    pub requests: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Requests that received a batch-execution error.
    pub errors: u64,
    /// Mean live records per executed batch — > 1 means concurrent
    /// requests actually coalesced.
    pub batch_fill: f64,
    /// live rows / padded rows across all batches (cost of the fixed-shape
    /// padding).
    pub pad_utilization: f64,
    /// Deepest the admission queue ever got.
    pub queue_peak: u64,
    /// Times an enqueuer blocked on a full queue.
    pub backpressure_waits: u64,
    /// Request latency percentiles, enqueue → response, microseconds.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    pub max_us: u64,
}

impl ServeStats {
    /// JSON object for the serve-bench emission / bench_diff tracking.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batches", json::num(self.batches as f64)),
            ("errors", json::num(self.errors as f64)),
            ("batch_fill", json::num(self.batch_fill)),
            ("pad_utilization", json::num(self.pad_utilization)),
            ("queue_peak", json::num(self.queue_peak as f64)),
            ("backpressure_waits", json::num(self.backpressure_waits as f64)),
            ("p50_us", json::num(self.p50_us as f64)),
            ("p95_us", json::num(self.p95_us as f64)),
            ("p99_us", json::num(self.p99_us as f64)),
            ("mean_us", json::num(self.mean_us)),
            ("max_us", json::num(self.max_us as f64)),
        ])
    }
}

/// One admitted request: the normalized record and its response channel.
struct Pending {
    row: Vec<f32>,
    tx: Sender<Result<Vec<f32>>>,
}

/// Latency samples the reservoir keeps resident — enough for stable
/// p50/p95/p99 while bounding a long-lived server's metric memory (a
/// production service answers requests indefinitely; an unbounded log
/// would leak 8 B per request forever and make every stats() snapshot
/// sort the whole history).
const LATENCY_RESERVOIR: usize = 65_536;

/// Algorithm-R reservoir over request latencies: the first
/// [`LATENCY_RESERVOIR`] samples are kept verbatim, after which each new
/// sample replaces a uniformly drawn slot with probability cap/seen —
/// every sample ever recorded has equal probability of being resident, so
/// the percentile estimates stay unbiased at O(1) memory.
struct LatencyLog {
    samples: Vec<u64>,
    seen: u64,
    rng: crate::prng::Pcg,
}

impl LatencyLog {
    fn new() -> Self {
        Self {
            samples: Vec::new(),
            seen: 0,
            rng: crate::prng::Pcg::new(0x5C0_4E1A),
        }
    }

    fn record(&mut self, us: u64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(us);
        } else {
            let j = self.rng.next_below(self.seen) as usize;
            if j < LATENCY_RESERVOIR {
                self.samples[j] = us;
            }
        }
    }
}

struct QueueInner {
    items: VecDeque<Pending>,
    closed: bool,
}

struct Shared {
    bundle: ModelBundle,
    backend: Arc<dyn KernelBackend>,
    opts: ServeOptions,
    queue: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    requests: AtomicU64,
    batches: AtomicU64,
    live_rows: AtomicU64,
    padded_rows: AtomicU64,
    queue_peak: AtomicU64,
    backpressure_waits: AtomicU64,
    errors: AtomicU64,
    latencies_us: Mutex<LatencyLog>,
}

/// The micro-batching membership service (see the module docs). Share it
/// behind an `Arc` and call [`Self::score`] from any number of client
/// threads; one batcher thread owns execution.
pub struct ScoreService {
    shared: Arc<Shared>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl ScoreService {
    pub fn new(
        bundle: ModelBundle,
        backend: Arc<dyn KernelBackend>,
        opts: ServeOptions,
    ) -> Result<ScoreService> {
        bundle.validate()?;
        let shared = Arc::new(Shared {
            bundle,
            backend,
            opts,
            queue: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            live_rows: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            backpressure_waits: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies_us: Mutex::new(LatencyLog::new()),
        });
        let for_worker = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("bigfcm-score".to_string())
            .spawn(move || worker_loop(for_worker))
            .map_err(|e| Error::Job(format!("spawning the score batcher thread: {e}")))?;
        Ok(ScoreService { shared, worker: Mutex::new(Some(worker)) })
    }

    /// The model this service scores against.
    pub fn bundle(&self) -> &ModelBundle {
        &self.shared.bundle
    }

    /// Score one raw record: normalize, enqueue, block for the response.
    /// Latency (enqueue → response, including queue wait and batch
    /// compute) is recorded per request.
    pub fn score(&self, record: &[f32]) -> Result<Vec<f32>> {
        let sh = &self.shared;
        if record.len() != sh.bundle.dims() {
            return Err(Error::InvalidArgument(format!(
                "record has {} features, model expects {}",
                record.len(),
                sh.bundle.dims()
            )));
        }
        let mut row = record.to_vec();
        sh.bundle.normalize_row(&mut row);
        let t0 = Instant::now();
        let (tx, rx) = channel();
        {
            let mut q = sh.queue.lock().expect("score queue poisoned");
            while q.items.len() >= sh.opts.queue_cap && !q.closed {
                sh.backpressure_waits.fetch_add(1, Ordering::Relaxed);
                q = sh.not_full.wait(q).expect("score queue poisoned");
            }
            if q.closed {
                return Err(Error::Job("score service is closed".into()));
            }
            q.items.push_back(Pending { row, tx });
            sh.queue_peak.fetch_max(q.items.len() as u64, Ordering::Relaxed);
            sh.not_empty.notify_one();
        }
        let out = rx
            .recv()
            .map_err(|_| Error::Job("score service dropped the request".into()))?;
        let us = t0.elapsed().as_micros() as u64;
        sh.latencies_us.lock().expect("latency log poisoned").record(us);
        sh.requests.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Meter snapshot: percentiles by nearest rank over the latency
    /// reservoir (exact until [`LATENCY_RESERVOIR`] requests, an unbiased
    /// uniform sample of the whole history after).
    pub fn stats(&self) -> ServeStats {
        let sh = &self.shared;
        let mut lat = sh.latencies_us.lock().expect("latency log poisoned").samples.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                return 0;
            }
            let rank = ((lat.len() as f64) * p).ceil() as usize;
            lat[rank.clamp(1, lat.len()) - 1]
        };
        let batches = sh.batches.load(Ordering::Relaxed);
        let live = sh.live_rows.load(Ordering::Relaxed);
        let padded = sh.padded_rows.load(Ordering::Relaxed);
        ServeStats {
            requests: sh.requests.load(Ordering::Relaxed),
            batches,
            errors: sh.errors.load(Ordering::Relaxed),
            batch_fill: if batches > 0 { live as f64 / batches as f64 } else { 0.0 },
            pad_utilization: if padded > 0 { live as f64 / padded as f64 } else { 0.0 },
            queue_peak: sh.queue_peak.load(Ordering::Relaxed),
            backpressure_waits: sh.backpressure_waits.load(Ordering::Relaxed),
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u64>() as f64 / lat.len() as f64
            },
            max_us: lat.last().copied().unwrap_or(0),
        }
    }

    /// Stop admitting requests; queued-but-unscored requests error out.
    /// The batcher drains and exits (joined on drop).
    pub fn close(&self) {
        let sh = &self.shared;
        let mut q = sh.queue.lock().expect("score queue poisoned");
        q.closed = true;
        while let Some(p) = q.items.pop_front() {
            let _ = p.tx.send(Err(Error::Job("score service is closed".into())));
        }
        sh.not_empty.notify_all();
        sh.not_full.notify_all();
    }
}

impl Drop for ScoreService {
    fn drop(&mut self) {
        self.close();
        if let Some(h) = self.worker.get_mut().expect("worker handle poisoned").take() {
            let _ = h.join();
        }
    }
}

/// Batcher thread: pop the first waiting request, linger for company, cut
/// the batch at `max_batch` or the linger deadline, execute off-lock.
fn worker_loop(sh: Arc<Shared>) {
    loop {
        let mut batch: Vec<Pending> = Vec::new();
        {
            let mut q = sh.queue.lock().expect("score queue poisoned");
            loop {
                if let Some(p) = q.items.pop_front() {
                    batch.push(p);
                    break;
                }
                if q.closed {
                    return;
                }
                q = sh.not_empty.wait(q).expect("score queue poisoned");
            }
            let deadline = Instant::now() + sh.opts.linger;
            loop {
                while batch.len() < sh.opts.max_batch {
                    match q.items.pop_front() {
                        Some(p) => batch.push(p),
                        None => break,
                    }
                }
                if batch.len() >= sh.opts.max_batch || q.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, wait) = sh
                    .not_empty
                    .wait_timeout(q, deadline - now)
                    .expect("score queue poisoned");
                q = guard;
                if wait.timed_out() && q.items.is_empty() {
                    break;
                }
            }
            sh.not_full.notify_all();
        }
        execute_batch(&sh, batch);
    }
}

/// Score one coalesced batch through a single `score_chunk` call and fan
/// the rows back out to their requesters.
fn execute_batch(sh: &Shared, batch: Vec<Pending>) {
    let live = batch.len();
    if live == 0 {
        return;
    }
    let d = sh.bundle.dims();
    let c = sh.bundle.clusters();
    let pad = sh.opts.pad_rows.max(1);
    let padded = live.div_ceil(pad) * pad;
    let mut x = Matrix::zeros(padded, d);
    for (i, p) in batch.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&p.row);
    }
    let mut u = Matrix::zeros(padded, c);
    match sh
        .backend
        .score_chunk(sh.bundle.kernel(), &x, &sh.bundle.centers, sh.bundle.m, &mut u)
    {
        Ok(()) => {
            for (i, p) in batch.iter().enumerate() {
                let _ = p.tx.send(Ok(u.row(i).to_vec()));
            }
        }
        Err(e) => {
            sh.errors.fetch_add(live as u64, Ordering::Relaxed);
            let msg = e.to_string();
            for p in &batch {
                let _ = p.tx.send(Err(Error::Job(format!("score batch failed: {msg}"))));
            }
        }
    }
    sh.batches.fetch_add(1, Ordering::Relaxed);
    sh.live_rows.fetch_add(live as u64, Ordering::Relaxed);
    sh.padded_rows.fetch_add(padded as u64, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::native::memberships;
    use crate::fcm::{NativeBackend, SessionAlgo, Variant};

    fn bundle_from_blobs(seed: u64) -> (ModelBundle, Matrix) {
        let data = blobs(256, 3, 3, 0.3, seed);
        let mut centers = Matrix::zeros(3, 3);
        for i in 0..3 {
            centers.row_mut(i).copy_from_slice(data.features.row(i * 80));
        }
        let b = ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0);
        (b, data.features)
    }

    #[test]
    fn single_requests_match_the_membership_oracle() {
        let (bundle, x) = bundle_from_blobs(11);
        let centers = bundle.centers.clone();
        let svc = ScoreService::new(
            bundle,
            Arc::new(NativeBackend),
            ServeOptions { linger: Duration::from_micros(0), ..Default::default() },
        )
        .unwrap();
        let oracle = memberships(&x, &centers, 2.0);
        for k in [0usize, 17, 103, 255] {
            let u = svc.score(x.row(k)).unwrap();
            let s: f32 = u.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {k} sums to {s}");
            for (a, b) in u.iter().zip(oracle.row(k)) {
                assert!((a - b).abs() < 1e-6, "row {k}: {a} vs {b}");
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches >= 1 && stats.batches <= 4);
        assert!(stats.p50_us <= stats.p95_us && stats.p95_us <= stats.p99_us);
    }

    #[test]
    fn concurrent_clients_coalesce_into_micro_batches() {
        let (bundle, x) = bundle_from_blobs(12);
        let svc = Arc::new(
            ScoreService::new(
                bundle,
                Arc::new(NativeBackend),
                ServeOptions {
                    max_batch: 8,
                    linger: Duration::from_millis(50),
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        let x = Arc::new(x);
        let handles: Vec<_> = (0..4)
            .map(|ci| {
                let svc = Arc::clone(&svc);
                let x = Arc::clone(&x);
                std::thread::spawn(move || {
                    for r in 0..5usize {
                        let u = svc.score(x.row(ci * 50 + r)).unwrap();
                        let s: f32 = u.iter().sum();
                        assert!((s - 1.0).abs() < 1e-6);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = svc.stats();
        assert_eq!(stats.requests, 20);
        assert!(
            stats.batch_fill > 1.0,
            "4 concurrent closed-loop clients under a 50ms linger must coalesce \
             (fill {}, {} batches)",
            stats.batch_fill,
            stats.batches
        );
        assert!(stats.pad_utilization > 0.0 && stats.pad_utilization <= 1.0);
    }

    #[test]
    fn closed_service_rejects_and_wrong_dims_error() {
        let (bundle, x) = bundle_from_blobs(13);
        let svc =
            ScoreService::new(bundle, Arc::new(NativeBackend), ServeOptions::default()).unwrap();
        assert!(svc.score(&[1.0, 2.0]).is_err(), "2 features against a 3-feature model");
        svc.close();
        assert!(svc.score(x.row(0)).is_err(), "closed service must reject");
    }

    #[test]
    fn kmeans_service_returns_one_hot_rows() {
        let (mut bundle, x) = bundle_from_blobs(14);
        bundle.algo = SessionAlgo::KMeans;
        let svc =
            ScoreService::new(bundle, Arc::new(NativeBackend), ServeOptions::default()).unwrap();
        let u = svc.score(x.row(5)).unwrap();
        assert_eq!(u.iter().filter(|&&v| v == 1.0).count(), 1);
        assert_eq!(u.iter().filter(|&&v| v == 0.0).count(), 2);
    }
}
