//! The online scoring service: bounded admission, micro-batched execution,
//! hot model reload.
//!
//! One request is one raw record; the response is its membership row. The
//! paper's serving regime ("heavy traffic from millions of users" — the
//! ROADMAP north star) is throughput-bound on kernel dispatch, not on any
//! single record's math, so the service never scores records one at a
//! time: a batcher thread pops the first waiting request, lingers a
//! configurable few hundred microseconds for concurrent requests to pile
//! in ([`ServeOptions::linger`], the standard micro-batching trade — a
//! bounded latency tax buys multiplicative throughput), zero-pads the
//! batch up to a row multiple ([`ServeOptions::pad_rows`], the fixed-shape
//! discipline a lowered device kernel wants; padding rows are discarded,
//! the same contract as the chunked runtime) and executes it as **one**
//! [`KernelBackend::score_chunk`] call. The admission queue is bounded:
//! a full queue blocks the caller (backpressure, counted) instead of
//! growing without limit.
//!
//! **Construction** goes through [`ScoreServiceBuilder`] — the single
//! construction path shared by the CLI, the model registry, the bench
//! harness and the tests.
//!
//! **Hot reload**: the model lives behind an `RwLock<ModelSnap>` holding
//! an `Arc<ModelBundle>` plus a monotonically increasing generation.
//! [`ScoreService::reload`] swaps both atomically; the batch executor
//! snapshots the pair exactly once per micro-batch, so every batch —
//! normalization *and* centers — runs against one internally consistent
//! generation, and every response is stamped with the generation that
//! scored it ([`Scored`]). In-flight batches admitted before a swap
//! finish on the bundle they snapshotted; there is no torn state where a
//! row normalized by an old scaler meets new centers.
//!
//! **Multi-tenancy**: requests carry a tenant id and a priority [`Lane`].
//! The queue is two lanes (high drains first; passed-over normal-lane
//! requests are counted as deprioritized) and each tenant is capped at
//! [`ServeOptions::tenant_quota`] resident requests — the cap rejects
//! immediately with [`Error::QuotaExceeded`] instead of letting one noisy
//! tenant fill the bounded queue and starve the rest.
//!
//! **Shutdown contract** ([`ScoreService::close`]): after `close` returns,
//! every request ever admitted has been answered — requests already
//! claimed into a batch complete normally, requests still queued get
//! [`Error::ShuttingDown`], new requests are rejected, and the batcher
//! thread has exited (joined). Never a hang; the registry's reload/retire
//! path relies on this.
//!
//! Metering is part of the contract: queue depth peak, batch fill (mean
//! live records per executed batch — > 1 means coalescing actually
//! happens), pad utilization, quota rejections, deprioritized pops, the
//! current model generation, and the full request-latency distribution
//! (p50/p95/p99, enqueue → response) surface in [`ServeStats`] and feed
//! the `bigfcm serve-bench` JSON.

use std::collections::{HashMap, VecDeque};
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::KernelBackend;
use crate::json::{self, Value};
use crate::serve::bundle::ModelBundle;
use crate::telemetry::metrics::MetricsRegistry;
use crate::telemetry::trace::{self, ManualSpan};

/// Knobs of one [`ScoreService`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Max live records coalesced into one micro-batch.
    pub max_batch: usize,
    /// Batches are zero-padded up to a multiple of this row count.
    pub pad_rows: usize,
    /// Bounded admission-queue capacity (full queue blocks enqueuers).
    pub queue_cap: usize,
    /// How long the batcher waits after a batch's first request for
    /// concurrent requests to coalesce; zero scores singles immediately.
    pub linger: Duration,
    /// Max requests one tenant may hold in the queue at once; admission
    /// beyond it fails fast with [`Error::QuotaExceeded`]. 0 = unlimited.
    pub tenant_quota: usize,
    /// Per-request deadline (enqueue → batch admission): a request still
    /// queued when it expires is answered [`Error::Deadline`] and shed
    /// before it reaches a batch — a degraded service answers *something*
    /// for every request instead of scoring work nobody is waiting for.
    /// `None` never sheds by age.
    pub deadline: Option<Duration>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            max_batch: 64,
            pad_rows: 8,
            queue_cap: 1024,
            linger: Duration::from_micros(200),
            tenant_quota: 0,
            deadline: None,
        }
    }
}

impl ServeOptions {
    pub fn from_config(cfg: &ServeConfig) -> Self {
        Self {
            max_batch: cfg.max_batch.max(1),
            pad_rows: cfg.pad_rows.max(1),
            queue_cap: cfg.queue_cap.max(1),
            linger: Duration::from_micros(cfg.linger_us),
            tenant_quota: cfg.tenant_quota,
            deadline: if cfg.deadline_us > 0 {
                Some(Duration::from_micros(cfg.deadline_us))
            } else {
                None
            },
        }
    }
}

/// Priority lane of one request: the batcher drains `High` before
/// `Normal`, so latency-critical tenants jump the queue (passed-over
/// normal requests are counted in [`ServeStats::deprioritized`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lane {
    High,
    #[default]
    Normal,
}

impl Lane {
    pub fn as_str(&self) -> &'static str {
        match self {
            Lane::High => "high",
            Lane::Normal => "normal",
        }
    }
}

impl FromStr for Lane {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "high" => Ok(Lane::High),
            "normal" => Ok(Lane::Normal),
            other => Err(Error::InvalidArgument(format!("unknown priority lane `{other}`"))),
        }
    }
}

/// One scored response: the membership row plus the model generation that
/// produced it. Memberships sum to 1 against exactly this generation's
/// bundle — the hot-reload atomicity contract.
#[derive(Clone, Debug)]
pub struct Scored {
    pub memberships: Vec<f32>,
    pub generation: u64,
}

/// Snapshot of a service's meters.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Requests answered (successfully or with a batch error).
    pub requests: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Requests that received a batch-execution error.
    pub errors: u64,
    /// Mean live records per executed batch — > 1 means concurrent
    /// requests actually coalesced.
    pub batch_fill: f64,
    /// live rows / padded rows across all batches (cost of the fixed-shape
    /// padding).
    pub pad_utilization: f64,
    /// Deepest the admission queue ever got.
    pub queue_peak: u64,
    /// Times an enqueuer blocked on a full queue.
    pub backpressure_waits: u64,
    /// Requests rejected at admission because their tenant was over quota.
    pub quota_rejections: u64,
    /// High-lane pops that passed over waiting normal-lane requests.
    pub deprioritized: u64,
    /// Requests shed with [`Error::Deadline`]: still queued when their
    /// deadline expired, answered without ever reaching a batch.
    pub deadline_shed: u64,
    /// Normal-lane requests rejected with [`Error::Overloaded`] at a full
    /// queue (high-lane work keeps backpressure-waiting instead).
    pub overload_shed: u64,
    /// Current model generation (1 at spawn, +1 per reload).
    pub generation: u64,
    /// Request latency percentiles, enqueue → response, microseconds.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    pub max_us: u64,
}

impl ServeStats {
    /// JSON object for the serve-bench emission / bench_diff tracking.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batches", json::num(self.batches as f64)),
            ("errors", json::num(self.errors as f64)),
            ("batch_fill", json::num(self.batch_fill)),
            ("pad_utilization", json::num(self.pad_utilization)),
            ("queue_peak", json::num(self.queue_peak as f64)),
            ("backpressure_waits", json::num(self.backpressure_waits as f64)),
            ("quota_rejections", json::num(self.quota_rejections as f64)),
            ("deprioritized", json::num(self.deprioritized as f64)),
            ("deadline_shed", json::num(self.deadline_shed as f64)),
            ("overload_shed", json::num(self.overload_shed as f64)),
            ("generation", json::num(self.generation as f64)),
            ("p50_us", json::num(self.p50_us as f64)),
            ("p95_us", json::num(self.p95_us as f64)),
            ("p99_us", json::num(self.p99_us as f64)),
            ("mean_us", json::num(self.mean_us)),
            ("max_us", json::num(self.max_us as f64)),
        ])
    }

    /// Publish into `reg` under `{prefix}.*` — the unified-registry view
    /// the wire `stats` and `metrics` verbs expose.
    pub fn publish_metrics(&self, reg: &MetricsRegistry, prefix: &str) {
        let c = |k: &str, v: u64| reg.set_counter(&format!("{prefix}.{k}"), v);
        let g = |k: &str, v: f64| reg.set_gauge(&format!("{prefix}.{k}"), v);
        c("requests", self.requests);
        c("batches", self.batches);
        c("errors", self.errors);
        g("batch_fill", self.batch_fill);
        g("pad_utilization", self.pad_utilization);
        c("queue_peak", self.queue_peak);
        c("backpressure_waits", self.backpressure_waits);
        c("quota_rejections", self.quota_rejections);
        c("deprioritized", self.deprioritized);
        c("deadline_shed", self.deadline_shed);
        c("overload_shed", self.overload_shed);
        c("generation", self.generation);
        g("p50_us", self.p50_us as f64);
        g("p95_us", self.p95_us as f64);
        g("p99_us", self.p99_us as f64);
        g("mean_us", self.mean_us);
        g("max_us", self.max_us as f64);
    }
}

/// One admitted request: the *raw* record (normalization happens at batch
/// execution against that batch's bundle snapshot — normalizing at
/// enqueue would let a reload tear a request across scalers), its tenant
/// (for quota bookkeeping) and its response channel.
struct Pending {
    row: Vec<f32>,
    tenant: Option<String>,
    tx: Sender<Result<Scored>>,
    /// Admission time — the deadline clock ([`ServeOptions::deadline`]).
    enqueued: Instant,
}

/// Latency samples the reservoir keeps resident — enough for stable
/// p50/p95/p99 while bounding a long-lived server's metric memory (a
/// production service answers requests indefinitely; an unbounded log
/// would leak 8 B per request forever and make every stats() snapshot
/// sort the whole history).
const LATENCY_RESERVOIR: usize = 65_536;

/// Algorithm-R reservoir over request latencies: the first
/// [`LATENCY_RESERVOIR`] samples are kept verbatim, after which each new
/// sample replaces a uniformly drawn slot with probability cap/seen —
/// every sample ever recorded has equal probability of being resident, so
/// the percentile estimates stay unbiased at O(1) memory.
struct LatencyLog {
    samples: Vec<u64>,
    seen: u64,
    rng: crate::prng::Pcg,
}

impl LatencyLog {
    fn new() -> Self {
        Self {
            samples: Vec::new(),
            seen: 0,
            rng: crate::prng::Pcg::new(0x5C0_4E1A),
        }
    }

    fn record(&mut self, us: u64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(us);
        } else {
            let j = self.rng.next_below(self.seen) as usize;
            if j < LATENCY_RESERVOIR {
                self.samples[j] = us;
            }
        }
    }
}

/// The model a batch scores against: bundle + generation, swapped as one
/// unit under the `RwLock` so no reader ever sees a bundle from one
/// generation stamped with another.
struct ModelSnap {
    bundle: Arc<ModelBundle>,
    generation: u64,
}

/// Two-lane bounded admission queue with per-tenant residency counts.
struct QueueInner {
    high: VecDeque<Pending>,
    normal: VecDeque<Pending>,
    /// Resident requests per tenant; tracked only when a quota is set.
    tenant_counts: HashMap<String, usize>,
    closed: bool,
}

impl QueueInner {
    fn len(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    /// Pop the next request, high lane first. Increments `deprioritized`
    /// when a high-lane pop passes over waiting normal-lane work.
    fn pop(&mut self, deprioritized: &AtomicU64) -> Option<Pending> {
        let p = if let Some(p) = self.high.pop_front() {
            if !self.normal.is_empty() {
                deprioritized.fetch_add(1, Ordering::Relaxed);
            }
            p
        } else {
            self.normal.pop_front()?
        };
        if let Some(t) = &p.tenant {
            if let Some(n) = self.tenant_counts.get_mut(t) {
                *n = n.saturating_sub(1);
                if *n == 0 {
                    self.tenant_counts.remove(t);
                }
            }
        }
        Some(p)
    }
}

struct Shared {
    model: RwLock<ModelSnap>,
    /// Feature count, immutable for the service's lifetime: every bundle
    /// this service will ever hold (reloads included) has these dims, so
    /// request validation never needs the model lock.
    dims: usize,
    backend: Arc<dyn KernelBackend>,
    opts: ServeOptions,
    queue: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    requests: AtomicU64,
    batches: AtomicU64,
    live_rows: AtomicU64,
    padded_rows: AtomicU64,
    queue_peak: AtomicU64,
    backpressure_waits: AtomicU64,
    quota_rejections: AtomicU64,
    deprioritized: AtomicU64,
    deadline_shed: AtomicU64,
    overload_shed: AtomicU64,
    errors: AtomicU64,
    latencies_us: Mutex<LatencyLog>,
    /// Serve-root trace span: opened at spawn, ended at close. Batch
    /// spans parent onto `trace_root_id` (the batcher thread has no
    /// ambient stack linking it to the spawner).
    trace_root: Mutex<Option<ManualSpan>>,
    trace_root_id: u64,
}

/// Builds a [`ScoreService`] — the one construction path. Start from a
/// bundle, layer options (a whole [`ServeOptions`], a [`ServeConfig`], or
/// individual knobs — later wins), then [`Self::spawn`] with a backend.
pub struct ScoreServiceBuilder {
    bundle: ModelBundle,
    opts: ServeOptions,
}

impl ScoreServiceBuilder {
    pub fn new(bundle: ModelBundle) -> Self {
        Self { bundle, opts: ServeOptions::default() }
    }

    /// Replace all options at once.
    pub fn options(mut self, opts: ServeOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Replace all options from the config file's serve section.
    pub fn from_config(mut self, cfg: &ServeConfig) -> Self {
        self.opts = ServeOptions::from_config(cfg);
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.opts.max_batch = n.max(1);
        self
    }

    pub fn pad_rows(mut self, n: usize) -> Self {
        self.opts.pad_rows = n.max(1);
        self
    }

    pub fn queue_cap(mut self, n: usize) -> Self {
        self.opts.queue_cap = n.max(1);
        self
    }

    pub fn linger(mut self, d: Duration) -> Self {
        self.opts.linger = d;
        self
    }

    pub fn tenant_quota(mut self, n: usize) -> Self {
        self.opts.tenant_quota = n;
        self
    }

    /// Per-request deadline; `None` never sheds by age.
    pub fn deadline(mut self, d: Option<Duration>) -> Self {
        self.opts.deadline = d;
        self
    }

    /// Validate the bundle, spawn the batcher thread, return the running
    /// service (generation 1).
    pub fn spawn(self, backend: Arc<dyn KernelBackend>) -> Result<ScoreService> {
        self.bundle.validate()?;
        let dims = self.bundle.dims();
        let trace_root = trace::global().begin("serve", "serve", 0);
        let trace_root_id = trace_root.id;
        let shared = Arc::new(Shared {
            model: RwLock::new(ModelSnap { bundle: Arc::new(self.bundle), generation: 1 }),
            dims,
            backend,
            opts: self.opts,
            queue: Mutex::new(QueueInner {
                high: VecDeque::new(),
                normal: VecDeque::new(),
                tenant_counts: HashMap::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            live_rows: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            backpressure_waits: AtomicU64::new(0),
            quota_rejections: AtomicU64::new(0),
            deprioritized: AtomicU64::new(0),
            deadline_shed: AtomicU64::new(0),
            overload_shed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies_us: Mutex::new(LatencyLog::new()),
            trace_root: Mutex::new(Some(trace_root)),
            trace_root_id,
        });
        let for_worker = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("bigfcm-score".to_string())
            .spawn(move || worker_loop(for_worker))
            .map_err(|e| Error::Job(format!("spawning the score batcher thread: {e}")))?;
        Ok(ScoreService { shared, worker: Mutex::new(Some(worker)) })
    }
}

/// The micro-batching membership service (see the module docs). Built via
/// [`ScoreServiceBuilder`]; share it behind an `Arc` and call
/// [`Self::score`] / [`Self::score_as`] from any number of client
/// threads; one batcher thread owns execution.
pub struct ScoreService {
    shared: Arc<Shared>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl ScoreService {
    /// The construction entry point: `ScoreService::builder(bundle)
    /// .max_batch(32).spawn(backend)`.
    pub fn builder(bundle: ModelBundle) -> ScoreServiceBuilder {
        ScoreServiceBuilder::new(bundle)
    }

    /// The bundle currently scoring (the latest generation's).
    pub fn bundle(&self) -> Arc<ModelBundle> {
        Arc::clone(&self.shared.model.read().expect("model lock poisoned").bundle)
    }

    /// The current model generation (1 at spawn, +1 per reload).
    pub fn generation(&self) -> u64 {
        self.shared.model.read().expect("model lock poisoned").generation
    }

    /// Hot-swap the model. The new bundle must validate and match the
    /// serving dims (a different feature space is a different service).
    /// Returns the new generation; batches admitted before the swap
    /// complete on the old bundle, batches cut after it score on the new
    /// one — each stamped accordingly.
    pub fn reload(&self, bundle: ModelBundle) -> Result<u64> {
        bundle.validate()?;
        if bundle.dims() != self.shared.dims {
            return Err(Error::Bundle(format!(
                "reload bundle has {} dims, service serves {}",
                bundle.dims(),
                self.shared.dims
            )));
        }
        let mut snap = self.shared.model.write().expect("model lock poisoned");
        snap.generation += 1;
        snap.bundle = Arc::new(bundle);
        Ok(snap.generation)
    }

    /// Score one raw record on the normal lane, untracked tenant; returns
    /// just the membership row. See [`Self::score_as`].
    pub fn score(&self, record: &[f32]) -> Result<Vec<f32>> {
        self.score_stamped(record).map(|s| s.memberships)
    }

    /// Score one raw record on the normal lane, untracked tenant; returns
    /// the generation-stamped response.
    pub fn score_stamped(&self, record: &[f32]) -> Result<Scored> {
        self.enqueue(record, None, Lane::Normal)
    }

    /// Score one raw record for a tenant on a priority lane: admission
    /// checks the tenant's quota, the response is generation-stamped.
    /// Latency (enqueue → response, including queue wait and batch
    /// compute) is recorded per request.
    pub fn score_as(&self, record: &[f32], tenant: &str, lane: Lane) -> Result<Scored> {
        self.enqueue(record, Some(tenant), lane)
    }

    fn enqueue(&self, record: &[f32], tenant: Option<&str>, lane: Lane) -> Result<Scored> {
        let sh = &self.shared;
        if record.len() != sh.dims {
            return Err(Error::InvalidArgument(format!(
                "record has {} features, model expects {}",
                record.len(),
                sh.dims
            )));
        }
        let row = record.to_vec();
        let t0 = Instant::now();
        let (tx, rx) = channel();
        {
            let mut q = sh.queue.lock().expect("score queue poisoned");
            if q.closed {
                return Err(Error::ShuttingDown);
            }
            // Quota check before the backpressure wait: an over-quota
            // tenant fails fast instead of camping on the full-queue
            // condvar and adding to the very congestion quotas exist to
            // bound.
            let tracked = match tenant {
                Some(t) if sh.opts.tenant_quota > 0 => {
                    let held = q.tenant_counts.get(t).copied().unwrap_or(0);
                    if held >= sh.opts.tenant_quota {
                        sh.quota_rejections.fetch_add(1, Ordering::Relaxed);
                        return Err(Error::QuotaExceeded(t.to_string()));
                    }
                    Some(t.to_string())
                }
                _ => None,
            };
            while q.len() >= sh.opts.queue_cap && !q.closed {
                // Degraded mode sheds the sheddable lane first: normal
                // work bounces immediately instead of camping on the
                // condvar, keeping the bounded queue's residual capacity
                // for high-lane (latency-critical) tenants, which retain
                // the blocking backpressure contract.
                if lane == Lane::Normal {
                    sh.overload_shed.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::Overloaded);
                }
                sh.backpressure_waits.fetch_add(1, Ordering::Relaxed);
                q = sh.not_full.wait(q).expect("score queue poisoned");
            }
            if q.closed {
                return Err(Error::ShuttingDown);
            }
            if let Some(t) = &tracked {
                // Recheck after the wait: the lock was released on the
                // condvar, so same-tenant waiters may have admitted since
                // the fail-fast check above.
                let held = q.tenant_counts.get(t).copied().unwrap_or(0);
                if held >= sh.opts.tenant_quota {
                    sh.quota_rejections.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::QuotaExceeded(t.clone()));
                }
                *q.tenant_counts.entry(t.clone()).or_insert(0) += 1;
            }
            let pending = Pending { row, tenant: tracked, tx, enqueued: t0 };
            match lane {
                Lane::High => q.high.push_back(pending),
                Lane::Normal => q.normal.push_back(pending),
            }
            sh.queue_peak.fetch_max(q.len() as u64, Ordering::Relaxed);
            sh.not_empty.notify_one();
        }
        let out = rx
            .recv()
            .map_err(|_| Error::Job("score service dropped the request".into()))?;
        let us = t0.elapsed().as_micros() as u64;
        sh.latencies_us.lock().expect("latency log poisoned").record(us);
        sh.requests.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Meter snapshot: percentiles by nearest rank over the latency
    /// reservoir (exact until [`LATENCY_RESERVOIR`] requests, an unbiased
    /// uniform sample of the whole history after).
    pub fn stats(&self) -> ServeStats {
        let sh = &self.shared;
        let mut lat = sh.latencies_us.lock().expect("latency log poisoned").samples.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                return 0;
            }
            let rank = ((lat.len() as f64) * p).ceil() as usize;
            lat[rank.clamp(1, lat.len()) - 1]
        };
        let batches = sh.batches.load(Ordering::Relaxed);
        let live = sh.live_rows.load(Ordering::Relaxed);
        let padded = sh.padded_rows.load(Ordering::Relaxed);
        ServeStats {
            requests: sh.requests.load(Ordering::Relaxed),
            batches,
            errors: sh.errors.load(Ordering::Relaxed),
            batch_fill: if batches > 0 { live as f64 / batches as f64 } else { 0.0 },
            pad_utilization: if padded > 0 { live as f64 / padded as f64 } else { 0.0 },
            queue_peak: sh.queue_peak.load(Ordering::Relaxed),
            backpressure_waits: sh.backpressure_waits.load(Ordering::Relaxed),
            quota_rejections: sh.quota_rejections.load(Ordering::Relaxed),
            deprioritized: sh.deprioritized.load(Ordering::Relaxed),
            deadline_shed: sh.deadline_shed.load(Ordering::Relaxed),
            overload_shed: sh.overload_shed.load(Ordering::Relaxed),
            generation: self.generation(),
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_us: if lat.is_empty() {
                0.0
            } else {
                lat.iter().sum::<u64>() as f64 / lat.len() as f64
            },
            max_us: lat.last().copied().unwrap_or(0),
        }
    }

    /// Drain-and-reject shutdown. On return: new requests are rejected
    /// ([`Error::ShuttingDown`]), every request still queued has been
    /// answered with the same error, requests already claimed into a
    /// batch have completed normally, and the batcher thread has exited
    /// (joined here, not left to race `Drop`). Idempotent.
    pub fn close(&self) {
        let sh = &self.shared;
        {
            let mut q = sh.queue.lock().expect("score queue poisoned");
            q.closed = true;
            while let Some(p) = q.pop(&sh.deprioritized) {
                let _ = p.tx.send(Err(Error::ShuttingDown));
            }
            sh.not_empty.notify_all();
            sh.not_full.notify_all();
        }
        if let Some(h) = self.worker.lock().expect("worker handle poisoned").take() {
            let _ = h.join();
        }
        // Close the serve-root span exactly once (close() runs again from
        // Drop); telemetry locks degrade to drop rather than poison.
        if let Some(root) = sh.trace_root.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let requests = sh.requests.load(Ordering::Relaxed);
            trace::global().end(&root, vec![("requests", requests.to_string())]);
        }
    }
}

impl Drop for ScoreService {
    fn drop(&mut self) {
        self.close();
    }
}

/// Pop the next request that still has time to live. Requests whose
/// [`ServeOptions::deadline`] expired while they queued are answered
/// [`Error::Deadline`] right here — shed before batch admission, never
/// scored — and counted in [`ServeStats::deadline_shed`].
fn pop_live(q: &mut QueueInner, sh: &Shared) -> Option<Pending> {
    while let Some(p) = q.pop(&sh.deprioritized) {
        let expired = sh
            .opts
            .deadline
            .map(|d| p.enqueued.elapsed() > d)
            .unwrap_or(false);
        if !expired {
            return Some(p);
        }
        sh.deadline_shed.fetch_add(1, Ordering::Relaxed);
        let _ = p.tx.send(Err(Error::Deadline));
    }
    None
}

/// Batcher thread: pop the first waiting request, linger for company, cut
/// the batch at `max_batch` or the linger deadline, execute off-lock.
fn worker_loop(sh: Arc<Shared>) {
    loop {
        let mut batch: Vec<Pending> = Vec::new();
        {
            let mut q = sh.queue.lock().expect("score queue poisoned");
            loop {
                if let Some(p) = pop_live(&mut q, &sh) {
                    batch.push(p);
                    break;
                }
                if q.closed {
                    return;
                }
                q = sh.not_empty.wait(q).expect("score queue poisoned");
            }
            let deadline = Instant::now() + sh.opts.linger;
            loop {
                while batch.len() < sh.opts.max_batch {
                    match pop_live(&mut q, &sh) {
                        Some(p) => batch.push(p),
                        None => break,
                    }
                }
                if batch.len() >= sh.opts.max_batch || q.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, wait) = sh
                    .not_empty
                    .wait_timeout(q, deadline - now)
                    .expect("score queue poisoned");
                q = guard;
                if wait.timed_out() && q.len() == 0 {
                    break;
                }
            }
            sh.not_full.notify_all();
        }
        execute_batch(&sh, batch);
    }
}

/// Score one coalesced batch through a single `score_chunk` call and fan
/// the rows back out to their requesters. The model (bundle + generation)
/// is snapshotted exactly once: normalization and centers come from the
/// same generation, and every response is stamped with it.
fn execute_batch(sh: &Shared, batch: Vec<Pending>) {
    let live = batch.len();
    if live == 0 {
        return;
    }
    let (bundle, generation) = {
        let snap = sh.model.read().expect("model lock poisoned");
        (Arc::clone(&snap.bundle), snap.generation)
    };
    let d = bundle.dims();
    let c = bundle.clusters();
    let pad = sh.opts.pad_rows.max(1);
    let padded = live.div_ceil(pad) * pad;
    let mut x = Matrix::zeros(padded, d);
    for (i, p) in batch.iter().enumerate() {
        let row = x.row_mut(i);
        row.copy_from_slice(&p.row);
        bundle.normalize_row(row);
    }
    let mut u = Matrix::zeros(padded, c);
    let mut batch_span = trace::global().span_child("batch", "serve", sh.trace_root_id);
    batch_span.attr("live", live.to_string());
    batch_span.attr("padded", padded.to_string());
    batch_span.attr("generation", generation.to_string());
    let scored = {
        let _score_span = trace::global().span("score_chunk", "serve");
        sh.backend.score_chunk(bundle.kernel(), &x, &bundle.centers, bundle.m, &mut u)
    };
    match scored {
        Ok(()) => {
            for (i, p) in batch.iter().enumerate() {
                let _ = p.tx.send(Ok(Scored { memberships: u.row(i).to_vec(), generation }));
            }
        }
        Err(e) => {
            sh.errors.fetch_add(live as u64, Ordering::Relaxed);
            let msg = e.to_string();
            for p in &batch {
                let _ = p.tx.send(Err(Error::Job(format!("score batch failed: {msg}"))));
            }
        }
    }
    sh.batches.fetch_add(1, Ordering::Relaxed);
    sh.live_rows.fetch_add(live as u64, Ordering::Relaxed);
    sh.padded_rows.fetch_add(padded as u64, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::native::memberships;
    use crate::fcm::{NativeBackend, SessionAlgo, Variant};

    fn bundle_from_blobs(seed: u64) -> (ModelBundle, Matrix) {
        let data = blobs(256, 3, 3, 0.3, seed);
        let mut centers = Matrix::zeros(3, 3);
        for i in 0..3 {
            centers.row_mut(i).copy_from_slice(data.features.row(i * 80));
        }
        let b = ModelBundle::new(centers, SessionAlgo::Fcm, Variant::Fast, 2.0);
        (b, data.features)
    }

    #[test]
    fn single_requests_match_the_membership_oracle() {
        let (bundle, x) = bundle_from_blobs(11);
        let centers = bundle.centers.clone();
        let svc = ScoreService::builder(bundle)
            .linger(Duration::from_micros(0))
            .spawn(Arc::new(NativeBackend))
            .unwrap();
        let oracle = memberships(&x, &centers, 2.0);
        for k in [0usize, 17, 103, 255] {
            let u = svc.score(x.row(k)).unwrap();
            let s: f32 = u.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {k} sums to {s}");
            for (a, b) in u.iter().zip(oracle.row(k)) {
                assert!((a - b).abs() < 1e-6, "row {k}: {a} vs {b}");
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.requests, 4);
        assert!(stats.batches >= 1 && stats.batches <= 4);
        assert_eq!(stats.generation, 1);
        assert!(stats.p50_us <= stats.p95_us && stats.p95_us <= stats.p99_us);
    }

    #[test]
    fn concurrent_clients_coalesce_into_micro_batches() {
        let (bundle, x) = bundle_from_blobs(12);
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .max_batch(8)
                .linger(Duration::from_millis(50))
                .spawn(Arc::new(NativeBackend))
                .unwrap(),
        );
        let x = Arc::new(x);
        let handles: Vec<_> = (0..4)
            .map(|ci| {
                let svc = Arc::clone(&svc);
                let x = Arc::clone(&x);
                std::thread::spawn(move || {
                    for r in 0..5usize {
                        let u = svc.score(x.row(ci * 50 + r)).unwrap();
                        let s: f32 = u.iter().sum();
                        assert!((s - 1.0).abs() < 1e-6);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = svc.stats();
        assert_eq!(stats.requests, 20);
        assert!(
            stats.batch_fill > 1.0,
            "4 concurrent closed-loop clients under a 50ms linger must coalesce \
             (fill {}, {} batches)",
            stats.batch_fill,
            stats.batches
        );
        assert!(stats.pad_utilization > 0.0 && stats.pad_utilization <= 1.0);
    }

    #[test]
    fn closed_service_rejects_and_wrong_dims_error() {
        let (bundle, x) = bundle_from_blobs(13);
        let svc = ScoreService::builder(bundle).spawn(Arc::new(NativeBackend)).unwrap();
        assert!(svc.score(&[1.0, 2.0]).is_err(), "2 features against a 3-feature model");
        svc.close();
        match svc.score(x.row(0)) {
            Err(Error::ShuttingDown) => {}
            other => panic!("closed service must reject with ShuttingDown, got {other:?}"),
        }
        // close() is idempotent and already joined the batcher.
        svc.close();
    }

    #[test]
    fn kmeans_service_returns_one_hot_rows() {
        let (mut bundle, x) = bundle_from_blobs(14);
        bundle.algo = SessionAlgo::KMeans;
        let svc = ScoreService::builder(bundle).spawn(Arc::new(NativeBackend)).unwrap();
        let u = svc.score(x.row(5)).unwrap();
        assert_eq!(u.iter().filter(|&&v| v == 1.0).count(), 1);
        assert_eq!(u.iter().filter(|&&v| v == 0.0).count(), 2);
    }

    #[test]
    fn reload_bumps_generation_and_swaps_centers() {
        let (bundle, x) = bundle_from_blobs(15);
        let (other, _) = bundle_from_blobs(16);
        let other_centers = other.centers.clone();
        let svc = ScoreService::builder(bundle)
            .linger(Duration::from_micros(0))
            .spawn(Arc::new(NativeBackend))
            .unwrap();
        let before = svc.score_stamped(x.row(3)).unwrap();
        assert_eq!(before.generation, 1);
        let g = svc.reload(other).unwrap();
        assert_eq!(g, 2);
        assert_eq!(svc.generation(), 2);
        let after = svc.score_stamped(x.row(3)).unwrap();
        assert_eq!(after.generation, 2);
        let oracle = memberships(&x, &other_centers, 2.0);
        for (a, b) in after.memberships.iter().zip(oracle.row(3)) {
            assert!((a - b).abs() < 1e-6, "post-reload row: {a} vs {b}");
        }
    }

    #[test]
    fn reload_rejects_mismatched_dims() {
        let (bundle, _) = bundle_from_blobs(17);
        let svc = ScoreService::builder(bundle).spawn(Arc::new(NativeBackend)).unwrap();
        let narrow = ModelBundle::new(Matrix::zeros(3, 2), SessionAlgo::Fcm, Variant::Fast, 2.0);
        assert!(svc.reload(narrow).is_err(), "2-dim bundle into a 3-dim service");
        assert_eq!(svc.generation(), 1, "failed reload must not bump the generation");
    }

    /// Delegates everything to [`NativeBackend`] but holds the first
    /// `score_chunk` call at a gate, so tests can pin requests resident
    /// in the queue deterministically (the batcher is stuck executing).
    struct GatedBackend {
        entered: std::sync::atomic::AtomicU64,
        release: std::sync::atomic::AtomicBool,
    }

    impl GatedBackend {
        fn new() -> Self {
            Self {
                entered: std::sync::atomic::AtomicU64::new(0),
                release: std::sync::atomic::AtomicBool::new(false),
            }
        }

        fn wait_entered(&self) {
            let t0 = Instant::now();
            while self.entered.load(Ordering::SeqCst) == 0 {
                assert!(t0.elapsed() < Duration::from_secs(5), "batcher never reached the gate");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }

    impl crate::fcm::KernelBackend for GatedBackend {
        fn exact_partials(
            &self,
            kernel: crate::fcm::Kernel,
            x: &Matrix,
            v: &Matrix,
            w: &[f32],
            m: f64,
        ) -> Result<crate::fcm::Partials> {
            NativeBackend.exact_partials(kernel, x, v, w, m)
        }

        fn partials_with_bounds(
            &self,
            kernel: crate::fcm::Kernel,
            x: &Matrix,
            v: &Matrix,
            w: &[f32],
            m: f64,
            rows: &mut crate::fcm::BoundRows,
        ) -> Result<crate::fcm::Partials> {
            NativeBackend.partials_with_bounds(kernel, x, v, w, m, rows)
        }

        fn name(&self) -> &'static str {
            "gated-native"
        }

        fn score_chunk(
            &self,
            kernel: crate::fcm::Kernel,
            x: &Matrix,
            v: &Matrix,
            m: f64,
            u: &mut Matrix,
        ) -> Result<()> {
            if self.entered.fetch_add(1, Ordering::SeqCst) == 0 {
                let t0 = Instant::now();
                while !self.release.load(Ordering::SeqCst) {
                    assert!(t0.elapsed() < Duration::from_secs(5), "gate never released");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            NativeBackend.score_chunk(kernel, x, v, m, u)
        }
    }

    #[test]
    fn quota_rejects_over_quota_tenant_at_admission() {
        let (bundle, x) = bundle_from_blobs(18);
        let gate = Arc::new(GatedBackend::new());
        // max_batch 1 + linger 0: the batcher claims exactly the first
        // request and blocks at the gate executing it, so the two
        // requests behind it stay resident — the tenant's full quota.
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .max_batch(1)
                .linger(Duration::from_micros(0))
                .tenant_quota(2)
                .spawn(Arc::clone(&gate) as Arc<dyn KernelBackend>)
                .unwrap(),
        );
        let x = Arc::new(x);
        let client = |i: usize| {
            let svc = Arc::clone(&svc);
            let x = Arc::clone(&x);
            std::thread::spawn(move || svc.score_as(x.row(i), "noisy", Lane::Normal))
        };
        let c1 = client(0);
        gate.wait_entered(); // batcher is now stuck on request 1
        let c2 = client(1);
        let c3 = client(2);
        // Let 2 and 3 reach the queue (they block in recv after enqueue).
        let t0 = Instant::now();
        while svc.stats().queue_peak < 2 {
            assert!(t0.elapsed() < Duration::from_secs(5), "requests 2/3 never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Tenant holds 2 resident = quota: the next request bounces
        // immediately, and a different tenant still gets in.
        match svc.score_as(x.row(3), "noisy", Lane::Normal) {
            Err(Error::QuotaExceeded(t)) => assert_eq!(t, "noisy"),
            other => panic!("expected QuotaExceeded, got {other:?}"),
        }
        assert_eq!(svc.stats().quota_rejections, 1);
        let c4 = client_as(&svc, &x, 4, "quiet");
        gate.release.store(true, Ordering::SeqCst);
        for h in [c1, c2, c3, c4] {
            let out = h.join().unwrap().unwrap();
            let s: f32 = out.memberships.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    fn client_as(
        svc: &Arc<ScoreService>,
        x: &Arc<Matrix>,
        row: usize,
        tenant: &str,
    ) -> std::thread::JoinHandle<Result<Scored>> {
        let svc = Arc::clone(svc);
        let x = Arc::clone(x);
        let tenant = tenant.to_string();
        std::thread::spawn(move || svc.score_as(x.row(row), &tenant, Lane::Normal))
    }

    #[test]
    fn close_answers_every_admitted_request() {
        let (bundle, x) = bundle_from_blobs(20);
        let gate = Arc::new(GatedBackend::new());
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .max_batch(1)
                .linger(Duration::from_micros(0))
                .spawn(Arc::clone(&gate) as Arc<dyn KernelBackend>)
                .unwrap(),
        );
        let x = Arc::new(x);
        let c1 = client_as(&svc, &x, 0, "t");
        gate.wait_entered(); // request 1 claimed into a batch, stuck at the gate
        let c2 = client_as(&svc, &x, 1, "t");
        let t0 = Instant::now();
        while svc.stats().queue_peak < 1 {
            assert!(t0.elapsed() < Duration::from_secs(5), "request 2 never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Close while a batch is in flight and another request is queued.
        // Contract: the claimed request completes, the queued one gets
        // ShuttingDown, close() returns without hanging (it joins the
        // batcher, which needs the gate open to finish — release first
        // from a helper thread to prove close really waits for it).
        let closer = {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || svc.close())
        };
        std::thread::sleep(Duration::from_millis(20));
        gate.release.store(true, Ordering::SeqCst);
        closer.join().unwrap();
        let r1 = c1.join().unwrap();
        let r2 = c2.join().unwrap();
        assert!(r1.is_ok(), "claimed request must complete: {r1:?}");
        match r2 {
            Err(Error::ShuttingDown) => {}
            other => panic!("queued request must get ShuttingDown, got {other:?}"),
        }
    }

    #[test]
    fn expired_request_is_shed_with_deadline_before_scoring() {
        let (bundle, x) = bundle_from_blobs(21);
        let gate = Arc::new(GatedBackend::new());
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .max_batch(1)
                .linger(Duration::from_micros(0))
                .deadline(Some(Duration::from_millis(5)))
                .spawn(Arc::clone(&gate) as Arc<dyn KernelBackend>)
                .unwrap(),
        );
        let x = Arc::new(x);
        let c1 = client_as(&svc, &x, 0, "t");
        gate.wait_entered(); // request 1 claimed into a batch before expiry
        let c2 = client_as(&svc, &x, 1, "t");
        let t0 = Instant::now();
        while svc.stats().queue_peak < 1 {
            assert!(t0.elapsed() < Duration::from_secs(5), "request 2 never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Let request 2 outlive its deadline in the queue, then unblock.
        std::thread::sleep(Duration::from_millis(25));
        gate.release.store(true, Ordering::SeqCst);
        let r1 = c1.join().unwrap();
        assert!(r1.is_ok(), "claimed-before-expiry request must score: {r1:?}");
        match c2.join().unwrap() {
            Err(Error::Deadline) => {}
            other => panic!("expired request must get Deadline, got {other:?}"),
        }
        let stats = svc.stats();
        assert_eq!(stats.deadline_shed, 1);
        assert_eq!(stats.requests, 2, "shed requests are still answered requests");
    }

    #[test]
    fn full_queue_sheds_normal_lane_but_backpressures_high_lane() {
        let (bundle, x) = bundle_from_blobs(22);
        let gate = Arc::new(GatedBackend::new());
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .max_batch(1)
                .queue_cap(1)
                .linger(Duration::from_micros(0))
                .spawn(Arc::clone(&gate) as Arc<dyn KernelBackend>)
                .unwrap(),
        );
        let x = Arc::new(x);
        let c1 = client_as(&svc, &x, 0, "t");
        gate.wait_entered(); // batcher stuck executing request 1
        let c2 = client_as(&svc, &x, 1, "t"); // fills the 1-slot queue
        let t0 = Instant::now();
        while svc.stats().queue_peak < 1 {
            assert!(t0.elapsed() < Duration::from_secs(5), "request 2 never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Normal lane at a full queue: immediate structured rejection.
        match svc.score_as(x.row(2), "t", Lane::Normal) {
            Err(Error::Overloaded) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(svc.stats().overload_shed, 1);
        // High lane keeps the blocking backpressure contract instead.
        let high = {
            let svc = Arc::clone(&svc);
            let x = Arc::clone(&x);
            std::thread::spawn(move || svc.score_as(x.row(3), "t", Lane::High))
        };
        let t0 = Instant::now();
        while svc.stats().backpressure_waits == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "high-lane client never waited");
            std::thread::sleep(Duration::from_millis(1));
        }
        gate.release.store(true, Ordering::SeqCst);
        for h in [c1, c2, high] {
            let out = h.join().unwrap().unwrap();
            let s: f32 = out.memberships.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn lane_parses_and_high_lane_counts_deprioritized() {
        assert_eq!("high".parse::<Lane>().unwrap(), Lane::High);
        assert_eq!("normal".parse::<Lane>().unwrap(), Lane::Normal);
        assert!("urgent".parse::<Lane>().is_err());
        let (bundle, x) = bundle_from_blobs(19);
        let svc = Arc::new(
            ScoreService::builder(bundle)
                .max_batch(1)
                .linger(Duration::from_micros(0))
                .spawn(Arc::new(NativeBackend))
                .unwrap(),
        );
        let x = Arc::new(x);
        // Saturate both lanes from many threads; with max_batch 1 every
        // pop is a scheduling decision, so some high pops should observe
        // waiting normal work.
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let svc = Arc::clone(&svc);
                let x = Arc::clone(&x);
                let lane = if i % 2 == 0 { Lane::High } else { Lane::Normal };
                std::thread::spawn(move || {
                    for r in 0..10usize {
                        svc.score_as(x.row((i * 10 + r) % 256), "t", lane).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.stats().requests, 80);
        // deprioritized is scheduling-dependent; just assert the meter is
        // wired (it can be 0 on a fast machine, so no hard lower bound).
        let _ = svc.stats().deprioritized;
    }
}
