//! Bulk ScoreJob: label an entire block store with memberships as one
//! MapReduce job.
//!
//! The paper sells the membership matrix as "a preprocessing step in many
//! data mining process implementations" — which means the common offline
//! workload is *score everything*: stream every block of a (possibly
//! multi-GiB) store against a trained [`ModelBundle`] and write the
//! memberships back out. This job does exactly that through the engine's
//! existing streaming path — blocks arrive via the byte-budgeted cache,
//! locality queues and prefetcher, are normalized with the bundle's
//! scaler, scored in one [`crate::fcm::KernelBackend::score_chunk`] call,
//! compressed to **top-k sparse rows** (`[idx₀, u₀, idx₁, u₁, …]`,
//! descending membership; k ≪ C keeps output bytes per record at 8k
//! regardless of C), and appended to a [`BlockStoreWriter`] output store.
//!
//! Map tasks finish out of order but output block `i` must be block `i`
//! of the membership store (records line up positionally with the input
//! store), so completed blocks pass through a bounded **reorder buffer**:
//! each task inserts its block under the writer lock and drains the
//! in-order prefix — pending out-of-order blocks are bounded by worker
//! count plus straggler skew, never O(store). Doomed (fault-injected)
//! attempts skip the write exactly like they skip the pruning slab, so
//! Hadoop-style re-execution never duplicates an append.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::backend::membership_row_from_d2;
use crate::fcm::native::DIST_EPS;
use crate::fcm::{Kernel, KernelBackend, QuantMode, QuantSidecar};
use crate::hdfs::{BlockStore, BlockStoreWriter};
use crate::mapreduce::{DistributedCache, Engine, JobStats, MapReduceJob, TaskCtx};
use crate::serve::bundle::ModelBundle;
use crate::telemetry::{metrics, trace};

/// Mergeable per-block aggregates the reduce folds (the actual membership
/// rows go to disk in the map phase, not through the shuffle).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScoreJobTotals {
    /// Records scored.
    pub rows: u64,
    /// Σ top-1 membership over all records — mean top-1 confidence is
    /// `top1_mass / rows`, a cheap model-quality aggregate.
    pub top1_mass: f64,
}

impl ScoreJobTotals {
    fn merged(self, other: ScoreJobTotals) -> ScoreJobTotals {
        ScoreJobTotals {
            rows: self.rows + other.rows,
            top1_mass: self.top1_mass + other.top1_mass,
        }
    }
}

/// Everything a bulk scoring run produces.
pub struct ScoreJobOutcome {
    /// The membership store (2k columns: k `(center, membership)` pairs
    /// per record, descending membership), reopenable later via
    /// [`BlockStore::open_disk`].
    pub store: BlockStore,
    pub totals: ScoreJobTotals,
    /// Stats of the underlying engine job (cache/locality/prefetch meters
    /// included).
    pub stats: JobStats,
    /// Memberships kept per record (top_k clamped to C).
    pub top_k: usize,
}

/// In-order writer behind the job: map tasks insert finished blocks, the
/// in-order prefix drains to the [`BlockStoreWriter`].
struct Reorder {
    writer: Option<BlockStoreWriter>,
    next: usize,
    pending: BTreeMap<usize, Matrix>,
}

struct BulkScoreJob {
    bundle: Arc<ModelBundle>,
    backend: Arc<dyn KernelBackend>,
    k: usize,
    /// Quantized candidate pre-pass (`--quant i8`): approximate i8
    /// distances rank the centers per record, exact f32 math runs only
    /// for the `2k` nearest candidates (slack = k); the losers keep their
    /// approximate distance in the membership denominator, where their
    /// mass is negligible by construction.
    quant: QuantMode,
    rows_quant: AtomicU64,
    quant_sidecar_bytes: AtomicU64,
    quant_build_ns: AtomicU64,
    reorder: Mutex<Reorder>,
}

impl BulkScoreJob {
    /// Insert block `id`'s sparse rows and flush the in-order prefix.
    fn push_block(&self, id: usize, rows: Matrix) -> Result<()> {
        let mut guard = self.reorder.lock().expect("score reorder poisoned");
        let st = &mut *guard;
        st.pending.insert(id, rows);
        loop {
            let next = st.next;
            let Some(block) = st.pending.remove(&next) else { break };
            let writer = st
                .writer
                .as_mut()
                .ok_or_else(|| Error::Job("score writer already finished".into()))?;
            writer.append(&block)?;
            st.next += 1;
        }
        Ok(())
    }

    /// Whether the candidate pre-pass can beat full scoring for this
    /// model: with `2k ≥ C` every center would be a candidate anyway.
    fn quant_applicable(&self) -> bool {
        self.quant.enabled() && 2 * self.k < self.bundle.clusters()
    }

    /// Score one (already normalized) block through the quantized
    /// candidate pre-pass: a transient i8 sidecar ranks every center by
    /// approximate distance, the `2k` nearest get exact f32 distances,
    /// and the membership row is computed over the mixed distance vector
    /// (K-Means rows are the one-hot argmin, which exact candidates
    /// dominate). The sidecar lives only for this block — bulk scoring
    /// streams each block once, so there is nothing to amortise across
    /// iterations like the session slab does.
    fn score_quant(&self, x: &Matrix, kernel: Kernel, u: &mut Matrix) {
        let v = &self.bundle.centers;
        let c = v.rows();
        let t0 = std::time::Instant::now();
        let sidecar = QuantSidecar::build(x);
        self.quant_build_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.quant_sidecar_bytes.fetch_add(sidecar.bytes(), Ordering::Relaxed);
        let qc = sidecar.prep_centers(v);
        let keep = 2 * self.k;
        let p = 1.0 / (self.bundle.m - 1.0);
        let m2 = self.bundle.m == 2.0;
        let mut d2 = vec![0.0f64; c];
        let mut inv = vec![0.0f64; c];
        let mut order: Vec<usize> = Vec::with_capacity(c);
        for i in 0..x.rows() {
            sidecar.row_approx(i, &qc, &mut d2);
            order.clear();
            order.extend(0..c);
            order.sort_unstable_by(|&a, &b| {
                d2[a].partial_cmp(&d2[b]).unwrap_or(std::cmp::Ordering::Equal)
            });
            for &j in order.iter().take(keep) {
                d2[j] = x.row_dist2(i, v.row(j));
            }
            for dv in d2.iter_mut() {
                *dv = dv.max(DIST_EPS);
            }
            if kernel.is_kmeans() {
                let mut best = 0usize;
                for j in 1..c {
                    if d2[j] < d2[best] {
                        best = j;
                    }
                }
                let urow = u.row_mut(i);
                urow.fill(0.0);
                urow[best] = 1.0;
            } else {
                membership_row_from_d2(&d2, p, m2, &mut inv, u.row_mut(i));
            }
        }
        self.rows_quant.fetch_add(x.rows() as u64, Ordering::Relaxed);
    }
}

impl MapReduceJob for BulkScoreJob {
    type MapOut = ScoreJobTotals;
    type Output = ScoreJobTotals;

    fn map_combine(&self, block: &Matrix, ctx: &TaskCtx) -> Result<ScoreJobTotals> {
        let c = self.bundle.clusters();
        let mut u = Matrix::zeros(block.rows(), c);
        let kernel = self.bundle.kernel();
        // Only scaler-carrying bundles pay a block copy; raw-space models
        // (the `--save-model` default) score the cached block in place —
        // on the multi-GiB stores this job exists for, an unconditional
        // clone would be gigabytes of pure memcpy.
        let normalized = self.bundle.scaler.is_some().then(|| {
            let mut x = block.clone();
            self.bundle.normalize_block(&mut x);
            x
        });
        let x = normalized.as_ref().unwrap_or(block);
        if self.quant_applicable() {
            self.score_quant(x, kernel, &mut u);
        } else {
            self.backend.score_chunk(kernel, x, &self.bundle.centers, self.bundle.m, &mut u)?;
        }
        let sparse = top_k_rows(&u, self.k);
        // Column 1 of every sparse row is the top-1 membership.
        let mut top1_mass = 0.0f64;
        for i in 0..sparse.rows() {
            top1_mass += sparse.get(i, 1) as f64;
        }
        // Doomed attempts are discarded by the engine's fault injection and
        // re-executed; writing from one would duplicate the append (the
        // same side-band rule as the session slab).
        if !ctx.doomed {
            self.push_block(ctx.task_id, sparse)?;
        }
        Ok(ScoreJobTotals { rows: block.rows() as u64, top1_mass })
    }

    fn reduce(&self, parts: Vec<ScoreJobTotals>, _ctx: &TaskCtx) -> Result<ScoreJobTotals> {
        Ok(parts.into_iter().fold(ScoreJobTotals::default(), ScoreJobTotals::merged))
    }

    fn supports_combine(&self) -> bool {
        true
    }

    fn combine(&self, left: ScoreJobTotals, right: ScoreJobTotals) -> Result<ScoreJobTotals> {
        Ok(left.merged(right))
    }

    fn shuffle_bytes(&self, _part: &ScoreJobTotals) -> u64 {
        16
    }

    fn name(&self) -> &str {
        "bulk-score"
    }
}

/// Top-k sparse rows of a dense membership matrix: `[idx₀, u₀, idx₁, u₁,
/// …]`, memberships descending (ties broken toward the lower center
/// index).
fn top_k_rows(u: &Matrix, k: usize) -> Matrix {
    let (n, c) = (u.rows(), u.cols());
    debug_assert!(k >= 1 && k <= c);
    let mut out = Matrix::zeros(n, 2 * k);
    let mut order: Vec<usize> = Vec::with_capacity(c);
    for i in 0..n {
        let urow = u.row(i);
        order.clear();
        order.extend(0..c);
        order.sort_by(|&a, &b| {
            urow[b]
                .partial_cmp(&urow[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let orow = out.row_mut(i);
        for (slot, &ci) in order.iter().take(k).enumerate() {
            orow[2 * slot] = ci as f32;
            orow[2 * slot + 1] = urow[ci];
        }
    }
    out
}

/// Reconstruct the dense membership row (length `c`, zeros outside the
/// kept entries) from one sparse top-k row.
pub fn dense_from_top_k(sparse: &[f32], c: usize) -> Vec<f32> {
    debug_assert_eq!(sparse.len() % 2, 0);
    let mut out = vec![0.0f32; c];
    for pair in sparse.chunks_exact(2) {
        let idx = pair[0] as usize;
        debug_assert!(idx < c, "sparse row names center {idx} of {c}");
        out[idx] = pair[1];
    }
    out
}

/// Score every block of `store` against `bundle` and write top-k sparse
/// membership rows to a new block store under `out_dir` (see the module
/// docs). The output store's modelled write cost is charged to the
/// engine's clock at the HDFS rate, mirroring the input-scan charges.
/// With `quant` on (and `2·top_k < C`) each block goes through the
/// quantized candidate pre-pass instead of a full `score_chunk`; the
/// returned stats carry `records_pruned_quant` (rows scored through the
/// pre-pass), `quant_sidecar_bytes` and `quant_build_s`.
pub fn run_score_job(
    engine: &mut Engine,
    store: &Arc<BlockStore>,
    bundle: Arc<ModelBundle>,
    backend: Arc<dyn KernelBackend>,
    top_k: usize,
    quant: QuantMode,
    out_dir: PathBuf,
) -> Result<ScoreJobOutcome> {
    bundle.validate()?;
    if store.cols() != bundle.dims() {
        return Err(Error::Bundle(format!(
            "store has {} features, model expects {}",
            store.cols(),
            bundle.dims()
        )));
    }
    let mut score_span = trace::global().span("score", "serve");
    score_span.attr("blocks", store.num_blocks().to_string());
    score_span.attr("top_k", top_k.to_string());
    let k = top_k.max(1).min(bundle.clusters());
    let writer = BlockStoreWriter::create(
        format!("{}-memberships", store.name()),
        2 * k,
        engine.workers(),
        out_dir,
    )?;
    let job = Arc::new(BulkScoreJob {
        bundle,
        backend,
        k,
        quant,
        rows_quant: AtomicU64::new(0),
        quant_sidecar_bytes: AtomicU64::new(0),
        quant_build_ns: AtomicU64::new(0),
        reorder: Mutex::new(Reorder { writer: Some(writer), next: 0, pending: BTreeMap::new() }),
    });
    let (totals, mut stats) =
        engine.run_job(Arc::clone(&job), store, Arc::new(DistributedCache::new()))?;
    stats.records_pruned_quant = job.rows_quant.load(Ordering::Relaxed);
    stats.quant_sidecar_bytes = job.quant_sidecar_bytes.load(Ordering::Relaxed);
    stats.quant_build_s = job.quant_build_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    let mut guard = job.reorder.lock().expect("score reorder poisoned");
    let st = &mut *guard;
    if !st.pending.is_empty() || st.next != store.num_blocks() {
        return Err(Error::Job(format!(
            "score job wrote {} of {} blocks ({} stranded in the reorder buffer)",
            st.next,
            store.num_blocks(),
            st.pending.len()
        )));
    }
    let writer = st.writer.take().expect("writer present until finish");
    engine.charge_scan(writer.total_bytes());
    let out = writer.finish()?;
    // One source of truth: the bulk job's counters land in the unified
    // registry under `score.*` alongside the legacy stats struct.
    stats.publish_metrics(metrics::global(), "score");
    Ok(ScoreJobOutcome { store: out, totals, stats, top_k: k })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_rows_keep_largest_descending() {
        let u = Matrix::from_rows(&[
            vec![0.1, 0.6, 0.3],
            vec![0.5, 0.2, 0.3],
            vec![0.25, 0.25, 0.5],
        ]);
        let s = top_k_rows(&u, 2);
        assert_eq!(s.row(0), &[1.0, 0.6, 2.0, 0.3]);
        assert_eq!(s.row(1), &[0.0, 0.5, 2.0, 0.3]);
        // Tie between centers 0 and 1 breaks toward the lower index.
        assert_eq!(s.row(2), &[2.0, 0.5, 0.0, 0.25]);
    }

    #[test]
    fn dense_reconstruction_zero_fills() {
        let dense = dense_from_top_k(&[2.0, 0.7, 0.0, 0.2], 4);
        assert_eq!(dense, vec![0.2, 0.0, 0.7, 0.0]);
    }
}
