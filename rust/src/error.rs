//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no thiserror): the build is fully
//! offline with zero external dependencies.

use std::fmt;
use std::path::PathBuf;

use crate::xla;

/// All failure modes of the BigFCM system.
#[derive(Debug)]
pub enum Error {
    Io { path: PathBuf, source: std::io::Error },
    Xla(String),
    Artifact(String),
    Json { offset: usize, message: String },
    Config(String),
    InvalidArgument(String),
    Dataset(String),
    BlockStore(String),
    Job(String),
    Clustering(String),
    Bundle(String),
    /// The score service is draining: the request was admitted but the
    /// service closed before a batch claimed it. Distinct from `Job` so
    /// callers (and the registry's retire path) can tell an orderly
    /// shutdown from a scoring failure.
    ShuttingDown,
    /// A tenant exceeded its admission quota; the request was rejected
    /// without queueing. Carries the tenant id.
    QuotaExceeded(String),
    /// A map task exhausted its attempt budget. Structured (task id +
    /// attempts) so callers can tell a genuinely dying task from a job
    /// logic error; the pool stays reusable after this is returned.
    TaskFailed { task: usize, attempts: usize },
    /// An operation hit its wall-clock timeout (e.g. connect/read on the
    /// serve wire). Distinct from `Job` so CLI callers can tell "down"
    /// (connection refused) from "slow" (peer up but unresponsive).
    Timeout(String),
    /// A serve request's deadline expired before a batch admitted it; the
    /// request was shed, never scored. Wire form: `err deadline ...`.
    Deadline,
    /// The serve queue is full and the request's lane is sheddable
    /// (Normal-lane work is rejected first under overload; High-lane work
    /// keeps backpressure-waiting).
    Overloaded,
    /// A session checkpoint failed to decode (corruption, truncation, a
    /// foreign file, or an unknown version) — resume refuses it loudly
    /// rather than warm-starting from garbage.
    Checkpoint(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path, source } => write!(f, "i/o error at {path:?}: {source}"),
            Error::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            Error::Artifact(m) => write!(f, "artifact registry: {m}"),
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::Dataset(m) => write!(f, "dataset error: {m}"),
            Error::BlockStore(m) => write!(f, "hdfs block store: {m}"),
            Error::Job(m) => write!(f, "mapreduce job failed: {m}"),
            Error::Clustering(m) => write!(f, "clustering did not produce a result: {m}"),
            Error::Bundle(m) => write!(f, "model bundle: {m}"),
            Error::ShuttingDown => write!(f, "score service is shutting down"),
            Error::QuotaExceeded(t) => write!(f, "tenant {t:?} exceeded admission quota"),
            Error::TaskFailed { task, attempts } => {
                write!(f, "map task {task} failed after {attempts} attempts")
            }
            Error::Timeout(m) => write!(f, "timed out: {m}"),
            Error::Deadline => write!(f, "deadline expired before scoring"),
            Error::Overloaded => write!(f, "service overloaded: request shed"),
            Error::Checkpoint(m) => write!(f, "session checkpoint: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Wrap an io::Error with the path that caused it.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Job("map task 3 failed".into());
        assert_eq!(e.to_string(), "mapreduce job failed: map task 3 failed");
        let e = Error::Json { offset: 17, message: "expected `,`".into() };
        assert!(e.to_string().contains("byte 17"));
    }

    #[test]
    fn io_error_carries_path_and_source() {
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.to_string().contains("/tmp/x"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
