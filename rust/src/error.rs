//! Crate-wide error type.

use std::path::PathBuf;

/// All failure modes of the BigFCM system.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("i/o error at {path:?}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("artifact registry: {0}")]
    Artifact(String),

    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    #[error("dataset error: {0}")]
    Dataset(String),

    #[error("hdfs block store: {0}")]
    BlockStore(String),

    #[error("mapreduce job failed: {0}")]
    Job(String),

    #[error("clustering did not produce a result: {0}")]
    Clustering(String),
}

impl Error {
    /// Wrap an io::Error with the path that caused it.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
