//! TOML-subset parser: sections, `key = value` with strings, numbers and
//! booleans, `#` comments. Enough for experiment configs without pulling a
//! TOML crate into the offline build.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    String(String),
    Number(f64),
    Bool(bool),
}

impl std::fmt::Display for TomlValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TomlValue::String(s) => write!(f, "{s}"),
            TomlValue::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            TomlValue::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// Parse `[section]` / `key = value` lines into a nested map.
pub fn parse_toml(text: &str) -> Result<super::TomlDoc> {
    let mut doc: super::TomlDoc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {}: unterminated section", lineno + 1)))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
        let key = k.trim().to_string();
        let value = parse_value(v.trim())
            .ok_or_else(|| Error::Config(format!("line {}: bad value `{}`", lineno + 1, v.trim())))?;
        if section.is_empty() {
            return Err(Error::Config(format!(
                "line {}: key `{key}` outside any [section]",
                lineno + 1
            )));
        }
        doc.entry(section.clone()).or_default().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if let Some(stripped) = v.strip_prefix('"') {
        return stripped.strip_suffix('"').map(|s| TomlValue::String(s.to_string()));
    }
    match v {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    v.parse::<f64>().ok().map(TomlValue::Number)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            "[a]\nx = 1\ny = 2.5   # trailing comment\nflag = true\nname = \"hi # not comment\"\n\n[b]\nz = -3e-2\n",
        )
        .unwrap();
        assert_eq!(doc["a"]["x"], TomlValue::Number(1.0));
        assert_eq!(doc["a"]["y"], TomlValue::Number(2.5));
        assert_eq!(doc["a"]["flag"], TomlValue::Bool(true));
        assert_eq!(doc["a"]["name"], TomlValue::String("hi # not comment".into()));
        assert_eq!(doc["b"]["z"], TomlValue::Number(-0.03));
    }

    #[test]
    fn rejects_key_outside_section() {
        assert!(parse_toml("x = 1").is_err());
    }

    #[test]
    fn rejects_unterminated_section() {
        assert!(parse_toml("[oops\nx=1").is_err());
    }

    #[test]
    fn empty_and_comment_only_ok() {
        let doc = parse_toml("# just a comment\n\n").unwrap();
        assert!(doc.is_empty());
    }
}
