//! Configuration system: typed config with defaults, a TOML-subset file
//! parser, and `key=value` CLI overrides.
//!
//! The launcher resolves configuration in three layers (later wins):
//! built-in defaults → `--config file.toml` → repeated `--set sec.key=value`.

mod parse;

pub use parse::{parse_toml, TomlValue};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::error::{Error, Result};
use crate::mapreduce::shard::ShardMergeMode;

/// Which per-record bound model a session's pruned kernels maintain in the
/// sticky slab (see `fcm::backend::BlockBounds`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundModel {
    /// One nearest-center distance per record; a record prunes while the
    /// worst per-center shift stays below `tol × d_min` (PR-3 model).
    DMin,
    /// Per-record × per-center Elkan-style lower bounds; center `j` only
    /// has to satisfy its *own* `δ_j ≤ tol × lb_j`, so mid-shift
    /// iterations (one center still moving, the rest settled) keep
    /// pruning where the single `d_min` bound stalls.
    Elkan,
    /// Elkan's per-center lower bounds plus a Hamerly-style single bound
    /// per record checked first: the cheap O(1) test (`δ_max ≤ tol ×
    /// d_min` for FCM, the refined `δ_best + max_{j≠best} δ_j ≤ margin`
    /// test for K-Means) prunes the common case without touching the C
    /// per-center bounds, which remain as the exact fallback — so the
    /// pruned set contains Elkan's while the per-record check usually
    /// costs what DMin's does.
    Hamerly,
}

impl BoundModel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dmin" => Ok(BoundModel::DMin),
            "elkan" => Ok(BoundModel::Elkan),
            "hamerly" => Ok(BoundModel::Hamerly),
            other => Err(Error::Config(format!("unknown bound model `{other}`"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BoundModel::DMin => "dmin",
            BoundModel::Elkan => "elkan",
            BoundModel::Hamerly => "hamerly",
        }
    }

    /// Whether this model's block state carries the per-record × per-center
    /// lower-bound matrix (the Elkan layout).
    pub fn keeps_lb(&self) -> bool {
        !matches!(self, BoundModel::DMin)
    }

    /// Whether this model's block state carries the per-record single
    /// nearest-center bound (the DMin layout; Hamerly keeps it as its O(1)
    /// fast test on top of the lower bounds).
    pub fn keeps_dmin(&self) -> bool {
        !matches!(self, BoundModel::Elkan)
    }
}

impl FromStr for BoundModel {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        BoundModel::parse(s)
    }
}

/// Whether a session's pruned kernels run the quantized distance pre-pass
/// before the exact f32 math (see `fcm::quant`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// No pre-pass: records that fail the bound test go straight to the
    /// exact gather path.
    Off,
    /// i8 per-block sidecar with symmetric per-column scales: an
    /// i32-accumulating kernel computes approximate distances plus a
    /// certified error radius, and records whose interval certifies the
    /// bound test's conclusion are replayed from cache instead of being
    /// gathered — exact math runs only for survivors.
    I8,
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(QuantMode::Off),
            "i8" => Ok(QuantMode::I8),
            other => Err(Error::Config(format!("unknown quant mode `{other}`"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::I8 => "i8",
        }
    }

    /// Whether the pre-pass runs at all (and therefore whether block state
    /// carries a sidecar plus the lower-bound matrix the certified test
    /// compares against).
    pub fn enabled(&self) -> bool {
        !matches!(self, QuantMode::Off)
    }
}

impl FromStr for QuantMode {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        QuantMode::parse(s)
    }
}

/// FNV-1a hash of the parameters that make two benchmark runs comparable,
/// as a hex string. `bench_diff.sh` refuses to diff JSONs whose hashes
/// differ — a 10% "regression" between an elkan run and a dmin run is not
/// a regression, it's a config change. The shard topology (count, merge
/// mode, steal penalty) is part of the hash for the same reason: a sharded
/// run pays different startup/net charges than a single-engine run.
#[allow(clippy::too_many_arguments)]
pub fn params_hash(
    algo: &str,
    bounds: &str,
    quant: &str,
    workers: usize,
    seed: u64,
    shards: usize,
    merge: ShardMergeMode,
    steal_penalty: f64,
) -> String {
    let canon = format!(
        "algo={algo};bounds={bounds};quant={quant};workers={workers};seed={seed};shards={shards};merge={};steal={steal_penalty}",
        merge.as_str()
    );
    format!("{:016x}", crate::hdfs::fnv1a(canon.as_bytes()))
}

/// Cluster-shape settings: how the single-machine run models the paper's
/// Hadoop deployment.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Simulated worker nodes (= thread-pool size = map slots).
    pub workers: usize,
    /// Records per HDFS block (one map task per block).
    pub block_records: usize,
    /// Rows per runtime chunk; must match the AOT artifact chunk.
    pub chunk: usize,
    /// Number of reduce slots (the paper uses 1 with an optional tree).
    pub reducers: usize,
    /// Block-cache byte budget per engine, in MiB (0 disables caching).
    pub cache_mib: usize,
    /// Overlap each worker's next block read with the current block's
    /// compute (the engine's prefetcher thread).
    pub prefetch: bool,
    /// Merge map outputs pairwise on the worker pool as slots drain, for
    /// jobs that implement a combiner (the worker-side tree reduce).
    pub tree_combine: bool,
    /// Sticky-slab byte budget for iteration-resident sessions, in MiB —
    /// the per-block pruning state kernels persist between iterations.
    pub slab_mib: usize,
    /// Bound model of the session's pruned kernels.
    pub bounds: BoundModel,
    /// Quantized distance pre-pass of the session's pruned kernels
    /// (default off until the CI A/B matrix lands).
    pub quant: QuantMode,
    /// Directory for the slab's disk spill ring: cold per-block bound
    /// state beyond `slab_mib` is written there and reloaded on the next
    /// touch instead of being evicted and recomputed. Empty disables
    /// spilling (budget pressure evicts, as before).
    pub slab_spill_dir: String,
    /// Scale a session's refresh cap (`PruneConfig::refresh_every`) by the
    /// observed per-iteration shift trajectory: steady geometric shrink
    /// doubles the cap (up to 8× the base), any shift growth snaps it back.
    pub adaptive_refresh: bool,
    /// Engine shards one run spans (shard = rack): each shard owns a
    /// contiguous block-id slice, a proportional slice of `cache_mib`, a
    /// slice of `workers`, its own prefetcher and a derived fault domain.
    /// 1 (the default) is the classic single-engine run.
    pub shards: usize,
    /// Record hierarchical trace spans (`telemetry::trace`). Off (the
    /// default) keeps every span site at a single relaxed atomic load;
    /// `--trace-out` on the CLI switches it on for that run.
    pub trace: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            block_records: 65_536,
            chunk: 4096,
            reducers: 1,
            cache_mib: 256,
            prefetch: true,
            tree_combine: true,
            slab_mib: 64,
            bounds: BoundModel::Elkan,
            quant: QuantMode::Off,
            slab_spill_dir: String::new(),
            adaptive_refresh: true,
            shards: 1,
            trace: false,
        }
    }
}

/// Tracing knobs beyond the on/off switch (the `[trace]` section; see
/// `crate::telemetry::trace`).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Spans at least this many µs long are logged with their ancestry as
    /// they are recorded. 0 (the default) disables slow-span logging.
    pub slow_span_us: u64,
    /// Retained-span cap; spans past it degrade to per-name aggregation
    /// rows instead of growing memory.
    pub max_spans: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { slow_span_us: 0, max_spans: crate::telemetry::trace::DEFAULT_MAX_SPANS }
    }
}

/// Sharded scale-out settings beyond the shard count itself (the `[shard]`
/// section; see `crate::mapreduce::shard`).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardConfig {
    /// How per-shard partials merge globally: `exact` exchanges full
    /// `Partials` and completes the single-engine merge DAG (bitwise
    /// drop-in); `representative` exchanges only centers + fuzzy counts
    /// and records its objective delta vs exact.
    pub merge: ShardMergeMode,
    /// Multiplier on `overhead.net_s_per_mib` for cross-shard stolen-block
    /// transfers (shard = rack, so a steal crosses the rack switch; see
    /// EXPERIMENTS.md §Sharding for the calibration note).
    pub steal_penalty: f64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self { merge: ShardMergeMode::Exact, steal_penalty: 4.0 }
    }
}

/// Serving-layer settings: the micro-batching score service and the bulk
/// ScoreJob (see `crate::serve`).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Max live records coalesced into one micro-batch.
    pub max_batch: usize,
    /// Batches are zero-padded up to a multiple of this row count (the
    /// fixed-shape discipline a lowered device kernel wants).
    pub pad_rows: usize,
    /// Bounded admission-queue capacity; a full queue blocks enqueuers
    /// (backpressure, counted in the service stats).
    pub queue_cap: usize,
    /// Microseconds the batcher lingers after the first request of a batch
    /// to let concurrent requests coalesce (0 disables micro-batching).
    pub linger_us: u64,
    /// Memberships kept per record by the bulk ScoreJob's sparse output
    /// rows (clamped to the model's cluster count).
    pub top_k: usize,
    /// Per-tenant admission quota: max requests one tenant may hold in the
    /// service queue at once. Requests beyond it are rejected immediately
    /// (`Error::QuotaExceeded`, counted in `ServeStats`). 0 = unlimited.
    pub tenant_quota: usize,
    /// Per-request deadline in microseconds: a request still unscored when
    /// its deadline expires is shed before batch admission and answered
    /// `err deadline` instead of occupying compute (counted in
    /// `ServeStats.deadline_shed`). 0 = no deadline.
    pub deadline_us: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            pad_rows: 8,
            queue_cap: 1024,
            linger_us: 200,
            top_k: 3,
            tenant_quota: 0,
            deadline_us: 0,
        }
    }
}

/// Iteration-resident session settings beyond the pruning knobs of
/// `[cluster]` — currently the checkpoint cadence of the recovery layer.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionConfig {
    /// Write a checksummed centers+iteration+objective checkpoint every
    /// this many iterations (`bigfcm session --checkpoint PATH`); a later
    /// `--resume PATH` warm-starts from it. 0 disables checkpointing.
    pub checkpoint_every: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self { checkpoint_every: 0 }
    }
}

/// Deterministic fault-injection settings (the `[faults]` section; see
/// `crate::faults::FaultPlan`). All rates default to 0 and the trip
/// schedule to off, so an absent section means no plan is built at all —
/// every fault check in the layers is a single `Option` test.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Seed of the fault schedule: same seed ⇒ same faults, replayable.
    pub seed: u64,
    /// Per-site injection rates in [0, 1].
    pub block_read: f64,
    pub spill_read: f64,
    pub spill_write: f64,
    pub bundle_load: f64,
    pub prefetch: f64,
    pub map_task: f64,
    pub connection: f64,
    /// Probability an injected read fault is bit-flip corruption instead
    /// of a transient I/O error.
    pub corrupt: f64,
    /// Latency-spike magnitude for connection faults, microseconds
    /// (0 = connection faults always drop).
    pub latency_us: u64,
    /// Deterministic "trip exactly the Nth operation" schedule: the site
    /// name (`block_read`, `spill_read`, …) or empty for off.
    pub trip_site: String,
    /// 0-based operation index `trip_site` trips at.
    pub trip_at: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            block_read: 0.0,
            spill_read: 0.0,
            spill_write: 0.0,
            bundle_load: 0.0,
            prefetch: 0.0,
            map_task: 0.0,
            connection: 0.0,
            corrupt: 0.0,
            latency_us: 0,
            trip_site: String::new(),
            trip_at: 0,
        }
    }
}

impl FaultsConfig {
    /// Whether any fault can ever fire — `false` (the default) means the
    /// chaos layer builds no plan and every site check is a no-op.
    pub fn enabled(&self) -> bool {
        [
            self.block_read,
            self.spill_read,
            self.spill_write,
            self.bundle_load,
            self.prefetch,
            self.map_task,
            self.connection,
        ]
        .iter()
        .any(|&r| r > 0.0)
            || !self.trip_site.is_empty()
    }

    /// Every rate field, for validation.
    fn rates(&self) -> [(&'static str, f64); 8] {
        [
            ("faults.block_read", self.block_read),
            ("faults.spill_read", self.spill_read),
            ("faults.spill_write", self.spill_write),
            ("faults.bundle_load", self.bundle_load),
            ("faults.prefetch", self.prefetch),
            ("faults.map_task", self.map_task),
            ("faults.connection", self.connection),
            ("faults.corrupt", self.corrupt),
        ]
    }
}

/// SimClock overhead model: the per-job/task/IO charges a real Hadoop
/// cluster pays. Defaults are calibrated in EXPERIMENTS.md §Calibration
/// against the paper's own Mahout baseline rows (Table 4).
#[derive(Clone, Debug, PartialEq)]
pub struct OverheadConfig {
    /// Seconds to launch one MapReduce job (JVM spin-up, scheduling).
    pub job_startup_s: f64,
    /// Seconds to launch one task attempt within a job.
    pub task_launch_s: f64,
    /// Seconds per MiB moved through the shuffle.
    pub shuffle_s_per_mib: f64,
    /// Seconds per MiB read from / written to HDFS.
    pub hdfs_s_per_mib: f64,
    /// Seconds per MiB moved over the serving front's wire (request +
    /// response frames). Default ≈ 1 GbE effective throughput.
    pub net_s_per_mib: f64,
    /// Multiplier translating our measured compute seconds onto the paper's
    /// (slower, JVM, 2016 Core i5) per-node compute speed.
    pub compute_scale: f64,
}

impl Default for OverheadConfig {
    fn default() -> Self {
        // Calibration: Mahout KM on 10 MiB × 1000 iterations ≈ 31 468 s in
        // Table 4 ⇒ ≈31.5 s/job-iteration dominated by startup; shuffle and
        // HDFS rates from common Hadoop-1.x measurements (~20 MiB/s effective).
        Self {
            job_startup_s: 28.0,
            task_launch_s: 1.2,
            shuffle_s_per_mib: 0.05,
            hdfs_s_per_mib: 0.05,
            net_s_per_mib: 0.01,
            compute_scale: 8.0,
        }
    }
}

/// How the driver chooses the combiner algorithm (Algorithm 3 line 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlagPolicy {
    /// Paper behaviour: race FCM vs WFCMPB on the sample, pick the faster.
    /// Inherently timing-dependent (the paper's own design).
    Race,
    /// Always plain FCM in the combiners (deterministic).
    ForceFcm,
    /// Always WFCMPB in the combiners (deterministic).
    ForceWfcmpb,
}

impl FlagPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "race" => Ok(FlagPolicy::Race),
            "fcm" => Ok(FlagPolicy::ForceFcm),
            "wfcmpb" => Ok(FlagPolicy::ForceWfcmpb),
            other => Err(Error::Config(format!("unknown flag policy `{other}`"))),
        }
    }
}

impl FromStr for FlagPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        FlagPolicy::parse(s)
    }
}

/// FCM algorithm settings (paper notation: C, m, epsilon).
#[derive(Clone, Debug, PartialEq)]
pub struct FcmConfig {
    /// Number of final clusters C.
    pub clusters: usize,
    /// Fuzzifier m (> 1).
    pub fuzzifier: f64,
    /// Reducer epsilon: convergence threshold on max squared center shift.
    pub epsilon: f64,
    /// Driver epsilon for the pre-clustering (Table 2 knob).
    pub driver_epsilon: f64,
    /// Hard iteration cap (the paper uses 1000).
    pub max_iterations: usize,
    /// Whether the driver pre-clustering runs at all (ablation knob).
    pub driver_preclustering: bool,
    /// Parker–Hall relative difference `r` for the sample-size formula.
    pub sample_rel_diff: f64,
    /// Parker–Hall v(alpha); 1.27359 for alpha = 0.05.
    pub sample_v_alpha: f64,
    /// How the driver picks the combiner algorithm (race = paper default).
    pub flag_policy: FlagPolicy,
    /// Pre-clustering restarts in the driver (best objective wins). The
    /// sample is small, so restarts are cheap insurance against a bad
    /// seeding draw.
    pub driver_restarts: usize,
    /// Reducer polish: after the WFCM merge, re-anchor the final centers
    /// with a short FCM pass over the driver's sample (shipped through the
    /// distributed cache). Recovers splits that underflow f32 when all
    /// per-block centers are near-coincident (FCM's coincident-cluster mode
    /// on weakly separated data).
    pub reducer_polish: bool,
}

impl Default for FcmConfig {
    fn default() -> Self {
        Self {
            clusters: 2,
            fuzzifier: 2.0,
            epsilon: 5.0e-7,
            driver_epsilon: 5.0e-11,
            max_iterations: 1000,
            driver_preclustering: true,
            sample_rel_diff: 0.10,
            sample_v_alpha: 1.27359,
            flag_policy: FlagPolicy::Race,
            driver_restarts: 4,
            reducer_polish: true,
        }
    }
}

/// Runtime backend selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Execute chunk steps through the AOT HLO artifacts on PJRT.
    Pjrt,
    /// Pure-rust chunk steps (no artifacts needed; used for tests/ablation).
    Native,
    /// PJRT when an artifact exists for the shape, else native.
    Auto,
    /// The offline PJRT shim: device execution shape (fixed chunks,
    /// zero-padded tails, per-chunk merge) computed with the native
    /// kernels — no artifacts needed, pruning contract fully supported.
    Shim,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "native" => Ok(Backend::Native),
            "auto" => Ok(Backend::Auto),
            "shim" => Ok(Backend::Shim),
            other => Err(Error::Config(format!("unknown backend `{other}`"))),
        }
    }
}

impl FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        Backend::parse(s)
    }
}

/// Top-level configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub cluster: ClusterConfig,
    pub overhead: OverheadConfig,
    pub fcm: FcmConfig,
    pub serve: ServeConfig,
    pub session: SessionConfig,
    pub shard: ShardConfig,
    pub faults: FaultsConfig,
    pub trace: TraceConfig,
    pub backend: Backend,
    /// Directory containing `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: PathBuf,
    /// Scratch directory for HDFS block stores.
    pub data_dir: PathBuf,
    /// Master seed for all randomness.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cluster: ClusterConfig::default(),
            overhead: OverheadConfig::default(),
            fcm: FcmConfig::default(),
            serve: ServeConfig::default(),
            session: SessionConfig::default(),
            shard: ShardConfig::default(),
            faults: FaultsConfig::default(),
            trace: TraceConfig::default(),
            backend: Backend::Auto,
            artifacts_dir: PathBuf::from("artifacts"),
            data_dir: PathBuf::from("data_cache"),
            seed: 0xB16FC4,
        }
    }
}

impl Config {
    /// Load from a TOML-subset file over the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        let mut cfg = Config::default();
        cfg.apply_toml(&text)?;
        Ok(cfg)
    }

    /// Apply a parsed TOML document over the current values.
    pub fn apply_toml(&mut self, text: &str) -> Result<()> {
        let doc = parse_toml(text)?;
        for (section, entries) in &doc {
            for (key, value) in entries {
                self.set(&format!("{section}.{key}"), &value.to_string())?;
            }
        }
        Ok(())
    }

    /// Apply one dotted-path override, e.g. `cluster.workers=8`.
    pub fn set_kv(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("override `{kv}` is not key=value")))?;
        self.set(k.trim(), v.trim())
    }

    /// Set a single dotted key.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |k: &str, v: &str| Error::Config(format!("bad value `{v}` for `{k}`"));
        macro_rules! num {
            ($t:ty) => {
                value.parse::<$t>().map_err(|_| bad(key, value))?
            };
        }
        match key {
            "cluster.workers" => self.cluster.workers = num!(usize),
            "cluster.block_records" => self.cluster.block_records = num!(usize),
            "cluster.chunk" => self.cluster.chunk = num!(usize),
            "cluster.reducers" => self.cluster.reducers = num!(usize),
            "cluster.cache_mib" => self.cluster.cache_mib = num!(usize),
            "cluster.prefetch" => {
                self.cluster.prefetch = value.parse::<bool>().map_err(|_| bad(key, value))?
            }
            "cluster.tree_combine" => {
                self.cluster.tree_combine = value.parse::<bool>().map_err(|_| bad(key, value))?
            }
            "cluster.slab_mib" => self.cluster.slab_mib = num!(usize),
            "cluster.bounds" => self.cluster.bounds = BoundModel::parse(value)?,
            "cluster.quant" => self.cluster.quant = QuantMode::parse(value)?,
            "cluster.slab_spill_dir" => self.cluster.slab_spill_dir = value.to_string(),
            "cluster.adaptive_refresh" => {
                self.cluster.adaptive_refresh =
                    value.parse::<bool>().map_err(|_| bad(key, value))?
            }
            "serve.max_batch" => self.serve.max_batch = num!(usize),
            "serve.pad_rows" => self.serve.pad_rows = num!(usize),
            "serve.queue_cap" => self.serve.queue_cap = num!(usize),
            "serve.linger_us" => self.serve.linger_us = num!(u64),
            "serve.top_k" => self.serve.top_k = num!(usize),
            "serve.tenant_quota" => self.serve.tenant_quota = num!(usize),
            "serve.deadline_us" => self.serve.deadline_us = num!(u64),
            "cluster.shards" => self.cluster.shards = num!(usize),
            "cluster.trace" => {
                self.cluster.trace = match value {
                    "on" | "true" => true,
                    "off" | "false" => false,
                    _ => return Err(bad(key, value)),
                }
            }
            "trace.slow_span_us" => self.trace.slow_span_us = num!(u64),
            "trace.max_spans" => self.trace.max_spans = num!(usize),
            "session.checkpoint_every" => self.session.checkpoint_every = num!(usize),
            "shard.merge" => self.shard.merge = value.parse::<ShardMergeMode>()?,
            "shard.steal_penalty" => self.shard.steal_penalty = num!(f64),
            "faults.seed" => self.faults.seed = num!(u64),
            "faults.block_read" => self.faults.block_read = num!(f64),
            "faults.spill_read" => self.faults.spill_read = num!(f64),
            "faults.spill_write" => self.faults.spill_write = num!(f64),
            "faults.bundle_load" => self.faults.bundle_load = num!(f64),
            "faults.prefetch" => self.faults.prefetch = num!(f64),
            "faults.map_task" => self.faults.map_task = num!(f64),
            "faults.connection" => self.faults.connection = num!(f64),
            "faults.corrupt" => self.faults.corrupt = num!(f64),
            "faults.latency_us" => self.faults.latency_us = num!(u64),
            "faults.trip_site" => self.faults.trip_site = value.to_string(),
            "faults.trip_at" => self.faults.trip_at = num!(u64),
            "overhead.job_startup_s" => self.overhead.job_startup_s = num!(f64),
            "overhead.task_launch_s" => self.overhead.task_launch_s = num!(f64),
            "overhead.shuffle_s_per_mib" => self.overhead.shuffle_s_per_mib = num!(f64),
            "overhead.hdfs_s_per_mib" => self.overhead.hdfs_s_per_mib = num!(f64),
            "overhead.net_s_per_mib" => self.overhead.net_s_per_mib = num!(f64),
            "overhead.compute_scale" => self.overhead.compute_scale = num!(f64),
            "fcm.clusters" => self.fcm.clusters = num!(usize),
            "fcm.fuzzifier" => self.fcm.fuzzifier = num!(f64),
            "fcm.epsilon" => self.fcm.epsilon = num!(f64),
            "fcm.driver_epsilon" => self.fcm.driver_epsilon = num!(f64),
            "fcm.max_iterations" => self.fcm.max_iterations = num!(usize),
            "fcm.driver_preclustering" => {
                self.fcm.driver_preclustering = value.parse::<bool>().map_err(|_| bad(key, value))?
            }
            "fcm.sample_rel_diff" => self.fcm.sample_rel_diff = num!(f64),
            "fcm.sample_v_alpha" => self.fcm.sample_v_alpha = num!(f64),
            "fcm.flag_policy" => self.fcm.flag_policy = FlagPolicy::parse(value)?,
            "fcm.driver_restarts" => self.fcm.driver_restarts = num!(usize),
            "fcm.reducer_polish" => {
                self.fcm.reducer_polish = value.parse::<bool>().map_err(|_| bad(key, value))?
            }
            "runtime.backend" => self.backend = Backend::parse(value)?,
            "paths.artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "paths.data_dir" => self.data_dir = PathBuf::from(value),
            "seed" | "run.seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            other => return Err(Error::Config(format!("unknown config key `{other}`"))),
        }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.fcm.fuzzifier <= 1.0 {
            return Err(Error::Config("fcm.fuzzifier must be > 1".into()));
        }
        if self.fcm.clusters < 2 {
            return Err(Error::Config("fcm.clusters must be >= 2".into()));
        }
        if self.cluster.chunk == 0 || self.cluster.block_records == 0 {
            return Err(Error::Config("cluster sizes must be positive".into()));
        }
        if self.fcm.epsilon <= 0.0 || self.fcm.driver_epsilon <= 0.0 {
            return Err(Error::Config("epsilons must be positive".into()));
        }
        if self.serve.max_batch == 0 || self.serve.pad_rows == 0 || self.serve.queue_cap == 0 {
            return Err(Error::Config("serve sizes must be positive".into()));
        }
        if self.serve.top_k == 0 {
            return Err(Error::Config("serve.top_k must be positive".into()));
        }
        for (key, rate) in self.faults.rates() {
            if !(0.0..=1.0).contains(&rate) {
                return Err(Error::Config(format!("{key} must be within [0, 1], got {rate}")));
            }
        }
        if self.cluster.shards == 0 {
            return Err(Error::Config("cluster.shards must be >= 1".into()));
        }
        if self.cluster.shards > self.cluster.workers {
            return Err(Error::Config(format!(
                "cluster.shards ({}) must not exceed cluster.workers ({}) — every shard needs a worker",
                self.cluster.shards, self.cluster.workers
            )));
        }
        if !(self.shard.steal_penalty >= 0.0) {
            return Err(Error::Config(format!(
                "shard.steal_penalty must be >= 0, got {}",
                self.shard.steal_penalty
            )));
        }
        if self.trace.max_spans == 0 {
            return Err(Error::Config("trace.max_spans must be >= 1".into()));
        }
        Ok(())
    }
}

/// Flattened `section.key → value` map of a parsed TOML document.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn kv_overrides() {
        let mut c = Config::default();
        c.set_kv("cluster.workers=16").unwrap();
        c.set_kv("cluster.cache_mib=64").unwrap();
        c.set_kv("cluster.prefetch=false").unwrap();
        c.set_kv("cluster.tree_combine=false").unwrap();
        c.set_kv("cluster.slab_mib=16").unwrap();
        c.set_kv("cluster.bounds=dmin").unwrap();
        c.set_kv("cluster.quant=i8").unwrap();
        c.set_kv("cluster.slab_spill_dir=/tmp/slab").unwrap();
        c.set_kv("cluster.adaptive_refresh=false").unwrap();
        c.set_kv("serve.max_batch=16").unwrap();
        c.set_kv("serve.linger_us=500").unwrap();
        c.set_kv("serve.top_k=2").unwrap();
        c.set_kv("serve.tenant_quota=32").unwrap();
        c.set_kv("overhead.net_s_per_mib=0.02").unwrap();
        c.set_kv("fcm.epsilon=5e-3").unwrap();
        c.set_kv("fcm.driver_preclustering=false").unwrap();
        c.set_kv("runtime.backend=native").unwrap();
        assert_eq!(c.cluster.workers, 16);
        assert_eq!(c.cluster.cache_mib, 64);
        assert!(!c.cluster.prefetch);
        assert!(!c.cluster.tree_combine);
        assert_eq!(c.cluster.slab_mib, 16);
        assert_eq!(c.cluster.bounds, BoundModel::DMin);
        assert_eq!(c.cluster.quant, QuantMode::I8);
        assert_eq!(c.cluster.slab_spill_dir, "/tmp/slab");
        assert!(!c.cluster.adaptive_refresh);
        assert_eq!(c.serve.max_batch, 16);
        assert_eq!(c.serve.linger_us, 500);
        assert_eq!(c.serve.top_k, 2);
        assert_eq!(c.serve.tenant_quota, 32);
        assert_eq!(c.overhead.net_s_per_mib, 0.02);
        assert_eq!(c.fcm.epsilon, 5e-3);
        assert!(!c.fcm.driver_preclustering);
        assert_eq!(c.backend, Backend::Native);
    }

    #[test]
    fn faults_session_and_deadline_keys_dispatch() {
        let mut c = Config::default();
        assert!(!c.faults.enabled(), "default [faults] must be inert");
        c.set_kv("faults.seed=42").unwrap();
        c.set_kv("faults.block_read=0.25").unwrap();
        c.set_kv("faults.spill_read=0.1").unwrap();
        c.set_kv("faults.corrupt=0.5").unwrap();
        c.set_kv("faults.latency_us=1500").unwrap();
        c.set_kv("faults.trip_site=bundle_load").unwrap();
        c.set_kv("faults.trip_at=3").unwrap();
        c.set_kv("session.checkpoint_every=5").unwrap();
        c.set_kv("serve.deadline_us=2000").unwrap();
        assert_eq!(c.faults.seed, 42);
        assert_eq!(c.faults.block_read, 0.25);
        assert_eq!(c.faults.spill_read, 0.1);
        assert_eq!(c.faults.corrupt, 0.5);
        assert_eq!(c.faults.latency_us, 1500);
        assert_eq!(c.faults.trip_site, "bundle_load");
        assert_eq!(c.faults.trip_at, 3);
        assert_eq!(c.session.checkpoint_every, 5);
        assert_eq!(c.serve.deadline_us, 2000);
        assert!(c.faults.enabled());
        c.validate().unwrap();
        c.set_kv("faults.block_read=1.5").unwrap();
        assert!(c.validate().is_err(), "rates beyond 1 must be rejected");
        // A trip schedule alone (all rates zero) still enables the layer.
        let mut c = Config::default();
        c.set_kv("faults.trip_site=block_read").unwrap();
        assert!(c.faults.enabled());
    }

    #[test]
    fn trace_keys_dispatch() {
        let mut c = Config::default();
        assert!(!c.cluster.trace, "tracing must default off");
        c.set_kv("cluster.trace=on").unwrap();
        assert!(c.cluster.trace);
        c.set_kv("cluster.trace=off").unwrap();
        assert!(!c.cluster.trace);
        c.set_kv("cluster.trace=true").unwrap();
        assert!(c.cluster.trace);
        assert!(c.set_kv("cluster.trace=maybe").is_err());
        c.set_kv("trace.slow_span_us=2500").unwrap();
        c.set_kv("trace.max_spans=1024").unwrap();
        assert_eq!(c.trace.slow_span_us, 2500);
        assert_eq!(c.trace.max_spans, 1024);
        c.validate().unwrap();
        c.set_kv("trace.max_spans=0").unwrap();
        assert!(c.validate().is_err(), "a zero span cap must be rejected");
    }

    #[test]
    fn bound_model_parse_roundtrips() {
        for model in [BoundModel::DMin, BoundModel::Elkan, BoundModel::Hamerly] {
            assert_eq!(BoundModel::parse(model.as_str()).unwrap(), model);
        }
        assert!(BoundModel::parse("nope").is_err());
        // Layout flags: hamerly carries both the lb matrix and the single
        // per-record bound.
        assert!(BoundModel::Hamerly.keeps_lb() && BoundModel::Hamerly.keeps_dmin());
        assert!(!BoundModel::DMin.keeps_lb() && BoundModel::DMin.keeps_dmin());
        assert!(BoundModel::Elkan.keeps_lb() && !BoundModel::Elkan.keeps_dmin());
    }

    #[test]
    fn quant_mode_parse_roundtrips() {
        for mode in [QuantMode::Off, QuantMode::I8] {
            assert_eq!(QuantMode::parse(mode.as_str()).unwrap(), mode);
        }
        assert!(QuantMode::parse("f16").is_err());
        assert!(QuantMode::I8.enabled() && !QuantMode::Off.enabled());
    }

    #[test]
    fn from_str_routes_through_parse() {
        assert_eq!("hamerly".parse::<BoundModel>().unwrap(), BoundModel::Hamerly);
        assert_eq!("i8".parse::<QuantMode>().unwrap(), QuantMode::I8);
        assert_eq!("shim".parse::<Backend>().unwrap(), Backend::Shim);
        assert_eq!("race".parse::<FlagPolicy>().unwrap(), FlagPolicy::Race);
        assert!("nope".parse::<BoundModel>().is_err());
        assert!("nope".parse::<Backend>().is_err());
    }

    #[test]
    fn params_hash_separates_configs() {
        let a = params_hash("fcm", "elkan", "off", 4, 42, 1, ShardMergeMode::Exact, 4.0);
        let b = params_hash("fcm", "elkan", "i8", 4, 42, 1, ShardMergeMode::Exact, 4.0);
        let c = params_hash("fcm", "elkan", "off", 4, 42, 1, ShardMergeMode::Exact, 4.0);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
        // Shard topology is part of run comparability: different shard
        // counts, merge modes or steal penalties must never diff clean.
        let sharded = params_hash("fcm", "elkan", "off", 4, 42, 2, ShardMergeMode::Exact, 4.0);
        let rep = params_hash("fcm", "elkan", "off", 4, 42, 2, ShardMergeMode::Representative, 4.0);
        let steep = params_hash("fcm", "elkan", "off", 4, 42, 2, ShardMergeMode::Exact, 8.0);
        assert_ne!(a, sharded);
        assert_ne!(sharded, rep);
        assert_ne!(sharded, steep);
    }

    #[test]
    fn shard_keys_dispatch_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.cluster.shards, 1);
        assert_eq!(c.shard.merge, ShardMergeMode::Exact);
        assert_eq!(c.shard.steal_penalty, 4.0);
        c.set_kv("cluster.shards=2").unwrap();
        c.set_kv("shard.merge=representative").unwrap();
        c.set_kv("shard.steal_penalty=6.5").unwrap();
        assert_eq!(c.cluster.shards, 2);
        assert_eq!(c.shard.merge, ShardMergeMode::Representative);
        assert_eq!(c.shard.steal_penalty, 6.5);
        c.validate().unwrap();
        c.set_kv("cluster.shards=0").unwrap();
        assert!(c.validate().is_err(), "0 shards must be rejected");
        c.set_kv("cluster.shards=8").unwrap(); // workers defaults to 4
        assert!(c.validate().is_err(), "more shards than workers must be rejected");
        c.set_kv("cluster.shards=2").unwrap();
        c.set_kv("shard.steal_penalty=-1").unwrap();
        assert!(c.validate().is_err(), "negative steal penalty must be rejected");
        assert!(c.set_kv("shard.merge=fuzzy").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut c = Config::default();
        assert!(c.set_kv("nope.key=1").is_err());
        assert!(c.set_kv("cluster.workers=abc").is_err());
        assert!(c.set_kv("no-equals").is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let mut c = Config::default();
        c.apply_toml(
            r#"
# experiment config
[cluster]
workers = 8
chunk = 2048

[fcm]
epsilon = 5.0e-5
fuzzifier = 1.2

[paths]
artifacts_dir = "art"
"#,
        )
        .unwrap();
        assert_eq!(c.cluster.workers, 8);
        assert_eq!(c.cluster.chunk, 2048);
        assert_eq!(c.fcm.epsilon, 5.0e-5);
        assert_eq!(c.fcm.fuzzifier, 1.2);
        assert_eq!(c.artifacts_dir, PathBuf::from("art"));
    }

    #[test]
    fn validation_catches_bad_fuzzifier() {
        let mut c = Config::default();
        c.fcm.fuzzifier = 1.0;
        assert!(c.validate().is_err());
    }
}
