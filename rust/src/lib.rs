//! # BigFCM — fast, precise and scalable Fuzzy C-Means on a MapReduce substrate
//!
//! A from-scratch reproduction of *BigFCM: Fast, Precise and Scalable FCM on
//! Hadoop* (Ghadiri, Ghaffari, Nikbakht, 2016) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the BigFCM
//!   driver/mapper/combiner/reducer pipeline ([`coordinator`]) running on a
//!   mini-Hadoop substrate ([`mapreduce`], [`hdfs`]) with Mahout-style
//!   iterative-MR baselines ([`baselines`]).
//! * **Layer 2/1 (build-time python)** — per-chunk FCM/K-Means compute graphs
//!   (JAX) wrapping Pallas kernels, AOT-lowered to HLO text artifacts that
//!   the [`runtime`] module loads and executes via PJRT. Python never runs on
//!   the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use bigfcm::config::Config;
//! use bigfcm::coordinator::BigFcm;
//! use bigfcm::data::builtin::iris;
//!
//! let cfg = Config::default();
//! let dataset = iris();
//! let result = BigFcm::new(cfg)
//!     .clusters(3)
//!     .fuzzifier(2.0)
//!     .run_in_memory(&dataset.features)
//!     .unwrap();
//! println!("centers: {:?}", result.centers);
//! ```
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! regeneration harness of every table and figure in the paper.

pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod faults;
pub mod fcm;
pub mod hdfs;
pub mod json;
pub mod mapreduce;
pub mod metrics;
pub mod prng;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod telemetry;
pub mod threadpool;
pub mod xla;

pub use error::{Error, Result};
