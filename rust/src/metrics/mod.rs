//! Evaluation metrics of the paper (§3.5): confusion-matrix accuracy,
//! silhouette width, and speedup.

pub mod confusion;
pub mod silhouette;

pub use confusion::{confusion_accuracy, confusion_matrix, hungarian_max};
pub use silhouette::{silhouette_width, silhouette_width_sampled};

/// Relative speedup of `baseline` over `ours` (paper: T_baseline / T_ours).
pub fn speedup(baseline_s: f64, ours_s: f64) -> f64 {
    if ours_s <= 0.0 {
        f64::INFINITY
    } else {
        baseline_s / ours_s
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn speedup_basic() {
        assert_eq!(super::speedup(100.0, 10.0), 10.0);
        assert!(super::speedup(1.0, 0.0).is_infinite());
    }
}
