//! Silhouette width (Rousseeuw 1987) — the paper's cluster-quality metric
//! (Table 8).
//!
//! s(i) = (b(i) − a(i)) / max(a(i), b(i)) with a(i) the mean distance to
//! the own cluster and b(i) the smallest mean distance to another cluster.
//! The paper evaluates it on subsamples of 1k–4k records; we do the same
//! (exact over the given sample, O(k²)).

use crate::data::Matrix;
use crate::prng::Pcg;

/// Exact silhouette width over the given records/assignments (Euclidean).
/// Records in singleton clusters contribute 0, per Rousseeuw's convention.
pub fn silhouette_width(x: &Matrix, assignments: &[usize]) -> f64 {
    let n = x.rows();
    assert_eq!(n, assignments.len());
    if n < 2 {
        return 0.0;
    }
    let k = assignments.iter().copied().max().unwrap_or(0) + 1;
    let mut cluster_sizes = vec![0usize; k];
    for &a in assignments {
        cluster_sizes[a] += 1;
    }
    let mut total = 0.0f64;
    // Per record: mean distance to each cluster.
    let mut sums = vec![0.0f64; k];
    for i in 0..n {
        sums.iter_mut().for_each(|s| *s = 0.0);
        for j in 0..n {
            if i == j {
                continue;
            }
            let d = x.row_dist2(i, x.row(j)).sqrt();
            sums[assignments[j]] += d;
        }
        let own = assignments[i];
        if cluster_sizes[own] <= 1 {
            continue; // s(i) = 0 for singletons
        }
        let a = sums[own] / (cluster_sizes[own] - 1) as f64;
        let mut b = f64::INFINITY;
        for (c, &s) in sums.iter().enumerate() {
            if c != own && cluster_sizes[c] > 0 {
                b = b.min(s / cluster_sizes[c] as f64);
            }
        }
        if b.is_finite() {
            let denom = a.max(b);
            if denom > 0.0 {
                total += (b - a) / denom;
            }
        }
    }
    total / n as f64
}

/// Silhouette over a uniform subsample of `sample` records (the paper's
/// 1k/2k/3k/4k columns in Table 8).
pub fn silhouette_width_sampled(
    x: &Matrix,
    assignments: &[usize],
    sample: usize,
    rng: &mut Pcg,
) -> f64 {
    let n = x.rows();
    if sample >= n {
        return silhouette_width(x, assignments);
    }
    let idx = rng.sample_indices(n, sample);
    let sub = x.select_rows(&idx);
    let sub_assign: Vec<usize> = idx.iter().map(|&i| assignments[i]).collect();
    silhouette_width(&sub, &sub_assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::assign_hard;

    #[test]
    fn well_separated_blobs_score_high() {
        let d = blobs(200, 2, 2, 0.1, 1);
        let labels = d.labels.as_ref().unwrap();
        let s = silhouette_width(&d.features, labels);
        assert!(s > 0.7, "expected near-1 silhouette, got {s}");
    }

    #[test]
    fn random_assignment_scores_near_zero() {
        let d = blobs(200, 2, 2, 0.1, 2);
        let random: Vec<usize> = (0..200).map(|i| i % 2).collect();
        let s = silhouette_width(&d.features, &random);
        assert!(s.abs() < 0.15, "random assignment silhouette {s}");
    }

    #[test]
    fn correct_beats_incorrect() {
        let d = blobs(150, 3, 3, 0.2, 3);
        let good = assign_hard(&d.features, &{
            // centroids from labels
            let mut c = Matrix::zeros(3, 3);
            let labels = d.labels.as_ref().unwrap();
            let mut counts = [0f32; 3];
            for i in 0..150 {
                let l = labels[i];
                counts[l] += 1.0;
                for j in 0..3 {
                    c.set(l, j, c.get(l, j) + d.features.get(i, j));
                }
            }
            for l in 0..3 {
                for j in 0..3 {
                    c.set(l, j, c.get(l, j) / counts[l]);
                }
            }
            c
        });
        let bad: Vec<usize> = (0..150).map(|i| i % 3).collect();
        assert!(
            silhouette_width(&d.features, &good) > silhouette_width(&d.features, &bad) + 0.3
        );
    }

    #[test]
    fn sampled_close_to_exact() {
        let d = blobs(1000, 3, 3, 0.3, 4);
        let labels = d.labels.as_ref().unwrap();
        let exact = silhouette_width(&d.features, labels);
        let mut rng = Pcg::new(5);
        let approx = silhouette_width_sampled(&d.features, labels, 300, &mut rng);
        assert!((exact - approx).abs() < 0.08, "exact {exact} vs sampled {approx}");
    }

    #[test]
    fn singleton_cluster_is_safe() {
        let x = Matrix::from_rows(&[vec![0.0], vec![0.1], vec![5.0]]);
        let s = silhouette_width(&x, &[0, 0, 1]);
        assert!(s.is_finite());
        assert!(s > 0.5);
    }

    #[test]
    fn degenerate_inputs() {
        let x = Matrix::from_rows(&[vec![1.0]]);
        assert_eq!(silhouette_width(&x, &[0]), 0.0);
    }
}
