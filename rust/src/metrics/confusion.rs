//! Confusion-matrix accuracy with optimal cluster↔class matching.
//!
//! Clustering is label-free, so accuracy requires assigning each found
//! cluster to a ground-truth class first. We solve the assignment exactly
//! with the Hungarian algorithm (O(n³), fine for C ≤ 50 as in the paper's
//! KDD/50-centroid runs), maximising the matched record count.

/// counts[i][j] = records in cluster i with true class j.
pub fn confusion_matrix(
    assignments: &[usize],
    labels: &[usize],
    clusters: usize,
    classes: usize,
) -> Vec<Vec<u64>> {
    assert_eq!(assignments.len(), labels.len());
    let mut m = vec![vec![0u64; classes]; clusters];
    for (&a, &l) in assignments.iter().zip(labels) {
        m[a][l] += 1;
    }
    m
}

/// Maximum-weight assignment on a (possibly rectangular) matrix.
/// Returns per-row column choice (usize::MAX = unassigned).
pub fn hungarian_max(weights: &[Vec<u64>]) -> Vec<usize> {
    let rows = weights.len();
    if rows == 0 {
        return Vec::new();
    }
    let cols = weights[0].len();
    let n = rows.max(cols);
    let max_w = weights
        .iter()
        .flat_map(|r| r.iter())
        .copied()
        .max()
        .unwrap_or(0) as i64;
    // Convert to square min-cost matrix: cost = max_w - weight, padding 0s.
    let cost: Vec<Vec<i64>> = (0..n)
        .map(|i| {
            (0..n)
                .map(|j| {
                    if i < rows && j < cols {
                        max_w - weights[i][j] as i64
                    } else {
                        max_w
                    }
                })
                .collect()
        })
        .collect();

    // Jonker–Volgenant style O(n³) Hungarian (potentials + augmenting paths).
    let inf = i64::MAX / 4;
    let mut u = vec![0i64; n + 1];
    let mut v = vec![0i64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j (1-based)
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut row_to_col = vec![usize::MAX; rows];
    for j in 1..=n {
        let i = p[j];
        if i >= 1 && i <= rows && j <= cols {
            row_to_col[i - 1] = j - 1;
        }
    }
    row_to_col
}

/// Accuracy = matched records / total, after optimal cluster↔class matching
/// (the paper's Table 7 "precision of the results").
pub fn confusion_accuracy(assignments: &[usize], labels: &[usize], clusters: usize) -> f64 {
    if assignments.is_empty() {
        return 0.0;
    }
    let classes = labels.iter().copied().max().unwrap_or(0) + 1;
    let m = confusion_matrix(assignments, labels, clusters, classes);
    let matching = hungarian_max(&m);
    let correct: u64 = matching
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != usize::MAX)
        .map(|(i, &c)| m[i][c])
        .sum();
    correct as f64 / assignments.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering_scores_one() {
        let labels = vec![0, 0, 1, 1, 2, 2];
        // Clusters permuted relative to classes.
        let assign = vec![2, 2, 0, 0, 1, 1];
        assert_eq!(confusion_accuracy(&assign, &labels, 3), 1.0);
    }

    #[test]
    fn chance_level_two_balanced_classes() {
        // Assignments independent of labels → ~50%.
        let labels: Vec<usize> = (0..1000).map(|i| i % 2).collect();
        let assign: Vec<usize> = (0..1000).map(|i| (i / 2) % 2).collect();
        let acc = confusion_accuracy(&assign, &labels, 2);
        assert!((0.45..0.55).contains(&acc), "{acc}");
    }

    #[test]
    fn hungarian_simple_case() {
        // weights: row 0 prefers col 1, row 1 prefers col 0.
        let w = vec![vec![1, 10], vec![8, 2]];
        let m = hungarian_max(&w);
        assert_eq!(m, vec![1, 0]);
    }

    #[test]
    fn hungarian_beats_greedy() {
        // Greedy would give row0→col0 (9), forcing row1→col1 (1): total 10.
        // Optimal is row0→col1 (8) + row1→col0 (7): total 15.
        let w = vec![vec![9, 8], vec![7, 1]];
        let m = hungarian_max(&w);
        let total: u64 = m.iter().enumerate().map(|(i, &j)| w[i][j]).sum();
        assert_eq!(total, 15);
    }

    #[test]
    fn rectangular_more_clusters_than_classes() {
        let labels = vec![0, 0, 1, 1];
        let assign = vec![0, 2, 1, 1]; // 3 clusters, 2 classes
        let acc = confusion_accuracy(&assign, &labels, 3);
        // Best: cluster0→class0 (1), cluster1→class1 (2); cluster2 unmatched.
        assert!((acc - 0.75).abs() < 1e-12, "{acc}");
    }

    #[test]
    fn rectangular_more_classes_than_clusters() {
        let labels = vec![0, 1, 2, 2];
        let assign = vec![0, 1, 1, 1];
        let acc = confusion_accuracy(&assign, &labels, 2);
        // cluster0→class0 (1), cluster1→class2 (2) = 3/4.
        assert!((acc - 0.75).abs() < 1e-12, "{acc}");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(confusion_accuracy(&[], &[], 2), 0.0);
    }

    #[test]
    fn confusion_matrix_counts() {
        let m = confusion_matrix(&[0, 0, 1], &[1, 1, 0], 2, 2);
        assert_eq!(m[0][1], 2);
        assert_eq!(m[1][0], 1);
        assert_eq!(m[0][0] + m[1][1], 0);
    }
}
