//! The `bigfcm` launcher.
//!
//! ```text
//! bigfcm run         --dataset susy --records 100000 --clusters 6 [--save-model m.bfm]
//! bigfcm session     --iters 50 --bounds elkan [--save-model m.bfm] [--trace-out t.json --timeline]
//! bigfcm serve       --port 0 [--model id=path.bfm]... | --connect ADDR --send CMD
//! bigfcm serve-bench --clients 4 --records 500 [--open-loop --rate 2000] [--json BENCH_serve.json]
//! bigfcm score       --model m.bfm --out DIR [--store DIR | --dataset susy]
//! bigfcm bench       --exp table4 [--full] [--backend native|pjrt|auto]
//! bigfcm gen         --dataset higgs --records 1000000 --out higgs.csv
//! bigfcm info        [--artifacts artifacts] [--model m.bfm]
//! ```
//!
//! Every flag can also be set via `--config file.toml` and repeated
//! `--set section.key=value` overrides (see `rust/src/config`).
//!
//! All string→enum flag parsing routes through the `FromStr` impls next
//! to each enum (`config`, `fcm::loops`, `baselines`, `serve::service`),
//! and the dataset/algo/bounds/quant flags shared by `run`/`session`/
//! `score`/`serve`/`serve-bench` resolve through one
//! [`resolve_common_args`] helper.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bigfcm::baselines::{run_baseline, BaselineAlgo};
use bigfcm::bench::tables::{run_by_id, Ctx};
use bigfcm::bench::Scale;
use bigfcm::config::{params_hash, BoundModel, Config, QuantMode};
use bigfcm::coordinator::BigFcm;
use bigfcm::data::normalize::Scaler;
use bigfcm::data::{builtin, csv};
use bigfcm::fcm::loops::{
    run_fcm_session, run_fcm_session_sharded, CheckpointPolicy, FcmParams, PruneConfig,
    SessionAlgo, Variant,
};
use bigfcm::fcm::{assign_hard, KernelBackend, SessionCheckpoint};
use bigfcm::faults::FaultPlan;
use bigfcm::hdfs::BlockStore;
use bigfcm::json;
use bigfcm::mapreduce::{
    Engine, EngineOptions, SessionOptions, ShardMergeMode, ShardedEngine, SimCost, MIB,
};
use bigfcm::metrics::confusion_accuracy;
use bigfcm::runtime::ResolvedBackend;
use bigfcm::serve::{
    client_call, run_score_job, FrontOptions, ModelBundle, ModelRegistry, ScoreService,
    ServeFront, ServeOptions,
};
use bigfcm::telemetry::{chrome_trace_json, human_duration, metrics, trace};

/// CLI result: any error renders via Display at top level (offline build —
/// no anyhow, so context is folded into the message at the wrap site).
type CliResult<T> = Result<T, Box<dyn std::error::Error>>;

/// Early-return with a formatted error message.
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err(format!($($arg)*).into())
    };
}

/// Minimal flag parser: `--key value` pairs + positional subcommand.
struct Args {
    sub: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> CliResult<Args> {
        let mut it = std::env::args().skip(1).peekable();
        let sub = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags take no value when followed by another flag/end
                let value = match it.peek() {
                    Some(next) if !next.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.push((key.to_string(), value));
            } else {
                bail!("unexpected positional argument `{a}`");
            }
        }
        Ok(Args { sub, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Every occurrence of a repeatable flag, in order (e.g. `--model
    /// susy=a.bfm --model higgs=b.bfm`).
    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

fn load_config(args: &Args) -> CliResult<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))
            .map_err(|e| format!("loading config {path}: {e}"))?,
        None => Config::default(),
    };
    for (k, v) in &args.flags {
        if k == "set" {
            cfg.set_kv(v).map_err(|e| format!("applying --set {v}: {e}"))?;
        }
    }
    if let Some(b) = args.get("backend") {
        cfg.set("runtime.backend", b)?;
    }
    if let Some(a) = args.get("artifacts") {
        cfg.set("paths.artifacts_dir", a)?;
    }
    if let Some(s) = args.get("seed") {
        cfg.set("seed", s)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn backend_of(cfg: &Config) -> CliResult<Arc<dyn KernelBackend>> {
    Ok(Arc::new(ResolvedBackend::from_config(cfg)?))
}

/// The dataset/algo/variant/bounds/quant flag cluster shared by the
/// dataset-driven subcommands, resolved once (see [`resolve_common_args`]).
struct CommonArgs {
    dataset_name: String,
    records: usize,
    clusters: usize,
    fuzzifier: f64,
    epsilon: f64,
    algo: SessionAlgo,
    variant: Variant,
    prune: PruneConfig,
}

impl CommonArgs {
    /// Materialize the synthetic dataset these flags name. Commands that
    /// read an existing store skip this — flag resolution stays shared
    /// without forcing a dataset build.
    fn load_dataset(&self, seed: u64) -> CliResult<bigfcm::data::Dataset> {
        builtin::by_name(&self.dataset_name, self.records, seed)
            .ok_or_else(|| format!("unknown dataset `{}`", self.dataset_name).into())
    }
}

/// The single resolution path for the flags `run`/`session`/`score`/
/// `serve`/`serve-bench` share. `records_flag` names the record-count
/// flag: the serve commands size the dataset with `--dataset-records`
/// because their `--records` means per-client request counts.
fn resolve_common_args(
    args: &Args,
    cfg: &Config,
    records_flag: &str,
    records_default: usize,
    clusters_default: usize,
) -> CliResult<CommonArgs> {
    let dataset_name = args.get_or("dataset", "susy");
    let records: usize = args.get_or(records_flag, &records_default.to_string()).parse()?;
    let clusters: usize = args.get_or("clusters", &clusters_default.to_string()).parse()?;
    let fuzzifier: f64 = args.get_or("fuzzifier", "2.0").parse()?;
    let epsilon: f64 = args.get_or("epsilon", &cfg.fcm.epsilon.to_string()).parse()?;
    let algo: SessionAlgo = args.get_or("algo", "fcm").parse()?;
    let variant: Variant = args.get_or("variant", "fast").parse()?;
    let mut prune = PruneConfig::from_cluster(&cfg.cluster);
    match args.get_or("bounds", cfg.cluster.bounds.as_str()).as_str() {
        "off" => prune.enabled = false,
        b => prune.bounds = b.parse::<BoundModel>()?,
    }
    if let Some(q) = args.get("quant") {
        prune.quant = q.parse::<QuantMode>()?;
    }
    if let Some(t) = args.get("tolerance") {
        prune.tolerance = t.parse()?;
    }
    if let Some(s) = args.get("slab-mib") {
        prune.slab_bytes = s.parse::<u64>()? * MIB;
    }
    if let Some(dir) = args.get("spill-dir") {
        prune.spill_dir = Some(std::path::PathBuf::from(dir));
    }
    Ok(CommonArgs { dataset_name, records, clusters, fuzzifier, epsilon, algo, variant, prune })
}

/// Admission/batching knobs shared by `serve` and `serve-bench`:
/// `serve.*` config defaults with per-invocation flag overrides.
fn resolve_serve_options(args: &Args, cfg: &Config) -> CliResult<ServeOptions> {
    let mut opts = ServeOptions::from_config(&cfg.serve);
    if let Some(v) = args.get("max-batch") {
        opts.max_batch = v.parse::<usize>()?.max(1);
    }
    if let Some(v) = args.get("linger-us") {
        opts.linger = Duration::from_micros(v.parse::<u64>()?);
    }
    if let Some(v) = args.get("queue-cap") {
        opts.queue_cap = v.parse::<usize>()?.max(1);
    }
    if let Some(v) = args.get("tenant-quota") {
        opts.tenant_quota = v.parse::<usize>()?;
    }
    if let Some(v) = args.get("deadline-us") {
        let us = v.parse::<u64>()?;
        opts.deadline = if us > 0 { Some(Duration::from_micros(us)) } else { None };
    }
    Ok(opts)
}

/// Engine options with the `[faults]` chaos plan attached (`None` when the
/// section is inert, so un-chaosed runs check nothing).
fn engine_options_of(cfg: &Config) -> CliResult<EngineOptions> {
    let mut opts = EngineOptions::from_cluster(&cfg.cluster);
    opts.faults = FaultPlan::from_config(&cfg.faults)?;
    Ok(opts)
}

/// Arm the global tracer from `cluster.trace` / `trace.*` config and the
/// `--trace-out` flag; returns the Chrome-trace output path when given.
/// Tracing stays fully off (the near-zero-cost disabled path) unless one
/// of the two asks for it.
fn arm_tracing(args: &Args, cfg: &Config) -> Option<String> {
    let out = args.get("trace-out").map(str::to_string);
    if cfg.cluster.trace || out.is_some() {
        let tracer = trace::global();
        tracer.set_slow_span_us(cfg.trace.slow_span_us);
        tracer.set_max_spans(cfg.trace.max_spans);
        tracer.enable(true);
    }
    out
}

/// Drain the tracer into Chrome tracing / Perfetto JSON at `path`, with
/// the modelled cost classes laid end-to-end as a second process's rows.
fn write_trace(path: &str, sim: &SimCost) -> CliResult<()> {
    let data = trace::global().drain();
    let sim_rows = [
        ("job_startup", sim.job_startup_s),
        ("task_launch", sim.task_launch_s),
        ("hdfs_io", sim.hdfs_io_s),
        ("shuffle", sim.shuffle_s),
        ("compute", sim.compute_s),
        ("net", sim.net_s),
        ("backoff", sim.backoff_s),
    ];
    let doc = chrome_trace_json(&data, &sim_rows);
    std::fs::write(path, doc).map_err(|e| format!("writing {path}: {e}"))?;
    println!("trace: wrote {path} ({} spans, {} dropped)", data.spans.len(), data.dropped);
    Ok(())
}

fn cmd_run(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    let common = resolve_common_args(args, &cfg, "records", 50000, 2)?;
    let (c, m, eps) = (common.clusters, common.fuzzifier, common.epsilon);
    let dataset = common.load_dataset(cfg.seed)?;
    let backend = backend_of(&cfg)?;
    println!(
        "dataset={} records={} dims={} C={c} m={m} eps={eps:.0e} backend={}",
        dataset.name,
        dataset.rows(),
        dataset.dims(),
        backend.name()
    );

    let store = Arc::new(BlockStore::in_memory(
        dataset.name.clone(),
        &dataset.features,
        cfg.cluster.block_records,
        cfg.cluster.workers,
    )?);
    let run = BigFcm::new(cfg.clone())
        .backend(Arc::clone(&backend))
        .clusters(c)
        .fuzzifier(m)
        .epsilon(eps)
        .run_store(&store)?;

    println!(
        "driver: ran={} sample={} T_fcm={:?} T_wfcmpb={:?} flag={}",
        run.driver.ran,
        run.driver.sample_size,
        run.driver.t_fcm,
        run.driver.t_wfcmpb,
        if run.driver.flag_fcm { "FCM" } else { "WFCMPB" }
    );
    println!(
        "job: {} map tasks, {} attempts, shuffle {} B",
        run.job.map_tasks, run.job.attempts, run.job.shuffle_bytes
    );
    println!(
        "wall={} modelled={} (startup {:.1}s + launch {:.1}s + io {:.1}s + shuffle {:.1}s + compute {:.1}s)",
        human_duration(run.wall),
        human_duration(std::time::Duration::from_secs_f64(run.modelled_s())),
        run.sim.job_startup_s,
        run.sim.task_launch_s,
        run.sim.hdfs_io_s,
        run.sim.shuffle_s,
        run.sim.compute_s,
    );
    for i in 0..run.centers.rows() {
        let row: Vec<String> = run.centers.row(i).iter().take(8).map(|v| format!("{v:.3}")).collect();
        println!("center[{i}] w={:.1} [{}{}]", run.weights[i], row.join(", "),
            if run.centers.cols() > 8 { ", ..." } else { "" });
    }
    if let Some(labels) = &dataset.labels {
        let acc = confusion_accuracy(&assign_hard(&dataset.features, &run.centers), labels, c);
        println!("confusion accuracy: {:.1}%", acc * 100.0);
    }
    if let Some(path) = args.get("save-model") {
        let mut bundle = ModelBundle::new(run.centers.clone(), SessionAlgo::Fcm, Variant::Fast, m);
        bundle.weights = run.weights.clone();
        bundle.seed = cfg.seed;
        bundle.dataset = dataset.name.clone();
        bundle.trained_rows = dataset.rows() as u64;
        bundle.iterations = run.reduce_iterations as u64;
        bundle.objective = run.objective;
        bundle.converged = run.converged;
        let bytes = bundle.save(std::path::Path::new(path))?;
        println!("saved model bundle: {path} ({bytes} B)");
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    let name = args.get_or("dataset", "susy");
    let n: usize = args.get_or("records", "50000").parse()?;
    let algo: BaselineAlgo = args.get_or("algo", "fkm").parse()?;
    let mut cfg = cfg;
    cfg.fcm.clusters = args.get_or("clusters", "2").parse()?;
    cfg.fcm.fuzzifier = args.get_or("fuzzifier", "2.0").parse()?;
    cfg.fcm.epsilon = args.get_or("epsilon", &cfg.fcm.epsilon.to_string()).parse()?;
    cfg.fcm.max_iterations = args.get_or("max-iterations", "100").parse()?;
    let dataset = builtin::by_name(&name, n, cfg.seed)
        .ok_or_else(|| format!("unknown dataset `{name}`"))?;
    let backend = backend_of(&cfg)?;
    let store = Arc::new(BlockStore::in_memory(
        dataset.name.clone(),
        &dataset.features,
        cfg.cluster.block_records,
        cfg.cluster.workers,
    )?);
    let mut engine = Engine::new(EngineOptions::from_cluster(&cfg.cluster), cfg.overhead.clone());
    let run = run_baseline(algo, &cfg, &store, backend, &mut engine)?;
    println!(
        "{}: {} iterations ({} MR jobs), converged={}, wall={}, modelled={}",
        algo.as_str(),
        run.iterations,
        run.jobs,
        run.converged,
        human_duration(run.wall),
        human_duration(std::time::Duration::from_secs_f64(run.modelled_s())),
    );
    Ok(())
}

/// `bigfcm session`: the iteration-resident convergence loop (one engine
/// session spanning every iteration — warm cache/pool/prefetcher, sticky
/// pruning slab, worker-side tree combine), printing the per-iteration
/// JobStats session counters.
fn cmd_session(args: &Args) -> CliResult<()> {
    let mut cfg = load_config(args)?;
    if let Some(v) = args.get("shards") {
        cfg.set("cluster.shards", v)?;
    }
    if let Some(v) = args.get("merge") {
        cfg.set("shard.merge", v)?;
    }
    if let Some(v) = args.get("steal-penalty") {
        cfg.set("shard.steal_penalty", v)?;
    }
    cfg.validate()?;
    let trace_out = arm_tracing(args, &cfg);
    let common = resolve_common_args(args, &cfg, "records", 50000, 2)?;
    let (c, m, eps) = (common.clusters, common.fuzzifier, common.epsilon);
    cfg.fcm.clusters = c;
    let iters: usize = args.get_or("iters", "50").parse()?;
    let (algo, variant, prune) = (common.algo, common.variant, common.prune.clone());
    let dataset = common.load_dataset(cfg.seed)?;
    let backend = backend_of(&cfg)?;
    let store = Arc::new(BlockStore::in_memory(
        dataset.name.clone(),
        &dataset.features,
        cfg.cluster.block_records,
        cfg.cluster.workers,
    )?);
    if let Some(v) = args.get("checkpoint-every") {
        cfg.session.checkpoint_every = v.parse()?;
    }
    // --checkpoint implies a cadence: an unconfigured
    // session.checkpoint_every of 0 means every iteration here.
    let checkpoint = args.get("checkpoint").map(|p| CheckpointPolicy {
        every: cfg.session.checkpoint_every.max(1),
        path: std::path::PathBuf::from(p),
    });
    let mut rng = bigfcm::prng::Pcg::new(cfg.seed);
    let sample = store.sample_records(c.max(2) * 8, &mut rng)?;
    let mut v0 = bigfcm::fcm::seeding::random_records(&sample, c, &mut rng);
    let mut resumed_from: Option<u64> = None;
    if let Some(path) = args.get("resume").or_else(|| args.get("resume-or-cold")) {
        match SessionCheckpoint::load(std::path::Path::new(path)) {
            Ok(cp) => {
                if cp.centers.cols() != store.cols() {
                    bail!(
                        "checkpoint {path} has {}-dim centers, store `{}` has {} features",
                        cp.centers.cols(),
                        store.name(),
                        store.cols()
                    );
                }
                println!(
                    "resuming from {path}: iteration {}, objective {:.6e}",
                    cp.iteration, cp.objective
                );
                v0 = cp.centers;
                resumed_from = Some(cp.iteration);
            }
            Err(e) if args.has("resume-or-cold") => {
                println!("checkpoint unusable, cold-starting instead: {e}");
            }
            Err(e) => return Err(format!("--resume {path}: {e}").into()),
        }
    }
    let params = FcmParams { m, epsilon: eps, max_iterations: iters, variant };

    println!(
        "session: dataset={} records={} C={c} m={m} eps={eps:.0e} algo={algo:?} \
         variant={variant:?} bounds={} quant={} slab={} MiB spill={} backend={}",
        dataset.name,
        dataset.rows(),
        if prune.enabled { prune.bounds.as_str() } else { "off" },
        prune.quant.as_str(),
        prune.slab_bytes / MIB,
        prune
            .spill_dir
            .as_ref()
            .map(|d| d.display().to_string())
            .unwrap_or_else(|| "off".into()),
        backend.name(),
    );
    // (read retries, read aborts, quarantines, prefetch errors) summed over
    // every engine shard's block cache — the engines drop with their branch.
    let (run, sharded, recovery) = if cfg.cluster.shards > 1 {
        let mut engine = ShardedEngine::new(
            &store,
            &engine_options_of(&cfg)?,
            cfg.overhead.clone(),
            cfg.cluster.shards,
            cfg.shard.steal_penalty,
        );
        let res = run_fcm_session_sharded(
            &mut engine,
            &store,
            backend,
            algo,
            v0,
            &params,
            &prune,
            SessionOptions::default(),
            checkpoint.as_ref(),
            cfg.shard.merge,
        )?;
        let mut recovery = (0u64, 0u64, 0u64, 0u64);
        for i in 0..cfg.cluster.shards {
            let cache = engine.engine(i).block_cache();
            recovery.0 += cache.read_retries();
            recovery.1 += cache.read_aborts();
            recovery.2 += cache.quarantines();
            recovery.3 += cache.prefetch_errors();
        }
        (res.run.clone(), Some(res), recovery)
    } else {
        let mut engine = Engine::new(engine_options_of(&cfg)?, cfg.overhead.clone());
        let run = run_fcm_session(
            &mut engine,
            &store,
            backend,
            algo,
            v0,
            &params,
            &prune,
            SessionOptions::default(),
            checkpoint.as_ref(),
        )?;
        let cache = engine.block_cache();
        let recovery = (
            cache.read_retries(),
            cache.read_aborts(),
            cache.quarantines(),
            cache.prefetch_errors(),
        );
        (run, None, recovery)
    };
    for (i, s) in run.per_iteration.iter().enumerate() {
        println!(
            "  iter {:>3}: pruned {:>8} (quant {:>7}), cap {:>3}, reduce parts {:>3} (depth {}), \
             slab {:>7.2} MiB, evictions {:>4}, spilled {:>7.2} MiB, reloads {:>4}",
            i + 1,
            s.records_pruned,
            s.records_pruned_quant,
            s.refresh_cap,
            s.reduce_parts,
            s.combine_depth,
            s.slab_bytes as f64 / MIB as f64,
            s.slab_evictions,
            s.slab_spilled_bytes as f64 / MIB as f64,
            s.slab_reloads,
        );
    }
    if args.has("timeline") {
        // Per-iteration phase breakdown from the same JobStats rows the
        // trace spans are stamped from (read/compute are summed worker
        // seconds, so they can exceed the elapsed wall).
        println!(
            "timeline:  iter |   read_s | compute_s |   pruned | combine_s | reduce_s |   wall_s \
             |    sim_s"
        );
        for (i, s) in run.per_iteration.iter().enumerate() {
            println!(
                "timeline:  {:>4} | {:>8.3} | {:>9.3} | {:>8} | {:>9.3} | {:>8.3} | {:>8.3} | \
                 {:>8.3}",
                i + 1,
                s.read_wall_s,
                s.compute_wall_s,
                s.records_pruned,
                s.combine_wall_s,
                s.reduce_wall_s,
                s.wall.as_secs_f64(),
                s.sim.total_s(),
            );
        }
    }
    println!(
        "{} iterations ({} engine jobs), converged={}, objective {:.6e}",
        run.result.iterations, run.jobs, run.result.converged, run.result.objective
    );
    // Publish into the unified registry and report *from* it — the
    // counters line is a registry read, not a second hand-summed view.
    let reg = metrics::global();
    run.publish_metrics(reg);
    let rc = |k: &str| reg.value(k).unwrap_or(0.0) as u64;
    println!(
        "session counters: records_pruned {}, records_pruned_quant {}, quant_sidecar_bytes {}, \
         quant_build_s {:.3}, slab_spilled_bytes {}, slab_reloads {}, peak resident {:.1} MiB",
        rc("session.records_pruned"),
        rc("session.records_pruned_quant"),
        rc("session.quant_sidecar_bytes"),
        reg.value("session.quant_build_s").unwrap_or(0.0),
        rc("session.slab_spilled_bytes"),
        rc("session.slab_reloads"),
        rc("session.peak_resident_bytes") as f64 / MIB as f64,
    );
    if let Some(sh) = &sharded {
        println!(
            "sharded: {} shards, merge={}, steals {} ({} B over the rack link)",
            sh.shards,
            sh.merge.as_str(),
            sh.shard_steals,
            sh.shard_steal_bytes,
        );
        for (i, last) in sh.per_shard_last.iter().enumerate() {
            println!(
                "  shard {:>2}: blocks {:>4} (stolen {:>3}, {} B), pruned {:>8}, \
                 peak {:>7.2} MiB, modelled {:.3}s",
                i,
                last.map_tasks,
                last.shard_steals,
                last.shard_steal_bytes,
                sh.records_pruned_per_shard[i],
                sh.per_shard_peak_resident_bytes[i] as f64 / MIB as f64,
                last.sim.total_s(),
            );
        }
        if matches!(sh.merge, ShardMergeMode::Representative) {
            println!(
                "merge objective delta: last {:.6e} max {:.6e}",
                sh.merge_objective_delta, sh.merge_objective_delta_max,
            );
        }
    }
    if cfg.faults.enabled() || checkpoint.is_some() || resumed_from.is_some() {
        println!(
            "recovery: read retries {}, read aborts {}, quarantines {}, prefetch errors {}, \
             spill retries {}, spill quarantines {}, backoff {:.3}s, checkpoints {} ({} B)",
            recovery.0,
            recovery.1,
            recovery.2,
            recovery.3,
            run.slab_spill_retries,
            run.slab_spill_quarantines,
            run.sim.backoff_s,
            run.checkpoints_written,
            run.checkpoint_bytes,
        );
    }
    if let Some(at) = resumed_from {
        println!(
            "resumed at iteration {at}: {} total iterations of progress",
            at + run.result.iterations as u64
        );
    }
    println!(
        "modelled {} (startup {:.1}s + launch {:.1}s + io {:.1}s + shuffle {:.1}s + compute {:.1}s)",
        human_duration(std::time::Duration::from_secs_f64(run.sim.total_s())),
        run.sim.job_startup_s,
        run.sim.task_launch_s,
        run.sim.hdfs_io_s,
        run.sim.shuffle_s,
        run.sim.compute_s,
    );
    // Bitwise fingerprint of the final centers — the verify.sh sharded
    // smoke diffs this line across `--shards 1` and `--shards N`.
    let mut center_bytes = Vec::with_capacity(run.result.centers.as_slice().len() * 4);
    for v in run.result.centers.as_slice() {
        center_bytes.extend_from_slice(&v.to_le_bytes());
    }
    println!("centers fnv1a={:016x}", bigfcm::hdfs::fnv1a(&center_bytes));
    if let Some(path) = args.get("save-model") {
        let mut bundle = ModelBundle::new(run.result.centers.clone(), algo, variant, m);
        bundle.weights = run.result.weights.clone();
        bundle.seed = cfg.seed;
        bundle.dataset = dataset.name.clone();
        bundle.trained_rows = dataset.rows() as u64;
        bundle.iterations = run.result.iterations as u64;
        bundle.objective = run.result.objective;
        bundle.converged = run.result.converged;
        bundle.records_pruned = run.records_pruned;
        let bytes = bundle.save(std::path::Path::new(path))?;
        println!("saved model bundle: {path} ({bytes} B)");
    }
    if let Some(path) = &trace_out {
        write_trace(path, &run.sim)?;
    }
    Ok(())
}

/// Quick training path for serving commands invoked without `--model`:
/// min-max normalize the dataset, run a short iteration-resident session,
/// and wrap the result (scaler included) into a bundle.
fn train_quick_bundle(
    cfg: &Config,
    dataset: &bigfcm::data::Dataset,
    c: usize,
    m: f64,
    backend: Arc<dyn KernelBackend>,
) -> CliResult<ModelBundle> {
    let scaler = Scaler::min_max(&dataset.features);
    let mut features = dataset.features.clone();
    scaler.apply(&mut features);
    let store = Arc::new(BlockStore::in_memory(
        dataset.name.clone(),
        &features,
        cfg.cluster.block_records,
        cfg.cluster.workers,
    )?);
    let mut engine = Engine::new(EngineOptions::from_cluster(&cfg.cluster), cfg.overhead.clone());
    let mut rng = bigfcm::prng::Pcg::new(cfg.seed);
    let sample = store.sample_records(c.max(2) * 8, &mut rng)?;
    let v0 = bigfcm::fcm::seeding::random_records(&sample, c, &mut rng);
    let params = FcmParams { m, epsilon: 1e-8, max_iterations: 40, variant: Variant::Fast };
    let run = run_fcm_session(
        &mut engine,
        &store,
        backend,
        SessionAlgo::Fcm,
        v0,
        &params,
        &PruneConfig::from_cluster(&cfg.cluster),
        SessionOptions::default(),
        None,
    )?;
    let mut bundle =
        ModelBundle::new(run.result.centers.clone(), SessionAlgo::Fcm, Variant::Fast, m);
    bundle.weights = run.result.weights.clone();
    bundle.scaler = Some(scaler);
    bundle.seed = cfg.seed;
    bundle.dataset = dataset.name.clone();
    bundle.trained_rows = dataset.rows() as u64;
    bundle.iterations = run.result.iterations as u64;
    bundle.objective = run.result.objective;
    bundle.converged = run.result.converged;
    bundle.records_pruned = run.records_pruned;
    Ok(bundle)
}

/// `bigfcm serve-bench`: load harness against the online scoring
/// service. Closed-loop by default (N client threads each scoring R
/// records back-to-back — measures capacity); `--open-loop` schedules
/// arrivals at a fixed `--rate` independent of completions and measures
/// each latency from the *scheduled* arrival, so queueing delay from
/// falling behind counts against the service (no coordinated omission)
/// and SLO attainment (`p99 < --p99-target-us` at `--rate` req/s) is
/// meaningful. Reports into the console and (optionally) a bench JSON.
fn cmd_serve_bench(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    let trace_out = arm_tracing(args, &cfg);
    let common = resolve_common_args(args, &cfg, "dataset-records", 20000, 4)?;
    let open_loop = args.has("open-loop");
    let clients: usize = args.get_or("clients", "4").parse()?;
    let per_client: usize = args.get_or("records", "500").parse()?;
    if !open_loop && (clients == 0 || per_client == 0) {
        bail!("--clients and --records must be positive");
    }
    let dataset = common.load_dataset(cfg.seed)?;
    let backend = backend_of(&cfg)?;
    let bundle = match args.get("model") {
        Some(path) => {
            let b = ModelBundle::load(std::path::Path::new(path))?;
            if b.dims() != dataset.dims() {
                bail!(
                    "model expects {} features, dataset `{}` has {}",
                    b.dims(),
                    common.dataset_name,
                    dataset.dims()
                );
            }
            b
        }
        None => train_quick_bundle(
            &cfg,
            &dataset,
            common.clusters,
            common.fuzzifier,
            Arc::clone(&backend),
        )?,
    };
    let opts = resolve_serve_options(args, &cfg)?;
    println!(
        "serve-bench[{}]: model C={} d={} algo={} backend={} | max_batch={}, pad={}, \
         linger={:?}, queue_cap={}",
        if open_loop { "open" } else { "closed" },
        bundle.clusters(),
        bundle.dims(),
        bundle.algo.as_str(),
        backend.name(),
        opts.max_batch,
        opts.pad_rows,
        opts.linger,
        opts.queue_cap,
    );
    let bundle_algo = bundle.algo;
    let service = Arc::new(ScoreService::builder(bundle).options(opts).spawn(backend)?);
    let features = Arc::new(dataset.features);

    // Extra JSON fields the active mode contributes to the bench doc.
    let mut mode_json: Vec<(&str, json::Value)> = Vec::new();
    let (total, wall, rps);
    if open_loop {
        let rate: f64 = args.get_or("rate", "2000").parse()?;
        let duration_s: f64 = args.get_or("duration-s", "2.0").parse()?;
        let p99_target_us: u64 = args.get_or("p99-target-us", "5000").parse()?;
        let inflight: usize = args.get_or("inflight", "64").parse()?;
        if !rate.is_finite() || rate <= 0.0 || !duration_s.is_finite() || duration_s <= 0.0
            || inflight == 0
        {
            bail!("--rate, --duration-s and --inflight must be positive");
        }
        let n_req = (rate * duration_s).ceil().max(1.0) as usize;
        let arrivals: Arc<Vec<Duration>> = Arc::new(
            (0..n_req).map(|i| Duration::from_secs_f64(i as f64 / rate)).collect(),
        );
        let next = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let start = Instant::now();
        let handles: Vec<_> = (0..inflight)
            .map(|wi| {
                let svc = Arc::clone(&service);
                let x = Arc::clone(&features);
                let arrivals = Arc::clone(&arrivals);
                let next = Arc::clone(&next);
                std::thread::spawn(move || -> Result<Vec<u64>, String> {
                    let n = x.rows();
                    let mut lat = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= arrivals.len() {
                            return Ok(lat);
                        }
                        let due = arrivals[i];
                        loop {
                            let now = start.elapsed();
                            if now >= due {
                                break;
                            }
                            std::thread::sleep((due - now).min(Duration::from_micros(200)));
                        }
                        let row = x.row((wi + i * 7) % n);
                        let u = svc.score(row).map_err(|e| e.to_string())?;
                        let s: f32 = u.iter().sum();
                        if (s - 1.0).abs() > 1e-4 {
                            return Err(format!("membership row sums to {s}"));
                        }
                        lat.push(start.elapsed().saturating_sub(due).as_micros() as u64);
                    }
                })
            })
            .collect();
        let mut lats: Vec<u64> = Vec::with_capacity(n_req);
        for (wi, h) in handles.into_iter().enumerate() {
            let mut l = h
                .join()
                .map_err(|_| format!("worker {wi} panicked"))?
                .map_err(|e| format!("worker {wi}: {e}"))?;
            lats.append(&mut l);
        }
        let w = start.elapsed();
        lats.sort_unstable();
        let pct = |p: f64| -> u64 {
            lats[((lats.len() as f64 * p).ceil() as usize).clamp(1, lats.len()) - 1]
        };
        let (p50, p95, p99) = (pct(0.50), pct(0.95), pct(0.99));
        let ok = lats.iter().filter(|&&l| l <= p99_target_us).count();
        let ok_fraction = ok as f64 / lats.len() as f64;
        let attained = p99 <= p99_target_us;
        let achieved = lats.len() as f64 / w.as_secs_f64().max(1e-9);
        println!(
            "open-loop: {} arrivals at {rate:.0} req/s over {duration_s:.1}s -> achieved \
             {achieved:.0} req/s",
            lats.len(),
        );
        println!(
            "open-loop latency (from scheduled arrival): p50 {p50} us, p95 {p95} us, p99 {p99} us"
        );
        println!(
            "SLO p99 < {p99_target_us} us at {rate:.0} req/s: {} ({:.1}% of requests within \
             target)",
            if attained { "ATTAINED" } else { "MISSED" },
            ok_fraction * 100.0,
        );
        mode_json.push(("target_rps", json::num(rate)));
        mode_json.push(("achieved_rps", json::num(achieved)));
        mode_json.push(("slo_p99_target_us", json::num(p99_target_us as f64)));
        mode_json.push(("slo_attained", json::num(if attained { 1.0 } else { 0.0 })));
        mode_json.push(("slo_ok_fraction", json::num(ok_fraction)));
        mode_json.push(("open_p50_us", json::num(p50 as f64)));
        mode_json.push(("open_p95_us", json::num(p95 as f64)));
        mode_json.push(("open_p99_us", json::num(p99 as f64)));
        total = lats.len();
        wall = w;
        rps = achieved;
    } else {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|ci| {
                let svc = Arc::clone(&service);
                let x = Arc::clone(&features);
                std::thread::spawn(move || -> Result<(), String> {
                    let n = x.rows();
                    for r in 0..per_client {
                        let row = x.row((ci * per_client + r * 7) % n);
                        let u = svc.score(row).map_err(|e| e.to_string())?;
                        let s: f32 = u.iter().sum();
                        if (s - 1.0).abs() > 1e-4 {
                            return Err(format!("membership row sums to {s}"));
                        }
                    }
                    Ok(())
                })
            })
            .collect();
        for (ci, h) in handles.into_iter().enumerate() {
            h.join()
                .map_err(|_| format!("client {ci} panicked"))?
                .map_err(|e| format!("client {ci}: {e}"))?;
        }
        total = clients * per_client;
        wall = t0.elapsed();
        rps = total as f64 / wall.as_secs_f64().max(1e-9);
    }
    let stats = service.stats();
    println!(
        "served {} requests in {} -> {:.0} req/s across {} batches",
        stats.requests,
        human_duration(wall),
        rps,
        stats.batches,
    );
    println!(
        "batch fill {:.2} (pad utilization {:.2}), queue peak {}, backpressure waits {}",
        stats.batch_fill, stats.pad_utilization, stats.queue_peak, stats.backpressure_waits,
    );
    println!(
        "latency: p50 {} us, p95 {} us, p99 {} us (mean {:.1} us, max {} us)",
        stats.p50_us, stats.p95_us, stats.p99_us, stats.mean_us, stats.max_us,
    );
    let coalesced = stats.batch_fill > 1.0;
    println!("coalescing: {}", if coalesced { "yes (batch fill > 1)" } else { "NO" });
    // The bench's serving counters land in the unified registry too, so
    // the emitted JSON carries the registry snapshot alongside the legacy
    // per-struct object.
    let reg = metrics::global();
    stats.publish_metrics(reg, "serve.bench");
    let json_path = args.get_or("json", "none");
    if json_path != "none" {
        let mut obj = match stats.to_json() {
            json::Value::Object(o) => o,
            _ => unreachable!("ServeStats::to_json returns an object"),
        };
        obj.insert("mode".into(), json::s(if open_loop { "open" } else { "closed" }));
        obj.insert("throughput_rps".into(), json::num(rps));
        obj.insert("requests_total".into(), json::num(total as f64));
        obj.insert("clients".into(), json::num(clients as f64));
        obj.insert("records_per_client".into(), json::num(per_client as f64));
        obj.insert("wall_s".into(), json::num(wall.as_secs_f64()));
        for (k, v) in mode_json {
            obj.insert(k.into(), v);
        }
        // Config identity: bench_diff.sh refuses to diff JSONs whose
        // hashes disagree instead of reporting bogus regressions across
        // incomparable configs.
        let hash = params_hash(
            bundle_algo.as_str(),
            cfg.cluster.bounds.as_str(),
            cfg.cluster.quant.as_str(),
            cfg.cluster.workers,
            cfg.seed,
            cfg.cluster.shards,
            cfg.shard.merge,
            cfg.shard.steal_penalty,
        );
        let doc = json::obj(vec![
            ("bench", json::s("serve_bench")),
            (
                "workload",
                json::s(format!("{} {} records", common.dataset_name, common.records)),
            ),
            ("config_hash", json::s(hash)),
            ("serve", json::Value::Object(obj)),
            ("metrics", reg.to_json()),
        ]);
        std::fs::write(&json_path, json::to_string(&doc))
            .map_err(|e| format!("writing {json_path}: {e}"))?;
        println!("wrote {json_path}");
    }
    if args.has("require-coalescing") && !coalesced {
        bail!(
            "micro-batching did not coalesce (batch fill {:.2} <= 1)",
            stats.batch_fill
        );
    }
    if let Some(path) = &trace_out {
        // Close first so the serve-root manual span lands in the drain
        // (close() is idempotent; the Drop-time close becomes a no-op).
        service.close();
        write_trace(path, &SimCost::default())?;
    }
    Ok(())
}

/// `bigfcm score`: bulk ScoreJob — label every record of a store with
/// top-k sparse membership rows written to a new block store.
fn cmd_score(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    let trace_out = arm_tracing(args, &cfg);
    let common = resolve_common_args(args, &cfg, "records", 50000, 2)?;
    let out_dir = args
        .get("out")
        .ok_or("`bigfcm score` needs --out DIR for the membership store")?
        .to_string();
    let top_k: usize = args.get_or("topk", &cfg.serve.top_k.to_string()).parse()?;
    let quant = common.prune.quant;
    let backend = backend_of(&cfg)?;
    let store = match args.get("store") {
        Some(dir) => Arc::new(BlockStore::open_disk(
            dir.to_string(),
            cfg.cluster.workers,
            std::path::PathBuf::from(dir),
        )?),
        None => {
            let dataset = common.load_dataset(cfg.seed)?;
            Arc::new(BlockStore::in_memory(
                dataset.name.clone(),
                &dataset.features,
                cfg.cluster.block_records,
                cfg.cluster.workers,
            )?)
        }
    };
    let fault_plan = FaultPlan::from_config(&cfg.faults)?;
    let bundle = match args.get("model") {
        Some(path) => Arc::new(ModelBundle::load_with_faults(
            std::path::Path::new(path),
            fault_plan.as_deref(),
        )?),
        None => bail!("`bigfcm score` needs --model PATH (save one with run/session --save-model)"),
    };
    println!(
        "score: store={} ({} blocks, {} records x {} features) model C={} top_k={top_k} quant={} \
         backend={}",
        store.name(),
        store.num_blocks(),
        store.total_rows(),
        store.cols(),
        bundle.clusters(),
        quant.as_str(),
        backend.name(),
    );
    let mut engine = Engine::new(engine_options_of(&cfg)?, cfg.overhead.clone());
    let outcome = run_score_job(
        &mut engine,
        &store,
        bundle,
        backend,
        top_k,
        quant,
        std::path::PathBuf::from(&out_dir),
    )?;
    println!(
        "labeled {} records -> {} ({} blocks, {} B, k={}), mean top-1 membership {:.4}",
        outcome.totals.rows,
        out_dir,
        outcome.store.num_blocks(),
        outcome.store.total_bytes(),
        outcome.top_k,
        outcome.totals.top1_mass / outcome.totals.rows.max(1) as f64,
    );
    println!(
        "job: {} map tasks, locality {}+{}, prefetch hits {}, wall {}, modelled {}",
        outcome.stats.map_tasks,
        outcome.stats.locality_hits,
        outcome.stats.locality_steals,
        outcome.stats.prefetch_hits,
        human_duration(outcome.stats.wall),
        human_duration(std::time::Duration::from_secs_f64(engine.clock().total_s())),
    );
    if quant.enabled() {
        println!(
            "quant pre-pass: {} rows via candidates, sidecar {} B, build {:.3}s",
            outcome.stats.records_pruned_quant,
            outcome.stats.quant_sidecar_bytes,
            outcome.stats.quant_build_s,
        );
    }
    if cfg.faults.enabled() {
        let cache = engine.block_cache();
        println!(
            "recovery: read retries {}, read aborts {}, quarantines {}, prefetch errors {}, \
             backoff {:.3}s",
            cache.read_retries(),
            cache.read_aborts(),
            cache.quarantines(),
            cache.prefetch_errors(),
            cache.backoff_seconds(),
        );
    }
    if let Some(path) = &trace_out {
        let sim = engine.clock().cost();
        write_trace(path, &sim)?;
    }
    Ok(())
}

/// `bigfcm serve`: the network front. Server mode binds the TCP frame
/// protocol over a [`ModelRegistry`] (multi-model, hot reload over the
/// wire via `reload <id> <path>`); client mode (`--connect ADDR --send
/// CMD`) sends one framed command and prints the reply — the pair that
/// `scripts/verify.sh` smoke-tests end-to-end.
fn cmd_serve(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    if let Some(addr) = args.get("connect") {
        let cmd = args
            .get("send")
            .ok_or("`bigfcm serve --connect` needs --send \"CMD\"")?;
        let reply = client_call(addr, cmd, Duration::from_secs(10))?;
        println!("{reply}");
        return Ok(());
    }
    let host = args.get_or("host", "127.0.0.1");
    let port: u16 = args.get_or("port", "0").parse()?;
    let backend = backend_of(&cfg)?;
    let opts = resolve_serve_options(args, &cfg)?;
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&backend), opts));
    let models = args.get_all("model");
    if models.is_empty() {
        // No bundles on the command line: quick-train a `default` model so
        // the server is immediately scoreable (same path serve-bench uses).
        let common = resolve_common_args(args, &cfg, "dataset-records", 20000, 4)?;
        let dataset = common.load_dataset(cfg.seed)?;
        let bundle = train_quick_bundle(
            &cfg,
            &dataset,
            common.clusters,
            common.fuzzifier,
            Arc::clone(&backend),
        )?;
        let generation = registry.publish("default", bundle)?;
        println!(
            "published model `default` (quick-trained on {}, generation {generation})",
            common.dataset_name
        );
    }
    let fault_plan = FaultPlan::from_config(&cfg.faults)?;
    for spec in models {
        let (id, path) = spec
            .split_once('=')
            .ok_or_else(|| format!("--model expects id=path.bfm, got `{spec}`"))?;
        let bundle =
            ModelBundle::load_with_faults(std::path::Path::new(path), fault_plan.as_deref())?;
        let generation = registry.publish(id, bundle)?;
        println!("published model `{id}` from {path} (generation {generation})");
    }
    let mut fopts = FrontOptions::default();
    fopts.faults = fault_plan;
    if let Some(v) = args.get("conn-workers") {
        fopts.conn_workers = v.parse::<usize>()?.max(1);
    }
    let front = ServeFront::bind(
        Arc::clone(&registry),
        &format!("{host}:{port}"),
        fopts,
        cfg.overhead.clone(),
    )?;
    let addr = front.local_addr();
    println!("bigfcm serve listening on {addr} (models: {})", registry.ids().join(", "));
    if let Some(pf) = args.get("port-file") {
        // Scripted callers bind port 0 and read the resolved address here.
        std::fs::write(pf, addr.to_string()).map_err(|e| format!("writing {pf}: {e}"))?;
    }
    while !front.is_shutdown() {
        std::thread::sleep(Duration::from_millis(100));
    }
    front.shutdown();
    let stats = front.stats();
    println!(
        "front: {} connections, {} frames ({} framing errors), {} scored, {} B in / {} B out, \
         modelled net {:.3}s, injected drops {}, injected wait {:.3}s",
        stats.connections,
        stats.frames,
        stats.framing_errors,
        stats.scored,
        stats.bytes_in,
        stats.bytes_out,
        stats.modelled_net_s,
        stats.conn_drops,
        stats.injected_wait_s,
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    let exp = args.get_or("exp", "all");
    let scale = if args.has("full") { Scale::full() } else { Scale::quick() };
    let backend = backend_of(&cfg)?;
    let ctx = Ctx::new(cfg, scale, backend);
    for table in run_by_id(&exp, &ctx)? {
        println!("{table}");
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    let name = args.get_or("dataset", "susy");
    let n: usize = args.get_or("records", "100000").parse()?;
    let out = args.get_or("out", &format!("{name}.csv"));
    let dataset = builtin::by_name(&name, n, cfg.seed)
        .ok_or_else(|| format!("unknown dataset `{name}`"))?;
    let f = std::fs::File::create(&out)?;
    csv::write_csv(&dataset, std::io::BufWriter::new(f))?;
    println!("wrote {} records x {} features to {out}", dataset.rows(), dataset.dims());
    Ok(())
}

fn cmd_info(args: &Args) -> CliResult<()> {
    let cfg = load_config(args)?;
    println!("bigfcm {} — BigFCM on a MapReduce substrate", env!("CARGO_PKG_VERSION"));
    println!("config: workers={} chunk={} block_records={}",
        cfg.cluster.workers, cfg.cluster.chunk, cfg.cluster.block_records);
    if let Some(path) = args.get("model") {
        match ModelBundle::load(std::path::Path::new(path)) {
            Ok(b) => println!("model bundle {path} (checksum ok):\n{}", b.summary()),
            Err(e) => println!("model bundle {path}: unreadable ({e})"),
        }
    }
    match bigfcm::runtime::PjrtRuntime::open(&cfg.artifacts_dir) {
        Ok(rt) => {
            println!(
                "artifacts: {} entries (chunk={}, row_block={}) at {}",
                rt.manifest().artifacts.len(),
                rt.manifest().chunk,
                rt.manifest().row_block,
                cfg.artifacts_dir.display()
            );
            for a in &rt.manifest().artifacts {
                println!("  {} ({}, d={}, C={})", a.name, a.graph.as_str(), a.dims, a.clusters);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn main() -> CliResult<()> {
    let args = Args::parse()?;
    match args.sub.as_str() {
        "run" => cmd_run(&args),
        "baseline" => cmd_baseline(&args),
        "session" => cmd_session(&args),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "score" => cmd_score(&args),
        "bench" => cmd_bench(&args),
        "gen" => cmd_gen(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!(
                "usage: bigfcm <run|baseline|session|serve|serve-bench|score|bench|gen|info> [--flags]\n\
                 \n\
                 run         run BigFCM on a dataset (--dataset --records --clusters --epsilon\n\
                 \u{20}           --save-model PATH)\n\
                 baseline    run a Mahout-style baseline (--algo km|fkm ...)\n\
                 session     iteration-resident convergence loop (--iters N\n\
                 \u{20}           --bounds dmin|elkan|hamerly|off --quant off|i8\n\
                 \u{20}           --algo fcm|kmeans --variant fast|classic --slab-mib N\n\
                 \u{20}           --spill-dir PATH --tolerance T --save-model PATH\n\
                 \u{20}           --checkpoint PATH --checkpoint-every N\n\
                 \u{20}           --resume PATH | --resume-or-cold PATH\n\
                 \u{20}           --shards N --merge exact|representative\n\
                 \u{20}           --steal-penalty X --trace-out t.json --timeline)\n\
                 \u{20}           with per-iteration + per-shard counters\n\
                 serve       network scoring front over a multi-model registry\n\
                 \u{20}           server: --host H --port P [--port-file PATH]\n\
                 \u{20}           [--model id=path.bfm]... [--tenant-quota N] [--conn-workers N]\n\
                 \u{20}           [--deadline-us U]\n\
                 \u{20}           client: --connect ADDR --send \"score default - normal 1,2,3\"\n\
                 \u{20}           (wire verbs: ping, health, score, reload, retire, stats,\n\
                 \u{20}           metrics, shutdown)\n\
                 serve-bench load harness for the online scoring service\n\
                 \u{20}           (--clients N --records R [--model PATH] [--max-batch B]\n\
                 \u{20}           [--linger-us U] [--queue-cap Q] [--tenant-quota N]\n\
                 \u{20}           [--open-loop --rate RPS --duration-s S --p99-target-us T\n\
                 \u{20}           --inflight W] [--json PATH|none] [--require-coalescing])\n\
                 score       bulk ScoreJob: label a store with top-k memberships\n\
                 \u{20}           (--model PATH --out DIR [--store DIR | --dataset D --records N]\n\
                 \u{20}           [--topk K] [--quant off|i8])\n\
                 bench       regenerate paper tables (--exp table2..table8|ablations|all [--full])\n\
                 gen         write a synthetic dataset to CSV (--dataset --records --out)\n\
                 info        show config + artifact registry [--model PATH]\n\
                 \n\
                 common:     --config file.toml --set sec.key=val --backend native|pjrt|auto|shim\n\
                 \u{20}           --artifacts DIR --seed N\n\
                 \u{20}           tracing: --trace-out t.json on session/score/serve-bench\n\
                 \u{20}           (Chrome/Perfetto JSON; --set cluster.trace=on,\n\
                 \u{20}           --set trace.slow_span_us=U for slow-span logs)\n\
                 \u{20}           chaos: --set faults.seed=S --set faults.block_read=R ... (see\n\
                 \u{20}           [faults] config; deterministic per seed, off by default)"
            );
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `bigfcm help`)"),
    }
}
