//! The BigFCM coordinator — the paper's system contribution (Algorithm 3).
//!
//! One pipeline run is:
//!
//! 1. **Driver job** ([`driver`]): sample R_x records (Parker–Hall sizing,
//!    Eq. 4), race plain FCM vs WFCMPB on the sample, pick the faster
//!    (`Flag`), store the winner's centers in the distributed cache.
//! 2. **The single MapReduce job** ([`combine_job`]): every map task runs
//!    the selected fast clustering over its block, warm-started from the
//!    cached centers, and emits `(centers, weights)`; the reducer merges all
//!    weighted centers with WFCM (optionally as a two-level tree).
//! 3. The final centers are the output — exactly one MR job regardless of
//!    epsilon, which is the paper's headline scaling property.

pub mod combine_job;
pub mod driver;

pub use combine_job::{CombineJob, CombinerOut};
pub use driver::{run_driver, DriverDecision};

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::data::{Dataset, Matrix};
use crate::error::Result;
use crate::fcm::{KernelBackend, ClusterResult, NativeBackend};
use crate::hdfs::BlockStore;
use crate::mapreduce::{
    DistributedCache, Engine, EngineOptions, JobRunCfg, JobStats, SessionOptions, ShardedEngine,
    SimCost,
};

/// Everything a BigFCM run produces.
#[derive(Clone, Debug)]
pub struct BigFcmRun {
    /// Final cluster centers (C, d).
    pub centers: Matrix,
    /// Final per-center weights.
    pub weights: Vec<f64>,
    /// Driver decision record (flag, race timings, sample size).
    pub driver: DriverDecision,
    /// Stats of the single MR job.
    pub job: JobStats,
    /// Real time of the whole pipeline on this machine.
    pub wall: Duration,
    /// Modelled cluster time of the whole pipeline.
    pub sim: SimCost,
    /// Reducer iterations (WFCM merge convergence).
    pub reduce_iterations: usize,
    /// Final reducer objective (stored into saved model bundles).
    pub objective: f64,
    /// Whether the WFCM reduce met its epsilon criterion (stored into
    /// saved model bundles — a capped, unconverged reduce must not be
    /// persisted as converged provenance).
    pub converged: bool,
    /// Per-shard stats rows of the MR job, with steal counters stamped
    /// (empty when `cluster.shards <= 1` — the single-engine pipeline).
    pub per_shard: Vec<JobStats>,
}

impl BigFcmRun {
    /// Modelled total seconds (what the paper's tables report).
    pub fn modelled_s(&self) -> f64 {
        self.sim.total_s()
    }
}

/// Builder-style front end for the pipeline.
pub struct BigFcm {
    cfg: Config,
    backend: Option<Arc<dyn KernelBackend>>,
}

impl BigFcm {
    pub fn new(cfg: Config) -> Self {
        Self { cfg, backend: None }
    }

    /// Override the chunk backend (default: native).
    pub fn backend(mut self, backend: Arc<dyn KernelBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn clusters(mut self, c: usize) -> Self {
        self.cfg.fcm.clusters = c;
        self
    }

    pub fn fuzzifier(mut self, m: f64) -> Self {
        self.cfg.fcm.fuzzifier = m;
        self
    }

    pub fn epsilon(mut self, eps: f64) -> Self {
        self.cfg.fcm.epsilon = eps;
        self
    }

    pub fn driver_epsilon(mut self, eps: f64) -> Self {
        self.cfg.fcm.driver_epsilon = eps;
        self
    }

    pub fn max_iterations(mut self, n: usize) -> Self {
        self.cfg.fcm.max_iterations = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Disable the driver pre-clustering (ablation: random seeds instead).
    pub fn without_driver(mut self) -> Self {
        self.cfg.fcm.driver_preclustering = false;
        self
    }

    /// Run over an existing block store with a fresh engine. The store is
    /// taken behind `Arc` because the engine's streaming map pipeline reads
    /// blocks from the worker pool. Engine shape (workers, block-cache
    /// budget, prefetch) comes from the cluster config.
    pub fn run_store(&self, store: &Arc<BlockStore>) -> Result<BigFcmRun> {
        if self.cfg.cluster.shards > 1 {
            let mut engine = ShardedEngine::new(
                store,
                &EngineOptions::from_cluster(&self.cfg.cluster),
                self.cfg.overhead.clone(),
                self.cfg.cluster.shards,
                self.cfg.shard.steal_penalty,
            );
            return self.run_with_sharded_engine(store, &mut engine);
        }
        let mut engine = Engine::new(
            EngineOptions::from_cluster(&self.cfg.cluster),
            self.cfg.overhead.clone(),
        );
        self.run_with_engine(store, &mut engine)
    }

    /// Run over in-memory records (shards them first).
    pub fn run_in_memory(&self, features: &Matrix) -> Result<BigFcmRun> {
        let store = Arc::new(BlockStore::in_memory(
            "in-memory",
            features,
            self.cfg.cluster.block_records,
            self.cfg.cluster.workers,
        )?);
        self.run_store(&store)
    }

    /// Convenience: run over a [`Dataset`].
    pub fn run_dataset(&self, dataset: &Dataset) -> Result<BigFcmRun> {
        self.run_in_memory(&dataset.features)
    }

    /// Run the full pipeline on a caller-provided engine (so several runs
    /// can share one SimClock and one warm block cache, e.g. in the bench
    /// harness). One [`crate::mapreduce::IterativeSession`] spans both
    /// phases: the driver's sampling/racing and the single MR job share
    /// the warm pool, cache and prefetcher, and the job's combiner outputs
    /// merge on the workers (tree combine) when `cluster.tree_combine` is
    /// on.
    pub fn run_with_engine(&self, store: &Arc<BlockStore>, engine: &mut Engine) -> Result<BigFcmRun> {
        self.cfg.validate()?;
        let backend: Arc<dyn KernelBackend> =
            self.backend.clone().unwrap_or_else(|| Arc::new(NativeBackend));
        let started = Instant::now();
        let cache = Arc::new(DistributedCache::new());
        let mut session = engine.session(store, SessionOptions::default());

        // ---- Phase 1: driver job -------------------------------------
        let decision = run_driver(&self.cfg, backend.as_ref(), &cache, &mut session)?;

        // ---- Phase 2: the single MR job ------------------------------
        let job = Arc::new(CombineJob::new(self.cfg.clone(), Arc::clone(&backend)));
        let (reduced, stats) = session.run_iteration(Arc::clone(&job), Arc::clone(&cache))?;
        drop(session);

        Ok(BigFcmRun {
            centers: reduced.result.centers,
            weights: reduced.result.weights,
            driver: decision,
            wall: started.elapsed(),
            sim: engine.clock().cost(),
            reduce_iterations: reduced.result.iterations,
            objective: reduced.result.objective,
            converged: reduced.result.converged,
            job: stats,
            per_shard: Vec::new(),
        })
    }

    /// Run the full pipeline across engine shards (`cluster.shards > 1`):
    /// the driver phase executes on shard 0's engine (its sampling and
    /// racing charges fold into the global clock), then the single MR job
    /// fans out one map + local-combine phase per shard and the global
    /// merge DAG completes driver-side — bitwise the single-engine
    /// pipeline result, with startup charged once per shard and stolen
    /// blocks' rack traffic on `net_s`.
    pub fn run_with_sharded_engine(
        &self,
        store: &Arc<BlockStore>,
        engine: &mut ShardedEngine,
    ) -> Result<BigFcmRun> {
        self.cfg.validate()?;
        let backend: Arc<dyn KernelBackend> =
            self.backend.clone().unwrap_or_else(|| Arc::new(NativeBackend));
        let started = Instant::now();
        let cache = Arc::new(DistributedCache::new());

        // ---- Phase 1: driver job, on shard 0 -------------------------
        let driver_before = engine.engine(0).clock().cost();
        let decision = {
            let mut session = engine.engine_mut(0).session(store, SessionOptions::default());
            run_driver(&self.cfg, backend.as_ref(), &cache, &mut session)?
        };
        let driver_cost = engine.engine(0).clock().cost().delta(&driver_before);
        engine.absorb(&driver_cost);

        // ---- Phase 2: the single MR job, one map phase per shard -----
        let job = Arc::new(CombineJob::new(self.cfg.clone(), Arc::clone(&backend)));
        let run_cfg =
            JobRunCfg { charge_startup: true, tree_combine: self.cfg.cluster.tree_combine };
        let (reduced, stats, per_shard) = engine.run_job_cfg(job, store, &cache, run_cfg)?;

        Ok(BigFcmRun {
            centers: reduced.result.centers,
            weights: reduced.result.weights,
            driver: decision,
            wall: started.elapsed(),
            sim: engine.clock().cost(),
            reduce_iterations: reduced.result.iterations,
            objective: reduced.result.objective,
            converged: reduced.result.converged,
            job: stats,
            per_shard,
        })
    }
}

/// Re-export for result users.
pub type FinalClustering = ClusterResult;
