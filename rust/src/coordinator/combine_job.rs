//! The single MapReduce job of BigFCM (Algorithm 3 lines 7–14).
//!
//! * **map+combine** (lines 7–11): cluster the block's records with the
//!   algorithm the driver flagged — plain fast FCM or WFCMPB — warm-started
//!   from the cached `v_init`; emit the block's centers with their weights
//!   (each weight = Σ membership mass of the block's records for that
//!   center).
//! * **reduce** (lines 12–14): WFCM over the union of all blocks' weighted
//!   centers. With `reducers > 1` the merge runs as a two-level tree —
//!   groups of map outputs are merged by intermediate WFCM reducers whose
//!   outputs a final WFCM folds together (the paper's "execute multiple
//!   reduce jobs … then integrate the results").

use std::sync::Arc;

use crate::config::Config;
use crate::coordinator::driver::{KEY_BLOCK_SIZE, KEY_FLAG, KEY_V_INIT};
use crate::data::Matrix;
use crate::error::{Error, Result};
use crate::fcm::loops::{run_fcm, FcmParams, Variant};
use crate::fcm::wfcmpb::{wfcmpb, WfcmpbResult};
use crate::fcm::KernelBackend;
use crate::mapreduce::{MapReduceJob, TaskCtx};

/// Combiner output: the block's centers with importance weights.
#[derive(Clone, Debug)]
pub struct CombinerOut {
    pub centers: Matrix,
    pub weights: Vec<f64>,
    pub iterations: usize,
}

/// The job object shared by all tasks.
pub struct CombineJob {
    cfg: Config,
    backend: Arc<dyn KernelBackend>,
}

impl CombineJob {
    pub fn new(cfg: Config, backend: Arc<dyn KernelBackend>) -> Self {
        Self { cfg, backend }
    }

    fn params(&self) -> FcmParams {
        FcmParams {
            m: self.cfg.fcm.fuzzifier,
            epsilon: self.cfg.fcm.epsilon,
            max_iterations: self.cfg.fcm.max_iterations,
            variant: Variant::Fast,
        }
    }

    /// WFCM over a pool of weighted centers (the reducer's core).
    fn wfcm_merge(&self, pool: &Matrix, pool_w: &[f64], seeds: Matrix) -> Result<WfcmpbResult> {
        let w32: Vec<f32> = pool_w.iter().map(|&w| w as f32).collect();
        let result = run_fcm(self.backend.as_ref(), pool, &w32, seeds, &self.params())?;
        Ok(WfcmpbResult { result, blocks: 1, block_iterations: vec![] })
    }
}

impl MapReduceJob for CombineJob {
    type MapOut = CombinerOut;
    type Output = WfcmpbResult;

    fn map_combine(&self, block: &Matrix, ctx: &TaskCtx) -> Result<CombinerOut> {
        let v_init = ctx
            .cache
            .get_matrix(KEY_V_INIT)
            .ok_or_else(|| Error::Job("v_init missing from distributed cache".into()))?;
        let flag_fcm = ctx.cache.get_flag(KEY_FLAG).unwrap_or(true);
        let params = self.params();
        if flag_fcm {
            // Flag = 1: plain fast FCM over the block (Algorithm 3 line 10).
            let w = vec![1.0f32; block.rows()];
            let r = run_fcm(self.backend.as_ref(), block, &w, v_init, &params)?;
            Ok(CombinerOut { centers: r.centers, weights: r.weights, iterations: r.iterations })
        } else {
            // Flag = 0: WFCMPB over the block.
            let block_size = ctx
                .cache
                .get_scalar(KEY_BLOCK_SIZE)
                .map(|b| b as usize)
                .unwrap_or_else(|| (block.rows() / 8).max(params_c(&v_init)));
            let r = wfcmpb(self.backend.as_ref(), block, v_init, block_size, &params)?;
            Ok(CombinerOut {
                centers: r.result.centers,
                weights: r.result.weights,
                iterations: r.result.iterations,
            })
        }
    }

    /// Tree combine only when the single-reducer funnel runs: with
    /// `cluster.reducers > 1` the reduce's own two-level WFCM grouping is
    /// keyed on the incoming part count, and pre-merged parts would
    /// silently bypass it — so the engine-level tree stands down and the
    /// multi-reducer path behaves exactly as before.
    fn supports_combine(&self) -> bool {
        self.cfg.cluster.reducers <= 1
    }

    /// Worker-side combine: **ordered pool concatenation**. Lossless and
    /// order-preserving — the reduce sees exactly the weighted-center rows
    /// a flat funnel would, in the same (block) order, so the tree path is
    /// a bit-identical drop-in even though `CombinerOut` pooling is not
    /// commutative. (The real O(blocks) → O(log blocks) reduction belongs
    /// to the `Partials`-merging iterative jobs, whose combine keeps the
    /// payload at C×d; this job's single reduce is already cheap.)
    fn combine(&self, mut left: CombinerOut, right: CombinerOut) -> Result<CombinerOut> {
        for i in 0..right.centers.rows() {
            left.centers.push_row(right.centers.row(i));
        }
        left.weights.extend_from_slice(&right.weights);
        left.iterations = left.iterations.max(right.iterations);
        Ok(left)
    }

    fn reduce(&self, parts: Vec<CombinerOut>, ctx: &TaskCtx) -> Result<WfcmpbResult> {
        if parts.is_empty() {
            return Err(Error::Job("reduce received no combiner outputs".into()));
        }
        let seeds = ctx
            .cache
            .get_matrix(KEY_V_INIT)
            .unwrap_or_else(|| parts[0].centers.clone());

        let reducers = self.cfg.cluster.reducers.max(1);
        let groups: Vec<&[CombinerOut]> = if reducers > 1 && parts.len() > reducers {
            parts.chunks(parts.len().div_ceil(reducers)).collect()
        } else {
            vec![&parts[..]]
        };

        // Level 1: per-group WFCM merges.
        let mut level1: Vec<CombinerOut> = Vec::with_capacity(groups.len());
        for g in &groups {
            let (pool, pool_w) = pool_of(g);
            let merged = self.wfcm_merge(&pool, &pool_w, seeds.clone())?;
            level1.push(CombinerOut {
                centers: merged.result.centers,
                weights: merged.result.weights,
                iterations: merged.result.iterations,
            });
        }

        // Level 2 (or the only level): final WFCM over the pooled output.
        let (pool, pool_w) = pool_of(&level1);
        let mut merged = self.wfcm_merge(&pool, &pool_w, seeds)?;

        // Reducer polish (our extension, `fcm.reducer_polish`): re-anchor
        // the merged centers with a short FCM pass over the driver's sample.
        // When every per-block FCM lands on a near-coincident center pair
        // (FCM's coincident-cluster mode), the WFCM merge of those pairs
        // collapses to exactly-equal f32 centers; the raw-record pass
        // recovers the data-space split, and on well-separated data it is a
        // no-op refinement.
        if self.cfg.fcm.reducer_polish {
            if let Some(sample) = ctx.cache.get_matrix(crate::coordinator::driver::KEY_SAMPLE) {
                // Exactly-equal centers are a symmetric fixed point of FCM
                // (identical memberships → identical updates), so break the
                // symmetry first by relocating duplicates to far records.
                crate::fcm::seeding::repair_duplicate_centers(
                    &sample,
                    &mut merged.result.centers,
                    1e-3,
                );
                let w = vec![1.0f32; sample.rows()];
                let polished =
                    run_fcm(self.backend.as_ref(), &sample, &w, merged.result.centers, &self.params())?;
                merged.result.centers = polished.centers;
            }
        }
        Ok(merged)
    }

    fn shuffle_bytes(&self, part: &CombinerOut) -> u64 {
        // centers f32 + weights f64.
        (part.centers.rows() * part.centers.cols() * 4 + part.weights.len() * 8) as u64
    }

    fn name(&self) -> &str {
        "bigfcm-combine"
    }
}

fn params_c(v: &Matrix) -> usize {
    v.rows().max(1)
}

/// Union all (centers, weights) into one weighted pool.
fn pool_of(parts: &[impl std::borrow::Borrow<CombinerOut>]) -> (Matrix, Vec<f64>) {
    let first = parts[0].borrow();
    let d = first.centers.cols();
    let mut pool = Matrix::zeros(0, d);
    let mut pool_w = Vec::new();
    for p in parts {
        let p = p.borrow();
        for i in 0..p.centers.rows() {
            pool.push_row(p.centers.row(i));
            pool_w.push(p.weights[i]);
        }
    }
    (pool, pool_w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::blobs;
    use crate::fcm::NativeBackend;
    use crate::mapreduce::DistributedCache;

    fn job(c: usize, reducers: usize) -> CombineJob {
        let mut cfg = Config::default();
        cfg.fcm.clusters = c;
        cfg.fcm.epsilon = 1e-9;
        cfg.cluster.reducers = reducers;
        CombineJob::new(cfg, Arc::new(NativeBackend))
    }

    fn cache_with_seeds(seeds: Matrix, flag: bool) -> DistributedCache {
        let c = DistributedCache::new();
        c.put_matrix(KEY_V_INIT, seeds);
        c.put_flag(KEY_FLAG, flag);
        c.put_scalar(KEY_BLOCK_SIZE, 128.0);
        c
    }

    #[test]
    fn combiner_emits_weighted_centers() {
        let data = blobs(512, 3, 3, 0.2, 1);
        let seeds = data.features.slice_rows(0, 3);
        let cache = cache_with_seeds(seeds, true);
        let j = job(3, 1);
        let ctx = TaskCtx { cache: &cache, task_id: 0, attempt: 0, doomed: false };
        let out = j.map_combine(&data.features, &ctx).unwrap();
        assert_eq!(out.centers.rows(), 3);
        assert_eq!(out.weights.len(), 3);
        // Weight mass is positive and bounded by the record count.
        let total: f64 = out.weights.iter().sum();
        assert!(total > 0.0 && total <= 512.0 + 1e-6, "total weight {total}");
    }

    #[test]
    fn combiner_wfcmpb_arm_runs() {
        let data = blobs(512, 3, 3, 0.2, 2);
        let seeds = data.features.slice_rows(0, 3);
        let cache = cache_with_seeds(seeds, false);
        let j = job(3, 1);
        let ctx = TaskCtx { cache: &cache, task_id: 0, attempt: 0, doomed: false };
        let out = j.map_combine(&data.features, &ctx).unwrap();
        assert_eq!(out.centers.rows(), 3);
    }

    #[test]
    fn reduce_merges_toward_global_centers() {
        // Split blob data into 4 parts; combiner each; reduce must land on
        // the blob structure.
        let data = blobs(2048, 3, 3, 0.2, 3);
        let seeds = data.features.slice_rows(0, 3);
        let cache = cache_with_seeds(seeds.clone(), true);
        let j = job(3, 1);
        let mut parts = Vec::new();
        for k in 0..4 {
            let blk = data.features.slice_rows(k * 512, (k + 1) * 512);
            let ctx = TaskCtx { cache: &cache, task_id: k, attempt: 0, doomed: false };
            parts.push(j.map_combine(&blk, &ctx).unwrap());
        }
        let ctx = TaskCtx { cache: &cache, task_id: usize::MAX, attempt: 0, doomed: false };
        let merged = j.reduce(parts, &ctx).unwrap();
        // Every merged center sits in a dense region.
        for i in 0..3 {
            let mut best = f64::INFINITY;
            for r in 0..data.features.rows() {
                best = best.min(data.features.row_dist2(r, merged.result.centers.row(i)));
            }
            assert!(best < 0.3, "merged center {i} off-data ({best})");
        }
    }

    #[test]
    fn tree_reduce_matches_flat_reduce() {
        let data = blobs(2048, 3, 3, 0.25, 4);
        let seeds = data.features.slice_rows(0, 3);
        let cache = cache_with_seeds(seeds, true);
        let flat = job(3, 1);
        let tree = job(3, 3);
        let mut parts = Vec::new();
        for k in 0..8 {
            let blk = data.features.slice_rows(k * 256, (k + 1) * 256);
            let ctx = TaskCtx { cache: &cache, task_id: k, attempt: 0, doomed: false };
            parts.push(flat.map_combine(&blk, &ctx).unwrap());
        }
        let ctx = TaskCtx { cache: &cache, task_id: usize::MAX, attempt: 0, doomed: false };
        let a = flat.reduce(parts.clone(), &ctx).unwrap();
        let b = tree.reduce(parts, &ctx).unwrap();
        // Both must describe the same blob structure (centers pairwise close).
        for i in 0..3 {
            let best = (0..3)
                .map(|jx| {
                    crate::data::matrix::dist2(
                        a.result.centers.row(i),
                        b.result.centers.row(jx),
                    )
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.05, "tree/flat divergence at center {i}: {best}");
        }
    }

    #[test]
    fn reduce_empty_fails() {
        let j = job(2, 1);
        let cache = DistributedCache::new();
        let ctx = TaskCtx { cache: &cache, task_id: 0, attempt: 0, doomed: false };
        assert!(j.reduce(vec![], &ctx).is_err());
    }

    #[test]
    fn missing_cache_fails_map() {
        let data = blobs(128, 2, 2, 0.3, 5);
        let cache = DistributedCache::new(); // no v_init
        let j = job(2, 1);
        let ctx = TaskCtx { cache: &cache, task_id: 0, attempt: 0, doomed: false };
        assert!(j.map_combine(&data.features, &ctx).is_err());
    }

    #[test]
    fn worker_combine_is_ordered_pool_concat() {
        let j = job(3, 1);
        assert!(j.supports_combine());
        let a = CombinerOut {
            centers: Matrix::from_rows(&[vec![1.0, 0.0]]),
            weights: vec![2.0],
            iterations: 3,
        };
        let b = CombinerOut {
            centers: Matrix::from_rows(&[vec![0.0, 1.0]]),
            weights: vec![5.0],
            iterations: 7,
        };
        let c = j.combine(a, b).unwrap();
        assert_eq!(c.centers.rows(), 2);
        assert_eq!(c.centers.row(0), &[1.0, 0.0]);
        assert_eq!(c.centers.row(1), &[0.0, 1.0]);
        assert_eq!(c.weights, vec![2.0, 5.0]);
        assert_eq!(c.iterations, 7);
    }

    #[test]
    fn shuffle_bytes_counts_payload() {
        let out = CombinerOut {
            centers: Matrix::zeros(3, 4),
            weights: vec![1.0; 3],
            iterations: 1,
        };
        let j = job(3, 1);
        assert_eq!(j.shuffle_bytes(&out), (3 * 4 * 4 + 3 * 8) as u64);
    }
}
