//! The driver job (Algorithm 3 lines 1–6).
//!
//! Samples R_x records from the block store (sized by Parker–Hall Eq. 4),
//! runs both candidate combiner algorithms on the sample —
//!
//! * plain fast FCM (one shot over the sample), and
//! * WFCMPB (block-wise weighted FCM, Algorithm 2)
//!
//! — compares their wall times (T_s vs T_f), sets `Flag` to the faster one
//! and publishes the winner's centers to the distributed cache as the
//! mappers' warm-start seeds (`v_init`). The driver runs on the master node
//! over a tiny sample, so it executes on the native backend; its time is
//! still charged to the modelled clock.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::error::Result;
use crate::fcm::loops::{run_fcm, FcmParams, Variant};
use crate::fcm::seeding::{kmeanspp, random_records};
use crate::fcm::wfcmpb::wfcmpb;
use crate::fcm::KernelBackend;
use crate::mapreduce::{DistributedCache, IterativeSession};
use crate::prng::Pcg;
use crate::sampling::parker_hall_sample_size;

/// Cache keys the driver writes.
pub const KEY_V_INIT: &str = "v_init";
pub const KEY_FLAG: &str = "flag";
pub const KEY_BLOCK_SIZE: &str = "wfcmpb_block";
/// The driver's sample R_x, shipped for the reducer polish pass.
pub const KEY_SAMPLE: &str = "driver_sample";

/// Record of the driver's decision (telemetry / Table 2 reporting).
#[derive(Clone, Debug)]
pub struct DriverDecision {
    /// Whether the pre-clustering ran at all (false = random-seed ablation).
    pub ran: bool,
    /// Sample size R_x.
    pub sample_size: usize,
    /// Plain-FCM time on the sample (T_s).
    pub t_fcm: Duration,
    /// WFCMPB time on the sample (T_f).
    pub t_wfcmpb: Duration,
    /// Flag = true → plain FCM in the combiners (paper's Flag = 1).
    pub flag_fcm: bool,
    /// Iterations the winning pre-clustering took.
    pub iterations: usize,
}

/// Run the driver job; writes `v_init`, `flag` (+ block size) to the
/// cache. Runs inside the pipeline's [`IterativeSession`], which spans the
/// driver and the MR phase so the engine's pool/cache/prefetcher stay warm
/// between them and driver-side charges land on the session's clock.
pub fn run_driver(
    cfg: &Config,
    backend: &dyn KernelBackend,
    cache: &DistributedCache,
    session: &mut IterativeSession<'_>,
) -> Result<DriverDecision> {
    let store = Arc::clone(session.store());
    let c = cfg.fcm.clusters;
    let mut rng = Pcg::new(cfg.seed);

    // Sample size λ = v(α)·c²/r² (Eq. 4), clamped to the dataset.
    let sample_size =
        parker_hall_sample_size(c, cfg.fcm.sample_rel_diff, cfg.fcm.sample_v_alpha)
            .min(store.total_rows());

    if !cfg.fcm.driver_preclustering {
        // Ablation arm: Mahout-style random record seeds, no pre-clustering.
        let sample = store.sample_records(c.max(2), &mut rng)?;
        let seeds = random_records(&sample, c, &mut rng);
        cache.put_matrix(KEY_V_INIT, seeds);
        cache.put_flag(KEY_FLAG, true);
        cache.put_scalar(KEY_BLOCK_SIZE, sample_size as f64);
        return Ok(DriverDecision {
            ran: false,
            sample_size: 0,
            t_fcm: Duration::ZERO,
            t_wfcmpb: Duration::ZERO,
            flag_fcm: true,
            iterations: 0,
        });
    }

    let sample = store.sample_records(sample_size, &mut rng)?;
    // Charge the sampling scan: proportional share of the store bytes.
    let frac = sample_size as f64 / store.total_rows().max(1) as f64;
    session.charge_scan((store.total_bytes() as f64 * frac) as u64);

    let params = FcmParams {
        m: cfg.fcm.fuzzifier,
        epsilon: cfg.fcm.driver_epsilon,
        max_iterations: cfg.fcm.max_iterations,
        variant: Variant::Fast,
    };
    let w = vec![1.0f32; sample.rows()];

    // Seeding per restart: D²-spread records (k-means++) rather than uniform
    // picks — with imbalanced classes (KDD99's 57% smurf) a uniform draw
    // concentrates all C seeds in the dominant classes. A few restarts with
    // best-objective selection de-risk an unlucky draw; the sample is small
    // so this is cheap. (The paper's driver only says "clustered using
    // basic FCM"; seeding + restarts are our refinement, ablated by
    // `without_driver`.)
    let restarts = cfg.fcm.driver_restarts.max(1);

    // Race 1: plain FCM over the sample (T_s; Algorithm 3 line 4).
    let t0 = Instant::now();
    let mut fcm_run = None;
    let mut best_seeds = None;
    for _ in 0..restarts {
        let seeds = kmeanspp(&sample, c, &mut rng);
        let r = run_fcm(backend, &sample, &w, seeds.clone(), &params)?;
        if fcm_run.as_ref().map_or(true, |b: &crate::fcm::ClusterResult| r.objective < b.objective)
        {
            fcm_run = Some(r);
            best_seeds = Some(seeds);
        }
    }
    let mut fcm_run = fcm_run.expect("restarts >= 1");
    let best_seeds = best_seeds.expect("restarts >= 1");
    // Repair duplicate centers (near-zero-variance clusters can capture
    // several centers without moving the objective) and re-converge.
    if crate::fcm::seeding::repair_duplicate_centers(&sample, &mut fcm_run.centers, 1e-2) > 0 {
        fcm_run = run_fcm(backend, &sample, &w, fcm_run.centers, &params)?;
    }
    let t_fcm = t0.elapsed();

    // Race 2: WFCMPB over the sample (T_f; line 2), from the winning seeds.
    // Block size = λ/8 so the sample spans several blocks, mirroring the
    // paper's per-block pass.
    let block = (sample_size / 8).max(c * 2);
    let t0 = Instant::now();
    let wf_run = wfcmpb(backend, &sample, best_seeds, block, &params)?;
    let mut wf_result = wf_run.result;
    // Same duplicate repair for the block-wise arm (see above).
    if crate::fcm::seeding::repair_duplicate_centers(&sample, &mut wf_result.centers, 1e-2) > 0 {
        wf_result = run_fcm(backend, &sample, &w, wf_result.centers, &params)?;
    }
    let t_wfcmpb = t0.elapsed();

    session.charge_local(t_fcm + t_wfcmpb);

    // Flag = 1 ⇔ plain FCM was faster (Algorithm 3 line 6). The race is the
    // paper's design and is timing-dependent; the Force* policies pin it for
    // reproducible runs.
    let flag_fcm = match cfg.fcm.flag_policy {
        // t_fcm covers `restarts` runs; compare per-run times.
        crate::config::FlagPolicy::Race => t_fcm.div_f64(restarts as f64) <= t_wfcmpb,
        crate::config::FlagPolicy::ForceFcm => true,
        crate::config::FlagPolicy::ForceWfcmpb => false,
    };
    let (centers, iterations) = if flag_fcm {
        (fcm_run.centers, fcm_run.iterations)
    } else {
        (wf_result.centers, wf_result.iterations)
    };
    cache.put_matrix(KEY_V_INIT, centers);
    cache.put_flag(KEY_FLAG, flag_fcm);
    cache.put_scalar(KEY_BLOCK_SIZE, block as f64);
    if cfg.fcm.reducer_polish {
        cache.put_matrix(KEY_SAMPLE, sample);
    }

    Ok(DriverDecision { ran: true, sample_size, t_fcm, t_wfcmpb, flag_fcm, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth::blobs;
    use crate::fcm::NativeBackend;
    use crate::hdfs::BlockStore;
    use crate::mapreduce::{Engine, EngineOptions, SessionOptions};

    fn setup(n: usize) -> (Config, Arc<BlockStore>, Engine) {
        let mut cfg = Config::default();
        cfg.fcm.clusters = 3;
        cfg.fcm.driver_epsilon = 1e-8;
        let data = blobs(n, 4, 3, 0.3, 42);
        let store = Arc::new(BlockStore::in_memory("t", &data.features, 256, 4).unwrap());
        let engine = Engine::new(EngineOptions::default(), cfg.overhead.clone());
        (cfg, store, engine)
    }

    #[test]
    fn driver_publishes_seeds_and_flag() {
        let (cfg, store, mut engine) = setup(2000);
        let cache = DistributedCache::new();
        let mut session = engine.session(&store, SessionOptions::default());
        let d = run_driver(&cfg, &NativeBackend, &cache, &mut session).unwrap();
        assert!(d.ran);
        assert!(d.sample_size > 100, "sample {}", d.sample_size);
        let v = cache.get_matrix(KEY_V_INIT).unwrap();
        assert_eq!((v.rows(), v.cols()), (3, 4));
        assert!(cache.get_flag(KEY_FLAG).is_some());
        assert!(d.iterations > 0);
    }

    #[test]
    fn sample_size_respects_parker_hall() {
        let (mut cfg, store, mut engine) = setup(100_000);
        cfg.fcm.clusters = 5;
        cfg.fcm.sample_rel_diff = 0.10;
        let cache = DistributedCache::new();
        let mut session = engine.session(&store, SessionOptions::default());
        let d = run_driver(&cfg, &NativeBackend, &cache, &mut session).unwrap();
        assert_eq!(d.sample_size, 3184); // the paper's worked example
    }

    #[test]
    fn sample_clamped_to_population() {
        let (cfg, store, mut engine) = setup(300);
        let cache = DistributedCache::new();
        let mut session = engine.session(&store, SessionOptions::default());
        let d = run_driver(&cfg, &NativeBackend, &cache, &mut session).unwrap();
        assert_eq!(d.sample_size, 300);
    }

    #[test]
    fn ablation_skips_preclustering() {
        let (mut cfg, store, mut engine) = setup(1000);
        cfg.fcm.driver_preclustering = false;
        let cache = DistributedCache::new();
        let mut session = engine.session(&store, SessionOptions::default());
        let d = run_driver(&cfg, &NativeBackend, &cache, &mut session).unwrap();
        assert!(!d.ran);
        assert_eq!(d.iterations, 0);
        // Seeds still published (random records).
        assert!(cache.get_matrix(KEY_V_INIT).is_some());
        assert_eq!(cache.get_flag(KEY_FLAG), Some(true));
    }

    #[test]
    fn driver_seeds_are_near_blob_centers() {
        let mut cfg = Config::default();
        cfg.fcm.clusters = 3;
        cfg.fcm.driver_epsilon = 1e-10;
        let data = blobs(3000, 3, 3, 0.15, 7);
        let store = Arc::new(BlockStore::in_memory("t", &data.features, 512, 4).unwrap());
        let mut engine = Engine::new(EngineOptions::default(), cfg.overhead.clone());
        let cache = DistributedCache::new();
        let mut session = engine.session(&store, SessionOptions::default());
        run_driver(&cfg, &NativeBackend, &cache, &mut session).unwrap();
        let seeds = cache.get_matrix(KEY_V_INIT).unwrap();
        // Each seed within 0.5 of some data point (pre-clustered, not random
        // box corners).
        for i in 0..3 {
            let mut best = f64::INFINITY;
            for j in 0..data.features.rows() {
                best = best.min(data.features.row_dist2(j, seeds.row(i)));
            }
            assert!(best < 0.5, "seed {i} far from data ({best})");
        }
    }
}
