//! Observability layer: structured tracing, a unified metrics registry and
//! the timing helpers every report shares.
//!
//! Two clocks exist in this system and every report keeps them separate:
//!
//! * **wall time** — real measured nanoseconds of our single-machine run;
//! * **sim time** — the modelled Hadoop-cluster time from
//!   [`crate::mapreduce::simclock`], which charges job/task/shuffle overheads
//!   the paper's physical testbed paid but a single process does not.
//!
//! The [`trace`] submodule records hierarchical spans (`session > iteration
//! > job > shard > map_task / combine / spill / prefetch`; `serve > batch >
//! score_chunk`) and exports Chrome `chrome://tracing` / Perfetto JSON; the
//! [`metrics`] submodule is the typed counter/gauge/histogram registry the
//! stats structs publish into so the CLI report, bench JSON and wire verbs
//! read one source of truth. Both degrade to dropping data on any internal
//! failure — instrumentation never kills a run.

pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, MetricValue, MetricsRegistry};
pub use trace::{chrome_trace_json, ManualSpan, SpanGuard, SpanRec, TraceData, Tracer};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A monotonically accumulating nanosecond cell, safe to bump from workers.
#[derive(Default)]
pub struct AtomicDuration {
    nanos: AtomicU64,
}

impl AtomicDuration {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn get(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }
}

/// Format a duration the way the paper's tables do (seconds, or m/h).
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 120.0 {
        format!("{s:.1}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else if s < 48.0 * 3600.0 {
        format!("{:.1}h", s / 3600.0)
    } else {
        format!("{:.1}d", s / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_duration_accumulates() {
        let d = AtomicDuration::new();
        d.add(Duration::from_millis(3));
        d.add(Duration::from_millis(4));
        assert_eq!(d.get(), Duration::from_millis(7));
    }

    #[test]
    fn human_duration_bands() {
        assert_eq!(human_duration(Duration::from_secs(30)), "30.0s");
        assert_eq!(human_duration(Duration::from_secs(600)), "10.0m");
        assert_eq!(human_duration(Duration::from_secs(7200)), "2.0h");
        assert_eq!(human_duration(Duration::from_secs(200_000)), "2.3d");
    }
}
